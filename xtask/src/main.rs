//! `cargo xtask lint` — the repo-native invariant linter.
//!
//! Walks `rust/src` (plus the equivalence suite and ROADMAP.md) and
//! enforces the invariants the engine's unsafe/atomic code lives by. This
//! is the first, fastest CI gate: it compiles with zero dependencies and
//! fails the build before the expensive matrix starts.
//!
//! Rules (rule IDs are stable; `tools/lint_mirror.py` reimplements the
//! same rules for authoring environments without a Rust toolchain — keep
//! the two in lockstep):
//!
//! * **R1** — every line whose *code* (comments/strings stripped) contains
//!   the token `unsafe` must have a `// SAFETY:` comment on the same line
//!   or within the 8 preceding lines, and `unsafe` may only appear at all
//!   in the allowlisted modules (`linalg::simd`, `runtime::pool`,
//!   `binary`, `transform`, `kernels::features`, `coordinator::backend`,
//!   `util::signal`).
//! * **R2** — every atomic-memory `Ordering::` use (`Relaxed`/`Acquire`/
//!   `Release`/`AcqRel`/`SeqCst`; `std::cmp::Ordering` is not matched)
//!   must have a `// ORDERING:` rationale within the same window. Exempt,
//!   per the LaneMetrics carve-out: `coordinator/metrics.rs` itself,
//!   counter bumps whose receiver chain goes through `metrics` (the site
//!   line or its 2 preceding continuation lines mention `metrics`), and
//!   `#[cfg(test)]` / `#[cfg(miri)]` modules.
//! * **R3** — every public SIMD kernel (`pub fn` at column 0 in
//!   `linalg/simd.rs`, minus the dispatch-introspection fns
//!   `level`/`force`/`active`) must be named in
//!   `rust/tests/simd_equivalence.rs`.
//! * **R4** — wire error codes (the `=> "..."` arms of the two
//!   `fn code()` bodies in `coordinator/mod.rs` plus the `CODE_*` consts
//!   in `coordinator/codec.rs` and `coordinator/server.rs`) must be
//!   unique and exactly equal the set in ROADMAP.md's "Serving failure
//!   model" table.
//! * **R5** — every `take_f32_uninit` / `take_f64_uninit` call site
//!   outside `linalg/workspace.rs` (where they are defined and
//!   self-tested) and outside test modules must carry a `// OVERWRITE:`
//!   comment within the window.
//! * **R6** — `rust/src/lib.rs` must carry
//!   `#![deny(unsafe_op_in_unsafe_fn)]` (what makes R1's per-operation
//!   granularity sound inside `unsafe fn`s).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Marker may sit on the site line or up to this many lines above. 8, not
/// less: rationale blocks span several comment lines and one block
/// legitimately covers the two or three stores of a single tiny method.
const WINDOW: usize = 8;

/// Modules allowed to contain `unsafe` at all (paths relative to
/// `rust/src`; a trailing `/` allowlists the whole directory).
const UNSAFE_ALLOWLIST: [&str; 7] = [
    "linalg/simd.rs",
    "runtime/pool.rs",
    "binary/",
    "transform/",
    "kernels/features.rs",
    "coordinator/backend.rs",
    "util/signal.rs",
];

/// `pub fn`s in `linalg/simd.rs` that are dispatch introspection, not
/// kernels — exempt from the equivalence-suite rule.
const KERNEL_ALLOWLIST: [&str; 3] = ["level", "force", "active"];

/// The five atomic-memory orderings (`std::cmp::Ordering` variants do not
/// appear here, so comparison code never trips R2).
const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `\b needle \b` word-boundary search (needle is ASCII).
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(p) = hay[start..].find(needle) {
        let at = start + p;
        let end = at + needle.len();
        let before_ok = at == 0 || !is_word(hay[..at].chars().next_back().unwrap());
        let after_ok = hay[end..].chars().next().is_none_or(|c| !is_word(c));
        if before_ok && after_ok {
            return true;
        }
        start = end;
    }
    false
}

/// Does this code line use an atomic-memory ordering (`Ordering::Relaxed`
/// etc.)? Word-boundary on both sides, so `MyOrdering::Relaxed` and
/// `Ordering::RelaxedExtra` do not match.
fn has_atomic_ordering(code: &str) -> bool {
    const TOK: &str = "Ordering::";
    let mut start = 0;
    while let Some(p) = code[start..].find(TOK) {
        let at = start + p;
        let before_ok = at == 0 || !is_word(code[..at].chars().next_back().unwrap());
        let rest = &code[at + TOK.len()..];
        let hit = before_ok
            && ATOMIC_ORDERINGS.iter().any(|v| {
                rest.starts_with(v) && rest[v.len()..].chars().next().is_none_or(|c| !is_word(c))
            });
        if hit {
            return true;
        }
        start = at + TOK.len();
    }
    false
}

/// One scanned source line: code with comments/strings stripped, the
/// comment text, and whether the line sits inside a `#[cfg(test)]` /
/// `#[cfg(miri)]` module.
struct Row {
    code: String,
    comment: String,
    in_test: bool,
}

/// Split one source line into (code, comment) given the running block
/// comment depth (Rust block comments nest). String and char literals are
/// blanked out of the code part so a quote or `//` inside them cannot
/// confuse detection; raw strings are handled for the `r"..."` form (no
/// `#` guards are used in this repo).
fn strip_line(line: &str, block_depth: &mut usize) -> (String, String) {
    let b: Vec<char> = line.chars().collect();
    let n = b.len();
    let (mut code, mut comment) = (String::new(), String::new());
    let mut i = 0;
    while i < n {
        let c = b[i];
        let nxt = if i + 1 < n { b[i + 1] } else { '\0' };
        if *block_depth > 0 {
            if c == '*' && nxt == '/' {
                *block_depth -= 1;
                comment.push_str("*/");
                i += 2;
            } else if c == '/' && nxt == '*' {
                *block_depth += 1;
                comment.push_str("/*");
                i += 2;
            } else {
                comment.push(c);
                i += 1;
            }
            continue;
        }
        if c == '/' && nxt == '/' {
            comment.extend(&b[i..]);
            break;
        }
        if c == '/' && nxt == '*' {
            *block_depth += 1;
            comment.push_str("/*");
            i += 2;
            continue;
        }
        if c == '"' || (c == 'r' && nxt == '"') {
            if c == 'r' {
                code.push('r');
                i += 1;
            }
            code.push_str("\"\"");
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    i += 2;
                } else if b[i] == '"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if c == '\'' {
            // char literal ('x' or '\x') vs lifetime ('static)
            if nxt == '\\' && i + 3 < n && b[i + 3] == '\'' {
                code.push_str("' '");
                i += 4;
                continue;
            }
            if nxt != '\\' && nxt != '\'' && i + 2 < n && b[i + 2] == '\'' {
                code.push_str("' '");
                i += 3;
                continue;
            }
            code.push(c);
            i += 1;
            continue;
        }
        code.push(c);
        i += 1;
    }
    (code, comment)
}

/// Is this (stripped) line a `#[cfg(test)]`-family attribute?
/// Matches `#[cfg(test…`, `#[cfg(miri…`, `#[cfg(all(test…`,
/// `#[cfg(all(miri…` with a word boundary after the keyword.
fn is_test_cfg_attr(stripped: &str) -> bool {
    ["#[cfg(test", "#[cfg(miri", "#[cfg(all(test", "#[cfg(all(miri"]
        .iter()
        .any(|pre| {
            stripped.find(pre).is_some_and(|p| {
                stripped[p + pre.len()..].chars().next().is_none_or(|c| !is_word(c))
            })
        })
}

/// Scan a whole file into rows, tracking nested block comments and
/// `#[cfg(test)] mod` / `#[cfg(miri)] mod` spans by brace depth.
fn scan_source(text: &str) -> Vec<Row> {
    let mut block_depth = 0usize;
    let mut rows = Vec::new();
    let mut pending_test_attr = false;
    let mut test_depth: Option<i64> = None;
    let mut depth: i64 = 0;
    for raw in text.lines() {
        let (code, comment) = strip_line(raw, &mut block_depth);
        let stripped = code.trim();
        let mut in_test = test_depth.is_some();
        if test_depth.is_none() {
            if is_test_cfg_attr(stripped) {
                pending_test_attr = true;
            } else if pending_test_attr && stripped.starts_with("mod ") {
                test_depth = Some(depth);
                in_test = true;
                pending_test_attr = false;
            } else if !stripped.is_empty() && !stripped.starts_with("#[") {
                pending_test_attr = false;
            }
        }
        depth += code.matches('{').count() as i64 - code.matches('}').count() as i64;
        if let Some(td) = test_depth {
            if depth <= td && code.contains('}') {
                // the closing brace line itself still counts as test code
                rows.push(Row { code, comment, in_test: true });
                test_depth = None;
                continue;
            }
        }
        rows.push(Row { code, comment, in_test });
    }
    rows
}

/// Is `marker` present in a comment on the site line or within the
/// preceding WINDOW lines?
fn has_marker(rows: &[Row], idx: usize, marker: &str) -> bool {
    let lo = idx.saturating_sub(WINDOW);
    rows[lo..=idx].iter().any(|r| r.comment.contains(marker))
}

/// R1 / R2 / R5 over a single source file (`rel` is the path relative to
/// `rust/src`, forward slashes).
fn lint_annotations(rel: &str, text: &str, errors: &mut Vec<String>) {
    let rows = scan_source(text);
    let allowed_unsafe = UNSAFE_ALLOWLIST
        .iter()
        .any(|a| rel == *a || (a.ends_with('/') && rel.starts_with(a)));
    for (i, row) in rows.iter().enumerate() {
        let loc = format!("rust/src/{}:{}", rel, i + 1);
        if contains_word(&row.code, "unsafe") {
            if !allowed_unsafe {
                errors.push(format!("R1 {loc}: `unsafe` outside the module allowlist"));
            }
            if !has_marker(&rows, i, "SAFETY:") {
                errors.push(format!("R1 {loc}: `unsafe` without an adjacent // SAFETY: comment"));
            }
        }
        let metrics_recv = rows[i.saturating_sub(2)..=i].iter().any(|r| r.code.contains("metrics"));
        if has_atomic_ordering(&row.code)
            && rel != "coordinator/metrics.rs"
            && !metrics_recv
            && !row.in_test
            && !has_marker(&rows, i, "ORDERING:")
        {
            errors.push(format!(
                "R2 {loc}: atomic Ordering:: without an adjacent // ORDERING: comment"
            ));
        }
        let takes_uninit = contains_word(&row.code, "take_f32_uninit")
            || contains_word(&row.code, "take_f64_uninit");
        if takes_uninit
            && rel != "linalg/workspace.rs"
            && !row.in_test
            && !has_marker(&rows, i, "OVERWRITE:")
        {
            errors.push(format!(
                "R5 {loc}: take_*_uninit without an adjacent // OVERWRITE: comment"
            ));
        }
    }
}

/// Column-0 `pub fn` names in `linalg/simd.rs`, minus the introspection
/// allowlist — the kernel surface the equivalence suite must cover.
fn extract_kernels(simd: &str) -> Vec<String> {
    simd.lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("pub fn ")?;
            let name: String = rest.chars().take_while(|c| is_word(*c)).collect();
            (!name.is_empty() && !KERNEL_ALLOWLIST.contains(&name.as_str())).then_some(name)
        })
        .collect()
}

/// R3: every kernel name must appear (word-boundary) in the equivalence
/// suite source.
fn lint_kernels(simd: &str, equiv: &str, errors: &mut Vec<String>) -> usize {
    let kernels = extract_kernels(simd);
    for k in &kernels {
        if !contains_word(equiv, k) {
            errors.push(format!(
                "R3 rust/src/linalg/simd.rs: public kernel `{k}` is not exercised by \
                 rust/tests/simd_equivalence.rs"
            ));
        }
    }
    kernels.len()
}

fn is_code_ident(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_lowercase() || c == '_')
}

/// `=> "code"` arms inside the `fn code(&self) -> &'static str` bodies of
/// coordinator/mod.rs.
fn extract_match_codes(coord: &str) -> Vec<String> {
    const HEAD: &str = "fn code(&self) -> &'static str {";
    let mut out = Vec::new();
    let mut rest = coord;
    while let Some(p) = rest.find(HEAD) {
        let body = &rest[p + HEAD.len()..];
        let end = body.find("\n    }").unwrap_or(body.len());
        for line in body[..end].lines() {
            if let Some(q) = line.find("=> \"") {
                if let Some(e) = line[q + 4..].find('"') {
                    let code = &line[q + 4..q + 4 + e];
                    if is_code_ident(code) {
                        out.push(code.to_string());
                    }
                }
            }
        }
        rest = &body[end..];
    }
    out
}

/// `const CODE_*: &str = "code";` declarations in coordinator/codec.rs
/// (and any stragglers in server.rs — `pub use` re-exports don't match).
fn extract_const_codes(server: &str) -> Vec<String> {
    const HEAD: &str = "const CODE_";
    const MID: &str = ": &str = \"";
    server
        .lines()
        .filter_map(|l| {
            let p = l.find(HEAD)?;
            let rest = &l[p + HEAD.len()..];
            let eq = rest.find(MID)?;
            let name = &rest[..eq];
            if !name.chars().all(|c| c.is_ascii_uppercase() || c == '_') {
                return None;
            }
            let tail = &rest[eq + MID.len()..];
            let code = &tail[..tail.find("\";")?];
            is_code_ident(code).then(|| code.to_string())
        })
        .collect()
}

/// `` | `code` | `` rows of ROADMAP.md's failure-model table.
fn extract_roadmap_codes(roadmap: &str) -> Vec<String> {
    roadmap
        .lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("| `")?;
            let code = &rest[..rest.find("` |")?];
            is_code_ident(code).then(|| code.to_string())
        })
        .collect()
}

fn dupes(v: &[String]) -> Vec<String> {
    let mut seen = std::collections::BTreeSet::new();
    let mut out = std::collections::BTreeSet::new();
    for c in v {
        if !seen.insert(c) {
            out.insert(c.clone());
        }
    }
    out.into_iter().collect()
}

/// R4: wire codes unique and exactly the ROADMAP table set.
fn lint_wire_codes(coord: &str, server: &str, roadmap: &str, errors: &mut Vec<String>) -> usize {
    let mut codes = extract_match_codes(coord);
    codes.extend(extract_const_codes(server));
    let d = dupes(&codes);
    if !d.is_empty() {
        errors.push(format!("R4 coordinator: duplicate wire codes: {d:?}"));
    }
    let table = extract_roadmap_codes(roadmap);
    let dt = dupes(&table);
    if !dt.is_empty() {
        errors.push("R4 ROADMAP.md: duplicate rows in the failure-model table".into());
    }
    let code_set: std::collections::BTreeSet<_> = codes.iter().collect();
    let table_set: std::collections::BTreeSet<_> = table.iter().collect();
    let missing: Vec<_> = code_set.difference(&table_set).collect();
    let stale: Vec<_> = table_set.difference(&code_set).collect();
    if !missing.is_empty() {
        errors
            .push(format!("R4 ROADMAP.md: failure-model table is missing wire codes {missing:?}"));
    }
    if !stale.is_empty() {
        errors.push(format!("R4 ROADMAP.md: failure-model table lists unknown codes {stale:?}"));
    }
    codes.len()
}

/// R6: the deny attribute that makes R1's per-operation rule sound.
fn lint_deny_attr(lib: &str, errors: &mut Vec<String>) {
    if !lib.contains("#![deny(unsafe_op_in_unsafe_fn)]") {
        errors.push("R6 rust/src/lib.rs: missing #![deny(unsafe_op_in_unsafe_fn)]".into());
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn read(root: &Path, rel: &str, errors: &mut Vec<String>) -> String {
    std::fs::read_to_string(root.join(rel)).unwrap_or_else(|e| {
        errors.push(format!("lint: cannot read {rel}: {e}"));
        String::new()
    })
}

/// Run every rule over the repo at `root`; returns (errors, kernel count,
/// wire-code count) for the summary line.
fn run_lint(root: &Path) -> (Vec<String>, usize, usize) {
    let mut errors = Vec::new();
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();
    if files.is_empty() {
        errors.push(format!("lint: no .rs files under {}", src.display()));
    }
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .expect("collected under src")
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(path) {
            Ok(text) => lint_annotations(&rel, &text, &mut errors),
            Err(e) => errors.push(format!("lint: cannot read rust/src/{rel}: {e}")),
        }
    }
    let simd = read(root, "rust/src/linalg/simd.rs", &mut errors);
    let equiv = read(root, "rust/tests/simd_equivalence.rs", &mut errors);
    let kernels = lint_kernels(&simd, &equiv, &mut errors);
    let coord = read(root, "rust/src/coordinator/mod.rs", &mut errors);
    // the codec split moved the CODE_* consts into codec.rs; scan both
    // files so a const in either is part of the taxonomy
    let server = read(root, "rust/src/coordinator/server.rs", &mut errors)
        + &read(root, "rust/src/coordinator/codec.rs", &mut errors);
    let roadmap = read(root, "ROADMAP.md", &mut errors);
    let codes = lint_wire_codes(&coord, &server, &roadmap, &mut errors);
    let lib = read(root, "rust/src/lib.rs", &mut errors);
    lint_deny_attr(&lib, &mut errors);
    (errors, kernels, codes)
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_default();
    if cmd != "lint" {
        eprintln!("usage: cargo xtask lint [repo-root]");
        return ExitCode::from(2);
    }
    let root = args.next().map(PathBuf::from).unwrap_or_else(|| {
        // the xtask manifest lives at <root>/xtask
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits one level under the repo root")
            .to_path_buf()
    });
    let (errors, kernels, codes) = run_lint(&root);
    for e in &errors {
        println!("{e}");
    }
    println!("xtask lint: {} violation(s), {kernels} kernels, {codes} wire codes", errors.len());
    if errors.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

// The linter is self-testing: every rule has at least one fixture it
// provably rejects and one it accepts, so a regression in the scanner
// (comment stripping, test-mod tracking, window math) fails `cargo test
// -p xtask` before it silently stops flagging real code.
#[cfg(test)]
mod tests {
    use super::*;

    fn annotate(rel: &str, text: &str) -> Vec<String> {
        let mut errors = Vec::new();
        lint_annotations(rel, text, &mut errors);
        errors
    }

    #[test]
    fn strip_separates_line_comments_and_blanks_strings() {
        let mut d = 0;
        let (code, comment) = strip_line(r#"let x = "unsafe // not"; // SAFETY: real"#, &mut d);
        assert!(!code.contains("unsafe"));
        assert!(comment.contains("SAFETY:"));
        assert_eq!(d, 0);
    }

    #[test]
    fn strip_handles_nested_block_comments() {
        let mut d = 0;
        let (code, _) = strip_line("a /* x /* y */ still comment", &mut d);
        assert_eq!(code.trim(), "a");
        assert_eq!(d, 1, "inner close leaves one open level");
        let (code, _) = strip_line("z */ b", &mut d);
        assert_eq!(code.trim(), "b");
        assert_eq!(d, 0);
    }

    #[test]
    fn strip_handles_char_literals_and_lifetimes() {
        let mut d = 0;
        let (code, comment) = strip_line(r"let q = '\''; let s: &'static str = f('/');", &mut d);
        assert!(comment.is_empty(), "quoted '/' must not open a comment: {comment}");
        assert!(code.contains("'static"));
    }

    #[test]
    fn r1_rejects_unmarked_unsafe_and_accepts_marked() {
        let bad = "pub fn f() {\n    unsafe { g() }\n}\n";
        let errs = annotate("linalg/simd.rs", bad);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("R1") && errs[0].contains("SAFETY"));

        let good = "pub fn f() {\n    // SAFETY: g has no preconditions.\n    unsafe { g() }\n}\n";
        assert!(annotate("linalg/simd.rs", good).is_empty());
    }

    #[test]
    fn r1_rejects_unsafe_outside_allowlist() {
        let text = "// SAFETY: marked, but the module is not allowlisted.\nunsafe { g() }\n";
        let errs = annotate("lsh/crosspolytope.rs", text);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("outside the module allowlist"));
        // directory allowlisting: anything under binary/ passes
        assert!(annotate("binary/mod.rs", text).is_empty());
    }

    #[test]
    fn r1_ignores_unsafe_in_comments_strings_and_substrings() {
        let text =
            "// unsafe in a comment is fine\nlet s = \"unsafe\";\n#![deny(unsafe_op_in_unsafe_fn)]\n";
        assert!(annotate("lsh/crosspolytope.rs", text).is_empty());
    }

    #[test]
    fn r1_marker_outside_window_is_rejected() {
        let filler = "    let x = 1;\n".repeat(WINDOW + 1);
        let text = format!("// SAFETY: too far away.\n{filler}    unsafe {{ g() }}\n");
        let errs = annotate("linalg/simd.rs", &text);
        assert_eq!(errs.len(), 1, "{errs:?}");
        let text = "// SAFETY: close enough.\n    let x = 1;\n    unsafe { g() }\n";
        assert!(annotate("linalg/simd.rs", text).is_empty());
    }

    #[test]
    fn r2_rejects_bare_atomic_ordering_and_accepts_marked() {
        let bad = "x.store(1, Ordering::Relaxed);\n";
        let errs = annotate("runtime/pool.rs", bad);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("R2"));
        let good =
            "// ORDERING: Relaxed — advisory flag.\nx.store(1, Ordering::Relaxed);\n";
        assert!(annotate("runtime/pool.rs", good).is_empty());
    }

    #[test]
    fn r2_exempts_metrics_counters_and_metrics_file() {
        // receiver chain through `metrics` on the site line
        let one_line = "lane.metrics.submitted.fetch_add(1, Ordering::Relaxed);\n";
        assert!(annotate("coordinator/mod.rs", one_line).is_empty());
        // rustfmt-split receiver chain: `metrics` two lines above the use
        let split = "self.metrics\n    .batched_rows\n    .fetch_add(n, Ordering::Relaxed);\n";
        assert!(annotate("coordinator/mod.rs", split).is_empty());
        // the metrics module itself is exempt wholesale
        assert!(annotate("coordinator/metrics.rs", "x.load(Ordering::Relaxed);\n").is_empty());
        // but a non-metrics receiver still trips
        assert_eq!(annotate("coordinator/mod.rs", "x.load(Ordering::Relaxed);\n").len(), 1);
    }

    #[test]
    fn r2_ignores_cmp_ordering_and_test_mods() {
        let cmp = "if a.cmp(&b) == Ordering::Less {\n}\n";
        assert!(annotate("runtime/pool.rs", cmp).is_empty());
        let test_mod =
            "#[cfg(test)]\nmod tests {\n    fn f() {\n        x.load(Ordering::SeqCst);\n    }\n}\n";
        assert!(annotate("runtime/pool.rs", test_mod).is_empty());
        // code after the test mod closes is checked again
        let after = format!("{test_mod}fn g() {{\n    x.load(Ordering::SeqCst);\n}}\n");
        assert_eq!(annotate("runtime/pool.rs", &after).len(), 1);
    }

    #[test]
    fn r5_rejects_unmarked_uninit_checkout() {
        let bad = "let y = ws.take_f32_uninit(n);\n";
        let errs = annotate("lsh/crosspolytope.rs", bad);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("R5"));
        let good = "let y = ws.take_f64_uninit(n); // OVERWRITE: fully overwritten below\n";
        assert!(annotate("lsh/crosspolytope.rs", good).is_empty());
        // the defining module is exempt (it self-tests the contract)
        assert!(annotate("linalg/workspace.rs", bad).is_empty());
    }

    #[test]
    fn r3_flags_uncovered_kernels() {
        let simd =
            "pub fn butterfly(x: &mut [f32]) {}\npub fn level() {}\n    pub fn indented() {}\n";
        let mut errors = Vec::new();
        let n = lint_kernels(simd, "calls butterfly here", &mut errors);
        assert_eq!(n, 1, "level is allowlisted, indented fn is not column-0 public API");
        assert!(errors.is_empty());
        let mut errors = Vec::new();
        lint_kernels(simd, "no mention at all", &mut errors);
        assert_eq!(errors.len(), 1);
        assert!(errors[0].contains("butterfly"));
        // substring mentions don't count: `butterfly4` is not `butterfly`
        let mut errors = Vec::new();
        lint_kernels(simd, "only butterfly4 is named", &mut errors);
        assert_eq!(errors.len(), 1, "{errors:?}");
    }

    const COORD_FIXTURE: &str = concat!(
        "impl RequestError {\n",
        "    fn code(&self) -> &'static str {\n",
        "        match self {\n",
        "            RequestError::Deadline => \"deadline\",\n",
        "            RequestError::Backend(_) => \"backend\",\n",
        "        }\n",
        "    }\n",
        "}\n",
    );
    const SERVER_FIXTURE: &str = "pub const CODE_TIMEOUT: &str = \"timeout\";\n";

    #[test]
    fn r4_accepts_exact_roadmap_match() {
        let roadmap = "| `deadline` | x |\n| `backend` | x |\n| `timeout` | x |\n";
        let mut errors = Vec::new();
        let n = lint_wire_codes(COORD_FIXTURE, SERVER_FIXTURE, roadmap, &mut errors);
        assert_eq!(n, 3);
        assert!(errors.is_empty(), "{errors:?}");
    }

    #[test]
    fn r4_rejects_missing_stale_and_duplicate_codes() {
        let mut errors = Vec::new();
        lint_wire_codes(COORD_FIXTURE, SERVER_FIXTURE, "| `deadline` | x |\n", &mut errors);
        assert!(errors.iter().any(|e| e.contains("missing wire codes")), "{errors:?}");
        let mut errors = Vec::new();
        let stale = "| `deadline` | x |\n| `backend` | x |\n| `timeout` | x |\n| `ghost` | x |\n";
        lint_wire_codes(COORD_FIXTURE, SERVER_FIXTURE, stale, &mut errors);
        assert!(errors.iter().any(|e| e.contains("unknown codes")), "{errors:?}");
        let mut errors = Vec::new();
        let dup_server = "pub const CODE_A: &str = \"deadline\";\n";
        let table = "| `deadline` | x |\n| `backend` | x |\n";
        lint_wire_codes(COORD_FIXTURE, dup_server, table, &mut errors);
        assert!(errors.iter().any(|e| e.contains("duplicate wire codes")), "{errors:?}");
    }

    #[test]
    fn r6_requires_the_deny_attribute() {
        let mut errors = Vec::new();
        lint_deny_attr("#![deny(unsafe_op_in_unsafe_fn)]\npub mod x;\n", &mut errors);
        assert!(errors.is_empty());
        lint_deny_attr("pub mod x;\n", &mut errors);
        assert_eq!(errors.len(), 1);
    }

    #[test]
    fn the_repo_itself_is_clean() {
        // the ultimate fixture: the live tree must pass its own linter
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
        let (errors, kernels, codes) = run_lint(root);
        assert!(errors.is_empty(), "{errors:#?}");
        assert!(kernels >= 14, "kernel surface shrank unexpectedly: {kernels}");
        assert!(codes >= 16, "wire-code taxonomy shrank unexpectedly: {codes}");
    }
}
