"""Layer-2 model tests: shapes, semantics, and AOT lowering consistency."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def rademacher(n, seed):
    rng = np.random.default_rng(seed)
    return rng.choice(np.float32([-1.0, 1.0]), size=n)


class TestModelOps:
    def test_transform_matches_ref(self):
        n, b = 64, 8
        rng = np.random.default_rng(1)
        x = rng.standard_normal((b, n)).astype(np.float32)
        d1, d2, d3 = (rademacher(n, i) for i in (1, 2, 3))
        got = np.asarray(model.transform(x, d1, d2, d3))
        want = np.asarray(ref.triplespin(x, d1, d2, d3))
        assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_crosspolytope_encoding(self):
        n, b = 64, 16
        rng = np.random.default_rng(2)
        x = rng.standard_normal((b, n)).astype(np.float32)
        d1, d2, d3 = (rademacher(n, i) for i in (4, 5, 6))
        ids = np.asarray(model.crosspolytope(x, d1, d2, d3))
        assert ids.shape == (b,)
        assert ids.dtype == np.int32
        assert (ids >= 0).all() and (ids < 2 * n).all()
        # manual check against the projection
        proj = np.asarray(ref.triplespin(x, d1, d2, d3))
        for i in range(b):
            j = int(np.argmax(np.abs(proj[i])))
            expect = j if proj[i, j] >= 0 else j + n
            assert ids[i] == expect

    def test_crosspolytope_negation_flips_sign(self):
        n = 32
        rng = np.random.default_rng(3)
        x = rng.standard_normal((4, n)).astype(np.float32)
        d1, d2, d3 = (rademacher(n, i) for i in (7, 8, 9))
        a = np.asarray(model.crosspolytope(x, d1, d2, d3))
        b = np.asarray(model.crosspolytope(-x, d1, d2, d3))
        assert ((a % n) == (b % n)).all()
        assert (a != b).all()

    def test_rff_shape(self):
        n, b = 64, 4
        rng = np.random.default_rng(4)
        x = rng.standard_normal((b, n)).astype(np.float32)
        d1, d2, d3 = (rademacher(n, i) for i in (1, 2, 3))
        out = np.asarray(model.rff(x, d1, d2, d3, np.float32([0.25])))
        assert out.shape == (b, 2 * n)


class TestAotLowering:
    def test_specs_cover_all_ops(self):
        for op in ("transform", "rff", "crosspolytope"):
            args, out, dtype = aot.specs_for(op, 64, 8)
            assert args[0].shape == (8, 64)
        with pytest.raises(ValueError):
            aot.specs_for("nope", 64, 8)

    def test_lower_and_manifest(self, tmp_path):
        entry = aot.lower_variant("transform", 64, 4, str(tmp_path))
        hlo = (tmp_path / entry["file"]).read_text()
        assert "HloModule" in hlo
        assert entry["inputs"] == [[4, 64], [64], [64], [64]]
        assert entry["output"] == [4, 64]

    def test_lowered_hlo_text_parses_back(self):
        # the text must parse back through XLA's HLO parser — the same
        # parser the Rust runtime uses (HloModuleProto::from_text_file).
        # Full text -> PJRT -> numerics round-trip is covered by the Rust
        # integration test against the golden vectors aot.py emits.
        from jax._src.lib import xla_client as xc

        n, b = 64, 4
        args, _, _ = aot.specs_for("transform", n, b)
        fn = aot.fn_for("transform")
        lowered = jax.jit(lambda *a: (fn(*a),)).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        comp = xc._xla.hlo_module_from_text(text)
        # parsing succeeded and the module round-trips to text
        assert "parameter(3)" in comp.to_string()

    def test_golden_vectors_match_ref(self, tmp_path):
        entry = aot.lower_variant("transform", 64, 4, str(tmp_path))
        golden = json.loads(
            (tmp_path / entry["golden"]).read_text())
        ins = [np.asarray(v, np.float32).reshape(s)
               for v, s in zip(golden["inputs"], entry["inputs"])]
        want = np.asarray(ref.triplespin(*ins))
        got = np.asarray(golden["output"], np.float32).reshape(
            entry["output"])
        assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_variant_table_is_sane(self):
        names = set()
        for op, n, batch in aot.VARIANTS:
            assert n & (n - 1) == 0
            assert batch >= 1
            name = f"{op}_n{n}_b{batch}"
            assert name not in names, f"duplicate variant {name}"
            names.add(name)
