"""Property-style sweeps over the Pallas kernels (hypothesis).

Complements test_kernel.py's allclose checks with structural invariants:
linearity, isometry, sign symmetries, padding behaviour — each a property
the TripleSpin math guarantees and the kernels must not break.
"""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import fwht as fwht_kernel
from compile.kernels import ref
from compile.kernels import triplespin as ts


def rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


def rademacher(n, seed):
    rng = np.random.default_rng(seed)
    return rng.choice(np.float32([-1.0, 1.0]), size=n)


class TestFwhtProperties:
    @given(n=st.sampled_from([4, 16, 64]), seed=st.integers(0, 2**31),
           alpha=st.floats(-3, 3, allow_nan=False))
    @settings(max_examples=15, deadline=None)
    def test_linearity(self, n, seed, alpha):
        x, y = rand((2, n), seed), rand((2, n), seed + 1)
        lhs = np.asarray(fwht_kernel.fwht(np.float32(alpha) * x + y))
        rhs = np.float32(alpha) * np.asarray(fwht_kernel.fwht(x)) + np.asarray(
            fwht_kernel.fwht(y))
        assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)

    @given(n=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2**31))
    @settings(max_examples=15, deadline=None)
    def test_isometry(self, n, seed):
        x = rand((3, n), seed)
        y = np.asarray(fwht_kernel.fwht(x))
        assert_allclose(np.linalg.norm(y, axis=1),
                        np.linalg.norm(x, axis=1), rtol=1e-4)

    def test_parseval_cross_terms(self):
        # <Hx, Hy> == <x, y> (full inner-product preservation)
        x, y = rand((1, 64), 1), rand((1, 64), 2)
        hx = np.asarray(fwht_kernel.fwht(x))
        hy = np.asarray(fwht_kernel.fwht(y))
        assert_allclose(hx @ hy.T, x @ y.T, rtol=1e-4)


class TestTripleSpinProperties:
    @given(n=st.sampled_from([16, 64]), seed=st.integers(0, 2**31))
    @settings(max_examples=15, deadline=None)
    def test_negation_antisymmetry(self, n, seed):
        x = rand((2, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        a = np.asarray(ts.triplespin(x, d1, d2, d3))
        b = np.asarray(ts.triplespin(-x, d1, d2, d3))
        assert_allclose(a, -b, rtol=1e-4, atol=1e-5)

    @given(n=st.sampled_from([16, 64, 256]), seed=st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_chain_is_orthogonal_times_sqrt_n(self, n, seed):
        # T/√n is orthogonal: ||Tx|| = √n ||x|| exactly for ±1 diags
        x = rand((2, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        y = np.asarray(ts.triplespin(x, d1, d2, d3))
        assert_allclose(np.linalg.norm(y, axis=1),
                        np.sqrt(n) * np.linalg.norm(x, axis=1), rtol=1e-4)

    def test_zero_input_zero_output(self):
        n = 32
        d = rademacher(n, 1)
        z = np.zeros((2, n), np.float32)
        assert not np.asarray(ts.triplespin(z, d, d, d)).any()


class TestCrossPolytopeProperties:
    @given(seed=st.integers(0, 2**31),
           scale=st.floats(0.1, 100.0, allow_nan=False))
    @settings(max_examples=15, deadline=None)
    def test_scale_invariance(self, seed, scale):
        n = 64
        x = rand((4, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        a = np.asarray(model.crosspolytope(x, d1, d2, d3))
        b = np.asarray(model.crosspolytope(np.float32(scale) * x, d1, d2, d3))
        assert (a == b).all()

    @given(seed=st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_bucket_range(self, seed):
        n = 32
        x = rand((8, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        ids = np.asarray(model.crosspolytope(x, d1, d2, d3))
        assert ((ids >= 0) & (ids < 2 * n)).all()


class TestRffProperties:
    @given(seed=st.integers(0, 2**31), sigma=st.floats(0.5, 10.0))
    @settings(max_examples=10, deadline=None)
    def test_features_bounded(self, seed, sigma):
        # |cos|,|sin| <= 1 -> each feature bounded by 1/sqrt(n)
        n = 64
        x = rand((3, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        phi = np.asarray(ts.rff_features(
            x, d1, d2, d3, np.float32([1.0 / sigma])))
        assert (np.abs(phi) <= 1.0 / np.sqrt(n) + 1e-6).all()

    def test_kernel_estimate_symmetric(self):
        n = 64
        x = rand((2, n), 3)
        d1, d2, d3 = (rademacher(n, i) for i in (4, 5, 6))
        phi = np.asarray(ts.rff_features(x, d1, d2, d3, np.float32([1.0])))
        kxy = float(phi[0] @ phi[1])
        kyx = float(phi[1] @ phi[0])
        assert abs(kxy - kyx) < 1e-7

    def test_distant_points_low_kernel(self):
        n = 256
        rng = np.random.default_rng(0)
        x = rng.standard_normal(n).astype(np.float32) * 10
        y = -x
        d1, d2, d3 = (rademacher(n, i) for i in (1, 2, 3))
        batch = np.stack([x, y])
        phi = np.asarray(ts.rff_features(batch, d1, d2, d3,
                                         np.float32([1.0])))
        assert abs(float(phi[0] @ phi[1])) < 0.1
