"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fwht as fwht_kernel
from compile.kernels import ref
from compile.kernels import triplespin as ts


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


def rademacher(n, seed):
    rng = np.random.default_rng(seed)
    return rng.choice(np.float32([-1.0, 1.0]), size=n)


# powers of two the kernels must handle; 1-2 exercise degenerate factors
POW2 = [2, 4, 8, 16, 64, 128, 256]


class TestFwhtKernel:
    @given(
        n=st.sampled_from(POW2),
        batch=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, n, batch, seed):
        x = rand((batch, n), seed)
        got = np.asarray(fwht_kernel.fwht(x))
        want = np.asarray(ref.fwht(x))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_involution(self):
        x = rand((4, 64), 1)
        y = np.asarray(fwht_kernel.fwht(np.asarray(fwht_kernel.fwht(x))))
        assert_allclose(y, x, rtol=1e-4, atol=1e-5)

    def test_batch_tiling_boundary(self):
        # batch not divisible by the tile: padding must not leak
        x = rand((5, 32), 2)
        got = np.asarray(fwht_kernel.fwht(x, block_batch=4))
        want = np.asarray(ref.fwht(x))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_norm_preservation(self):
        x = rand((3, 128), 3)
        y = np.asarray(fwht_kernel.fwht(x))
        assert_allclose(
            np.linalg.norm(y, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
        )

    def test_factor_split(self):
        assert fwht_kernel._factor(4096) == (64, 64)
        assert fwht_kernel._factor(256) == (16, 16)
        assert fwht_kernel._factor(128) == (16, 8)
        assert fwht_kernel._factor(2) == (2, 1)
        assert fwht_kernel._factor(1) == (1, 1)


class TestTripleSpinKernel:
    @given(
        n=st.sampled_from([4, 16, 64, 256]),
        batch=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    @settings(max_examples=20, deadline=None)
    def test_matches_ref(self, n, batch, seed):
        x = rand((batch, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        got = np.asarray(ts.triplespin(x, d1, d2, d3))
        want = np.asarray(ref.triplespin(x, d1, d2, d3))
        assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_norm_scaling(self):
        # isometric chain scaled by sqrt(n): unit rows -> norm sqrt(n)
        n = 64
        x = rand((4, n), 5)
        x /= np.linalg.norm(x, axis=1, keepdims=True)
        d1, d2, d3 = (rademacher(n, i) for i in (7, 8, 9))
        y = np.asarray(ts.triplespin(x, d1, d2, d3))
        assert_allclose(np.linalg.norm(y, axis=1), np.sqrt(n), rtol=1e-4)

    def test_gaussian_diag_also_works(self):
        # HDg variant: the kernel doesn't care about the diag distribution
        n = 32
        x = rand((2, n), 6)
        d1, d2 = rademacher(n, 1), rademacher(n, 2)
        dg = rand(n, 3)
        got = np.asarray(ts.triplespin(x, d1, d2, dg))
        want = np.asarray(ref.triplespin(x, d1, d2, dg))
        assert_allclose(got, want, rtol=1e-3, atol=1e-4)


class TestRffKernel:
    @given(
        n=st.sampled_from([16, 64, 256]),
        batch=st.integers(min_value=1, max_value=5),
        seed=st.integers(min_value=0, max_value=2**31),
        sigma=st.floats(min_value=0.3, max_value=20.0),
    )
    @settings(max_examples=15, deadline=None)
    def test_matches_ref(self, n, batch, seed, sigma):
        x = rand((batch, n), seed)
        d1, d2, d3 = (rademacher(n, seed + i) for i in (1, 2, 3))
        inv = np.float32([1.0 / sigma])
        got = np.asarray(ts.rff_features(x, d1, d2, d3, inv))
        want = np.asarray(ref.rff_features(x, d1, d2, d3, inv))
        assert got.shape == (batch, 2 * n)
        assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_self_kernel_is_one(self):
        # phi(x)·phi(x) = mean(cos²+sin²) = 1 exactly
        n = 64
        x = rand((3, n), 11)
        d1, d2, d3 = (rademacher(n, i) for i in (4, 5, 6))
        phi = np.asarray(ts.rff_features(x, d1, d2, d3, np.float32([0.5])))
        assert_allclose((phi * phi).sum(axis=1), 1.0, rtol=1e-5)

    def test_kernel_estimate_close_to_exact(self):
        # dot of feature maps ≈ Gaussian kernel, averaged over diag draws
        n = 256
        sigma = 1.0
        rng = np.random.default_rng(0)
        x = rng.standard_normal(n).astype(np.float32)
        x /= np.linalg.norm(x)
        y = 0.8 * x + 0.2 * rng.standard_normal(n).astype(np.float32) / np.sqrt(n)
        y /= np.linalg.norm(y)
        exact = np.exp(-np.linalg.norm(x - y) ** 2 / (2 * sigma**2))
        ests = []
        for s in range(6):
            d1, d2, d3 = (rademacher(n, 100 + 3 * s + i) for i in range(3))
            batch = np.stack([x, y])
            phi = np.asarray(
                ts.rff_features(batch, d1, d2, d3, np.float32([1.0 / sigma]))
            )
            ests.append(float(phi[0] @ phi[1]))
        est = np.mean(ests)
        assert abs(est - exact) < 0.05, f"{est} vs {exact}"
