"""Layer-1 Pallas kernel: two-level (Kronecker-factored) FWHT.

TPU adaptation of the Hadamard transform (DESIGN.md §Hardware-Adaptation):
instead of the `log n` butterfly rounds a CPU/GPU implementation uses
(pointer-chasing, bad for the MXU), factor `H_n = H_a ⊗ H_b` for `n = a·b`
and compute

    Y = H_a · X · H_b        (X = row-reshaped (a, b) view of x)

i.e. **two small dense matmuls** against Hadamard factors that live in VMEM.
For n = 4096, a = b = 64: both factors are 64×64 — exactly one MXU tile —
and a (batch_tile, n) f32 block plus factors fit comfortably in VMEM
(batch_tile=128: 128·4096·4 B = 2 MiB stream + 32 KiB factors).

Pallas runs with ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot execute Mosaic custom-calls. Real-TPU performance is estimated
from the BlockSpec footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _factor(n: int) -> tuple[int, int]:
    """Split n = a*b with a, b powers of two, as square as possible."""
    assert n & (n - 1) == 0 and n > 0
    log = n.bit_length() - 1
    a = 1 << ((log + 1) // 2)
    return a, n // a


def _fwht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int,
                 scale: float):
    """One batch-tile: reshape rows to (a, b), multiply by both factors."""
    bt = x_ref.shape[0]
    x = x_ref[...].reshape(bt, a, b)
    ha = ha_ref[...]
    hb = hb_ref[...]
    # Y = Ha @ X @ Hb  (Hb symmetric, so right-multiplying by Hb == Hb^T)
    y = jax.lax.dot_general(x, hb, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(ha, y, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # dot_general(ha, y): contracting ha dim 1 with y dim 1 (the 'a' axis)
    # -> result (a, bt, b); transpose back to (bt, a, b).
    y = y.transpose(1, 0, 2)
    o_ref[...] = (y * scale).reshape(bt, a * b)


def fwht(x: jnp.ndarray, *, block_batch: int = 128,
         interpret: bool = True) -> jnp.ndarray:
    """Normalized FWHT over the last axis of ``x (batch, n)`` via Pallas.

    Matches ``ref.fwht`` to f32 round-off.
    """
    batch, n = x.shape
    a, b = _factor(n)
    ha = jnp.asarray(ref.hadamard_matrix(a))
    hb = jnp.asarray(ref.hadamard_matrix(b))
    scale = float(1.0 / (n ** 0.5))
    bt = min(block_batch, batch)
    # pad batch to a multiple of the tile
    pad = (-batch) % bt
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, n), x.dtype)], axis=0)
    grid = (x.shape[0] // bt,)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, a=a, b=b, scale=scale),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        interpret=interpret,
    )(x, ha, hb)
    return out[:batch] if pad else out
