"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is deliberately simple and dense — `O(n^2)` Hadamard
matmuls — so the Pallas kernels (and the Rust native path, transitively via
the AOT artifacts) have an unambiguous reference.
"""

import jax.numpy as jnp
import numpy as np


def hadamard_matrix(n: int) -> np.ndarray:
    """Unnormalized Sylvester Hadamard matrix (entries ±1), n a power of 2."""
    assert n & (n - 1) == 0 and n > 0, f"n={n} must be a power of two"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h.astype(np.float32)


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform of the last axis (dense matmul).

    ``y = x @ H`` with ``H = H_sylvester / sqrt(n)`` (H is symmetric, so
    left/right application coincide for vectors).
    """
    n = x.shape[-1]
    h = jnp.asarray(hadamard_matrix(n)) / jnp.sqrt(n).astype(jnp.float32)
    return x @ h


def triplespin(x: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
               d3: jnp.ndarray) -> jnp.ndarray:
    """``sqrt(n) * H D3 H D2 H D1 x`` per row of the batch ``x (b, n)``.

    The paper's flagship discrete chain, with L2-normalized ``H`` and the
    ``sqrt(n)`` scaling that makes rows act like N(0,1) directions.
    """
    n = x.shape[-1]
    y = fwht(x * d1)
    y = fwht(y * d2)
    y = fwht(y * d3)
    return y * jnp.sqrt(n).astype(jnp.float32)


def rff_features(x: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                 d3: jnp.ndarray, inv_sigma: jnp.ndarray) -> jnp.ndarray:
    """Gaussian-kernel random Fourier features from the TripleSpin projection.

    ``phi(x) = [cos(Tx/sigma); sin(Tx/sigma)] / sqrt(n)`` — output ``(b, 2n)``.
    """
    n = x.shape[-1]
    proj = triplespin(x, d1, d2, d3) * inv_sigma
    scale = (1.0 / jnp.sqrt(n)).astype(jnp.float32)
    return jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1) * scale
