"""Layer-1 Pallas kernel: fused TripleSpin chain and RFF feature map.

One kernel invocation computes the full `sqrt(n) * H D3 H D2 H D1 x` chain
for a batch tile — the three diagonal scalings are elementwise VPU ops fused
between the Kronecker-factored Hadamard matmuls, so the tile never leaves
VMEM between spins (on real TPU; under ``interpret=True`` this structure is
still what gets lowered to HLO and what the Rust runtime executes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .fwht import _factor


def _hadamard_pair(y, ha, hb, a: int, b: int):
    """(bt, n) -> unnormalized FWHT via Y = Ha @ X @ Hb on (a, b) reshapes."""
    bt = y.shape[0]
    x = y.reshape(bt, a, b)
    t = jax.lax.dot_general(x, hb, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    t = jax.lax.dot_general(ha, t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return t.transpose(1, 0, 2).reshape(bt, a * b)


def _chain_kernel(x_ref, d1_ref, d2_ref, d3_ref, ha_ref, hb_ref, o_ref, *,
                  a: int, b: int, scale: float):
    ha = ha_ref[...]
    hb = hb_ref[...]
    y = x_ref[...] * d1_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    y = y * d2_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    y = y * d3_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    o_ref[...] = y * scale


def _rff_kernel(x_ref, d1_ref, d2_ref, d3_ref, inv_sigma_ref, ha_ref, hb_ref,
                o_ref, *, a: int, b: int, scale: float, feat_scale: float):
    ha = ha_ref[...]
    hb = hb_ref[...]
    y = x_ref[...] * d1_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    y = y * d2_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    y = y * d3_ref[...]
    y = _hadamard_pair(y, ha, hb, a, b)
    proj = y * (scale * inv_sigma_ref[0])
    o_ref[...] = jnp.concatenate(
        [jnp.cos(proj), jnp.sin(proj)], axis=-1) * feat_scale


def _padded(x, bt):
    batch = x.shape[0]
    pad = (-batch) % bt
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, pad


def triplespin(x, d1, d2, d3, *, block_batch: int = 128,
               interpret: bool = True):
    """Fused ``sqrt(n) * H D3 H D2 H D1 x`` over a batch; matches
    ``ref.triplespin``."""
    batch, n = x.shape
    a, b = _factor(n)
    ha = jnp.asarray(ref.hadamard_matrix(a))
    hb = jnp.asarray(ref.hadamard_matrix(b))
    # 3 unnormalized FWHTs contribute n^{3/2}; target scaling is sqrt(n)/n^{3/2}... :
    # normalized chain = n^{-3/2} * unnormalized; final factor sqrt(n).
    scale = float(n ** 0.5 / n ** 1.5)
    bt = min(block_batch, batch)
    x, pad = _padded(x, bt)
    grid = (x.shape[0] // bt,)
    vec = lambda i: (0,)  # noqa: E731 — diagonals broadcast to every tile
    out = pl.pallas_call(
        functools.partial(_chain_kernel, a=a, b=b, scale=scale),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        interpret=interpret,
    )(x, d1, d2, d3, ha, hb)
    return out[:batch] if pad else out


def rff_features(x, d1, d2, d3, inv_sigma, *, block_batch: int = 128,
                 interpret: bool = True):
    """Fused TripleSpin projection + cos/sin featurization; matches
    ``ref.rff_features``. ``inv_sigma`` is a shape-(1,) f32 array."""
    batch, n = x.shape
    a, b = _factor(n)
    ha = jnp.asarray(ref.hadamard_matrix(a))
    hb = jnp.asarray(ref.hadamard_matrix(b))
    scale = float(n ** 0.5 / n ** 1.5)
    feat_scale = float(1.0 / (n ** 0.5))
    bt = min(block_batch, batch)
    x, pad = _padded(x, bt)
    grid = (x.shape[0] // bt,)
    vec = lambda i: (0,)  # noqa: E731
    out = pl.pallas_call(
        functools.partial(_rff_kernel, a=a, b=b, scale=scale,
                          feat_scale=feat_scale),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 2 * n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((n,), vec),
            pl.BlockSpec((1,), vec),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 2 * n), lambda i: (i, 0)),
        interpret=interpret,
    )(x, d1, d2, d3, inv_sigma, ha, hb)
    return out[:batch] if pad else out
