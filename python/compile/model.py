"""Layer-2 JAX model: the TripleSpin compute graph served by the Rust side.

Build-time only — these functions are jitted, lowered to HLO text by
``aot.py``, and executed from Rust via PJRT. They call the Layer-1 Pallas
kernels (``kernels.triplespin``) so the fused chain lowers into the same
HLO module.

Operations exported:
  * ``transform``      — ``sqrt(n)·HD3 HD2 HD1 x``  (b, n) -> (b, n)
  * ``rff``            — Gaussian-kernel RFF map    (b, n) -> (b, 2n)
  * ``crosspolytope``  — LSH hash bucket ids        (b, n) -> (b,) int32
"""

import jax.numpy as jnp

from .kernels import triplespin as ts_kernels


def transform(x, d1, d2, d3):
    """The flagship discrete chain, batched."""
    return ts_kernels.triplespin(x, d1, d2, d3)


def rff(x, d1, d2, d3, inv_sigma):
    """Random Fourier features for the Gaussian kernel (paper §4)."""
    return ts_kernels.rff_features(x, d1, d2, d3, inv_sigma)


def crosspolytope(x, d1, d2, d3):
    """Cross-polytope hash ids (paper §2): ``argmax |Tx|`` with sign.

    Returns int32 bucket ids in ``[0, 2n)``: ``i`` for ``+e_i``, ``i + n``
    for ``-e_i`` — the same encoding the Rust LSH module uses.
    """
    n = x.shape[-1]
    y = ts_kernels.triplespin(x, d1, d2, d3)
    idx = jnp.argmax(jnp.abs(y), axis=-1)
    vals = jnp.take_along_axis(y, idx[:, None], axis=-1)[:, 0]
    return jnp.where(vals >= 0, idx, idx + n).astype(jnp.int32)
