"""AOT lowering: JAX/Pallas model -> HLO text artifacts + manifest.

Run once at ``make artifacts``; Python never runs on the request path.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each (op, n, batch) variant becomes ``artifacts/<name>.hlo.txt``; the
manifest (``artifacts/manifest.json``) records the parameter shapes so the
Rust runtime can validate inputs before execution.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# (op, n, batch) variants to export. n must be a power of two; batches
# match the coordinator's lane sizes.
VARIANTS = [
    ("transform", 64, 1), ("transform", 64, 16),
    ("transform", 256, 1), ("transform", 256, 16), ("transform", 256, 64),
    ("transform", 1024, 16),
    ("rff", 64, 16),
    ("rff", 256, 1), ("rff", 256, 16), ("rff", 256, 64),
    ("crosspolytope", 64, 16),
    ("crosspolytope", 256, 16), ("crosspolytope", 256, 64),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as "{...}",
    # which the XLA text parser silently turns into zeros — the in-VMEM
    # Hadamard factors of the Pallas kernels would vanish. Print in full.
    import jaxlib._jax as jx

    opts = jx.HloPrintOptions()
    opts.print_large_constants = True
    # the pinned XLA 0.5.1 text parser predates source_end_line/column
    # metadata attributes — strip metadata entirely.
    opts.print_metadata = False
    module = jx.HloModule.from_serialized_hlo_module_proto(
        comp.as_serialized_hlo_module_proto()
    )
    text = module.to_string(opts)
    assert "{...}" not in text, "HLO printer still eliding constants"
    return text


def specs_for(op: str, n: int, batch: int):
    """(example arg specs, output shape) for one variant."""
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((batch, n), f32)
    d = jax.ShapeDtypeStruct((n,), f32)
    if op == "transform":
        return (x, d, d, d), (batch, n), "f32"
    if op == "rff":
        s = jax.ShapeDtypeStruct((1,), f32)
        return (x, d, d, d, s), (batch, 2 * n), "f32"
    if op == "crosspolytope":
        return (x, d, d, d), (batch,), "i32"
    raise ValueError(f"unknown op {op}")


def fn_for(op: str):
    return {"transform": model.transform, "rff": model.rff,
            "crosspolytope": model.crosspolytope}[op]


def example_inputs(op: str, n: int, batch: int, seed: int = 7):
    """Deterministic example inputs for golden-vector generation."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, n)).astype(np.float32)
    diags = [rng.choice(np.float32([-1.0, 1.0]), size=n) for _ in range(3)]
    ins = [x] + diags
    if op == "rff":
        ins.append(np.float32([0.5]))  # inv_sigma
    return ins


def lower_variant(op: str, n: int, batch: int, out_dir: str,
                  golden: bool = True) -> dict:
    args, out_shape, out_dtype = specs_for(op, n, batch)
    # wrap so the HLO root is a tuple (rust side unwraps with to_tuple1)
    fn = fn_for(op)
    jitted = jax.jit(lambda *a: (fn(*a),))
    lowered = jitted.lower(*args)
    text = to_hlo_text(lowered)
    name = f"{op}_n{n}_b{batch}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "op": op,
        "n": n,
        "batch": batch,
        "file": f"{name}.hlo.txt",
        "inputs": [list(a.shape) for a in args],
        "output": list(out_shape),
        "output_dtype": out_dtype,
    }
    # golden input/output vectors: the Rust integration test executes the
    # artifact via PJRT and compares against these (cross-language check).
    # Skip the largest batches to keep artifacts small.
    if golden and batch <= 16:
        ins = example_inputs(op, n, batch)
        out = np.asarray(jitted(*ins)[0])
        gname = f"{name}.golden.json"
        with open(os.path.join(out_dir, gname), "w") as f:
            json.dump(
                {
                    "inputs": [i.reshape(-1).tolist() for i in ins],
                    "output": out.reshape(-1).tolist(),
                },
                f,
            )
        entry["golden"] = gname
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated op filter (e.g. 'transform,rff')")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    entries = []
    for op, n, batch in VARIANTS:
        if only and op not in only:
            continue
        entry = lower_variant(op, n, batch, args.out_dir)
        entries.append(entry)
        print(f"lowered {entry['name']} -> {entry['file']}")

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
