//! Quickstart: build TripleSpin transforms, compare them to the dense
//! Gaussian baseline on speed, storage and statistical behaviour.
//!
//!     cargo run --release --example quickstart

use std::time::Instant;
use triplespin::kernels::{exact, FeatureKind, FeatureMap};
use triplespin::linalg::vecops::norm2;
use triplespin::transform::{make, make_square, Family};
use triplespin::util::rng::Rng;

fn main() {
    let n = 1024;
    println!("== TripleSpin quickstart (n = {n}) ==\n");

    // 1. Construct one member of each family and apply it to a unit vector.
    let mut rng = Rng::new(42);
    let x = rng.unit_vec(n);
    println!(
        "{:<22} {:>14} {:>12} {:>10}",
        "family", "storage(bits)", "apply time", "||y||/√n"
    );
    for fam in [
        Family::Dense,
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
        Family::SkewCirculant,
    ] {
        let t = make_square(fam, n, &mut Rng::new(1));
        let start = Instant::now();
        let reps = 20;
        let mut y = Vec::new();
        for _ in 0..reps {
            y = t.apply(&x);
        }
        let dt = start.elapsed() / reps;
        println!(
            "{:<22} {:>14} {:>12} {:>10.4}",
            fam.label(),
            t.param_bits(),
            format!("{dt:?}"),
            norm2(&y) / (n as f64).sqrt()
        );
    }

    // 2. Kernel approximation: the structured map matches the exact kernel.
    println!("\n== Gaussian-kernel estimate vs exact (σ = 1.0) ==");
    let mut rng = Rng::new(7);
    let a = rng.unit_vec(n);
    let mut b = a.clone();
    for (i, v) in b.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v += 0.02;
        }
    }
    triplespin::linalg::vecops::normalize(&mut b);
    let exact_val = exact::gaussian(&a, &b, 1.0);
    println!("exact κ(x,y)          = {exact_val:.4}");
    for fam in [Family::Dense, Family::Hd3] {
        let mut est = 0.0;
        let runs = 5;
        for s in 0..runs {
            let t = make(fam, 2048, n, n, &mut Rng::new(100 + s));
            let fm = FeatureMap::new(t, FeatureKind::GaussianRff, 1.0);
            est += fm.approx_kernel(&a, &b);
        }
        println!("{:<22}≈ {:.4}", fam.label(), est / runs as f64);
    }

    println!("\nThe discrete HD3HD2HD1 chain stores only 3n bits — a {}x\ncompression over the dense matrix — with matching accuracy.",
        (n * n * 32) / (3 * n));
}
