//! Random-feature kernel approximation on the USPST-like dataset — a
//! miniature of the paper's Figure 2 experiment.
//!
//! Prints Gram-matrix reconstruction error vs feature count for the
//! Gaussian and angular kernels, per transform family.
//!
//!     cargo run --release --example kernel_approx

use triplespin::data::uspst;
use triplespin::kernels::{exact, gram, FeatureKind, FeatureMap};
use triplespin::transform::{make, Family};
use triplespin::util::rng::Rng;

fn main() {
    let points = uspst::dataset_n(300, 3);
    let n = uspst::DIM; // 256
    let sigma = exact::median_bandwidth(&points, 150);
    println!("== Gram reconstruction, {} digit images, σ = {sigma:.3} ==\n", points.len());

    for (kernel_name, kind) in [
        ("Gaussian kernel", FeatureKind::GaussianRff),
        ("angular kernel", FeatureKind::Angular),
    ] {
        let k_exact = match kind {
            FeatureKind::GaussianRff => exact::gram(&points, |a, b| exact::gaussian(a, b, sigma)),
            _ => exact::gram(&points, exact::angular),
        };
        println!("--- {kernel_name} ---");
        print!("{:<22}", "family \\ features");
        let feature_counts = [32usize, 128, 512];
        for f in feature_counts {
            print!(" {f:>8}");
        }
        println!();
        for fam in [
            Family::Dense,
            Family::Toeplitz,
            Family::SkewCirculant,
            Family::Hdg,
            Family::Hd3,
        ] {
            print!("{:<22}", fam.label());
            for feats in feature_counts {
                let mut err = 0.0;
                let runs = 3;
                for s in 0..runs {
                    let t = make(fam, feats, n, n, &mut Rng::new(10 + s));
                    let fm = FeatureMap::new(t, kind, sigma);
                    err += gram::reconstruction_error(&fm, &points, &k_exact);
                }
                print!(" {:>8.4}", err / runs as f64);
            }
            println!();
        }
        println!();
    }
    println!("All TripleSpin rows track the dense-Gaussian error curve (Figure 2's finding).");
}
