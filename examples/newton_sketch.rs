//! Newton sketch for logistic regression — the paper's §6.3 / Figure 3.
//!
//! Generates the AR(1)-correlated design matrix, runs exact Newton and
//! several sketched variants, and prints the optimality-gap traces.
//!
//!     cargo run --release --example newton_sketch

use triplespin::data::logistic;
use triplespin::sketch::{newton_solve, NewtonOptions, SketchKind};
use triplespin::transform::Family;

fn main() {
    let (n, d) = (2048usize, 32usize);
    println!("== Newton sketch: logistic regression, n={n} observations, d={d} ==\n");
    let p = logistic::generate(n, d, 0.99, 1);

    // f* from a long exact run
    let exact = newton_solve(
        &p,
        SketchKind::Exact,
        NewtonOptions {
            max_iters: 60,
            ..Default::default()
        },
    );
    let f_star = *exact.values.last().unwrap();
    println!("f* = {f_star:.6} (exact Newton, {} iterations)\n", exact.values.len() - 1);

    let m = 8 * d; // sketch dimension
    let kinds = [
        SketchKind::Exact,
        SketchKind::Gaussian,
        SketchKind::Struct(Family::Hd3),
        SketchKind::Struct(Family::Hdg),
        SketchKind::Struct(Family::Toeplitz),
    ];
    println!("optimality gap f(x_t) - f*   (sketch m = {m})");
    print!("{:<26}", "iteration");
    for it in [1usize, 2, 4, 8, 12, 16] {
        print!(" {it:>9}");
    }
    println!();
    for kind in kinds {
        let trace = newton_solve(
            &p,
            kind,
            NewtonOptions {
                sketch_rows: m,
                max_iters: 16,
                ..Default::default()
            },
        );
        let gaps = trace.gaps(f_star);
        print!("{:<26}", kind.label());
        for it in [1usize, 2, 4, 8, 12, 16] {
            if it < gaps.len() {
                print!(" {:>9.2e}", gaps[it]);
            } else {
                print!(" {:>9}", "-");
            }
        }
        println!();
    }
    println!(
        "\nSketched runs converge a constant factor slower than exact Newton but every\n\
         TripleSpin sketch tracks the Gaussian sketch — Figure 3 (left)'s finding."
    );
}
