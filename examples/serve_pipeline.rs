//! End-to-end serving driver — proves all three layers compose.
//!
//! Loads the AOT artifacts (Layer 1 Pallas kernels lowered through the
//! Layer 2 JAX model into HLO text), starts the PJRT runtime thread and the
//! Layer 3 coordinator on top of it, then drives a mixed batched workload
//! (transform / RFF feature maps / LSH hashes) from several client threads,
//! reporting throughput and latency percentiles per lane. A native-backend
//! pass runs the same workload for comparison, and cross-checks numerics
//! between the two backends.
//!
//!     make artifacts && cargo run --release --example serve_pipeline

use std::sync::Arc;
use std::time::{Duration, Instant};

use triplespin::coordinator::{Backend, Config, Coordinator, NativeBackend, PjrtBackend};
use triplespin::runtime::{Op, RuntimeService};
use triplespin::util::rng::Rng;

const N: usize = 256;
const REQUESTS_PER_CLIENT: usize = 400;
const CLIENTS: usize = 3;

fn drive(c: &Arc<Coordinator>, label: &str) {
    let ops = [Op::Transform, Op::Rff, Op::CrossPolytope];
    let start = Instant::now();
    let mut handles = Vec::new();
    for t in 0..CLIENTS as u64 {
        let cc = Arc::clone(c);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            let mut done = 0usize;
            while done < REQUESTS_PER_CLIENT {
                let op = ops[(done + t as usize) % ops.len()];
                match cc.submit(op, rng.gaussian_vec(N)) {
                    Ok((_, rx)) => {
                        rx.recv().expect("response").result.expect("ok");
                        done += 1;
                    }
                    Err(triplespin::coordinator::SubmitError::Busy) => {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    Err(e) => panic!("{e}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = start.elapsed();
    let total = CLIENTS * REQUESTS_PER_CLIENT;
    println!(
        "\n[{label}] {total} requests from {CLIENTS} clients in {dt:?} -> {:.0} req/s",
        total as f64 / dt.as_secs_f64()
    );
    for ((op, n), m) in c.metrics() {
        println!(
            "  lane {op:>14}/n={n}: completed {:>5}  mean batch {:>5.1}  p50 {:>7} µs  p95 {:>7} µs",
            m.completed.load(std::sync::atomic::Ordering::Relaxed),
            m.mean_batch_size(),
            m.latency.percentile_us(0.50),
            m.latency.percentile_us(0.95),
        );
    }
}

fn main() {
    let (sigma, seed) = (1.0, 42);
    let lanes = vec![(Op::Transform, N), (Op::Rff, N), (Op::CrossPolytope, N)];
    let config = Config {
        lanes: lanes.clone(),
        max_batch: 64,
        max_wait: Duration::from_micros(200),
        queue_cap: 512,
        sigma,
        seed,
        ..Config::default()
    };

    // --- three-layer path: Pallas/JAX artifacts via PJRT ---
    println!("loading artifacts + compiling via PJRT ...");
    let svc = RuntimeService::spawn("artifacts".into())
        .expect("run `make artifacts` first");
    let pjrt: Arc<dyn Backend> =
        Arc::new(PjrtBackend::new(svc.handle(), &[N], sigma, seed).expect("backend"));
    let c = Arc::new(Coordinator::start(config.clone(), pjrt));
    drive(&c, "pjrt (L1 Pallas -> L2 JAX -> HLO -> PJRT)");

    // numeric cross-check against the native backend
    let native = NativeBackend::new(&[N], sigma, seed);
    let mut rng = Rng::new(77);
    let v = rng.gaussian_vec(N);
    let via_coord = c.call(Op::Transform, v.clone()).expect("call");
    let via_native = native.run_batch(Op::Transform, N, 1, &v).expect("native");
    let max_err = via_coord
        .as_f32()
        .unwrap()
        .iter()
        .zip(via_native.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\ncross-check pjrt vs native transform: max |err| = {max_err:.3e}");
    assert!(max_err < 1e-2, "backends disagree!");

    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }
    svc.shutdown();

    // --- native hot path, same workload ---
    let native: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[N], sigma, seed));
    let c2 = Arc::new(Coordinator::start(config, native));
    drive(&c2, "native (pure-Rust FWHT hot path)");
    if let Ok(c2) = Arc::try_unwrap(c2) {
        c2.shutdown();
    }

    println!("\nAll layers compose: python built the kernels once; the request path is Rust-only.");
}
