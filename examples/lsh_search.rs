//! Approximate nearest-neighbor search with cross-polytope LSH — the
//! application motivating the paper's Figure 1 and Theorem 5.3.
//!
//! Indexes the USPST-like digit dataset with structured (`HD3HD2HD1`)
//! hashes, runs queries, and reports recall and candidate-set sizes against
//! exact brute force.
//!
//!     cargo run --release --example lsh_search

use std::time::Instant;
use triplespin::data::uspst;
use triplespin::linalg::vecops::normalize;
use triplespin::lsh::LshIndex;
use triplespin::transform::Family;
use triplespin::util::rng::Rng;

fn main() {
    let count = 1200;
    let n = uspst::DIM; // 256
    println!("== cross-polytope LSH search over {count} digit images (n = {n}) ==\n");
    let points = uspst::dataset_n(count, 1);

    for (family, tables) in [
        (Family::Hd3, 8),
        (Family::Hd3, 16),
        (Family::Dense, 16),
    ] {
        let t0 = Instant::now();
        let idx = LshIndex::build(points.clone(), family, n, tables, 1, 99);
        let build = t0.elapsed();

        // queries: perturbed dataset points (so ground truth is nontrivial)
        let mut rng = Rng::new(5);
        let trials = 100;
        let mut hit = 0usize;
        let mut cand_total = 0usize;
        let t1 = Instant::now();
        for _ in 0..trials {
            let qi = rng.below(points.len() as u64) as usize;
            let mut q = points[qi].clone();
            for v in q.iter_mut() {
                *v += 0.02 * rng.gaussian_f32();
            }
            normalize(&mut q);
            let truth = idx.brute_force(&q, 1)[0].0;
            let cands = idx.candidates(&q);
            cand_total += cands.len();
            if idx.query(&q, 1).first().map(|r| r.0) == Some(truth) {
                hit += 1;
            }
        }
        let qt = t1.elapsed() / trials as u32;
        println!(
            "{:<18} L={tables:<3} build {:>8}  recall@1 = {:>5.1}%  avg candidates = {:>5.1} / {count}  query {:?}",
            family.label(),
            format!("{build:?}"),
            100.0 * hit as f64 / trials as f64,
            cand_total as f64 / trials as f64,
            qt,
        );
    }
    println!("\nStructured hashes match dense-Gaussian recall while hashing in O(n log n).");
}
