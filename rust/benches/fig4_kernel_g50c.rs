//! Figure 4 (appendix) — Gram reconstruction error on G50C.
//!
//! The paper: 550 points, n = 50 (zero-padded to 64 for the Hadamard
//! families), σ = 17.4734 on the original download; we use the median
//! heuristic on our generated instance. Same metric and sweep as Figure 2.
//!
//!     cargo bench --bench fig4_kernel_g50c   (TS_FULL=1 for 10 runs)

use triplespin::data::g50c;
use triplespin::kernels::{exact, gram, FeatureKind, FeatureMap};
use triplespin::linalg::fwht::next_pow2;
use triplespin::transform::{make, Family};
use triplespin::util::rng::Rng;

fn main() {
    let full = std::env::var("TS_FULL").is_ok();
    let runs = if full { 10 } else { 3 };
    let points = g50c::dataset(1);
    let n_pad = next_pow2(g50c::DIM); // 50 -> 64
    let sigma = exact::median_bandwidth(&points, 300);
    let feature_counts = [16usize, 32, 64, 128, 256, 512, 1024];

    println!(
        "== Figure 4: Gram reconstruction error, G50C ({} pts, n={} padded to {n_pad}, σ={sigma:.4}, {runs} runs) ==",
        points.len(),
        g50c::DIM
    );

    let families = [
        Family::Dense,
        Family::Toeplitz,
        Family::SkewCirculant,
        Family::Hdg,
        Family::Hd3,
    ];

    for (kname, kind) in [
        ("Gaussian kernel", FeatureKind::GaussianRff),
        ("angular kernel", FeatureKind::Angular),
    ] {
        let k_exact = match kind {
            FeatureKind::GaussianRff => {
                exact::gram(&points, |a, b| exact::gaussian(a, b, sigma))
            }
            _ => exact::gram(&points, exact::angular),
        };
        println!("\n--- {kname} ---");
        print!("{:<22}", "family \\ #features");
        for f in &feature_counts {
            print!(" {f:>8}");
        }
        println!();
        for fam in families {
            print!("{:<22}", fam.label());
            for &feats in &feature_counts {
                let mut err = 0.0;
                for s in 0..runs {
                    let t = make(fam, feats, n_pad, n_pad, &mut Rng::new(200 + s as u64));
                    let fm = FeatureMap::new(t, kind, sigma);
                    err += gram::reconstruction_error(&fm, &points, &k_exact);
                }
                print!(" {:>8.4}", err / runs as f64);
            }
            println!();
        }
    }
    println!("\n(paper: for the Gaussian kernel all curves nearly identical;\n HD3HD2HD1 at least matches the unstructured baseline)");
}
