//! Applications bench (ours) — the paper's §1 claims beyond the three main
//! experiments: vector quantization with random projection trees (Remark 4)
//! and the Johnson–Lindenstrauss transform (§2). Both swap the Gaussian
//! matrix for TripleSpin members and should lose nothing.
//!
//!     cargo bench --bench apps_quantize_jlt

use std::time::Instant;
use triplespin::data::uspst;
use triplespin::jlt::{max_distortion, Jlt};
use triplespin::quantize::{distortion, RpTree};
use triplespin::transform::Family;
use triplespin::util::rng::Rng;

fn main() {
    // ---------------- RP-tree quantization ----------------
    let pts = uspst::dataset_n(600, 11);
    println!("== RP-tree quantization (600 digit images, n=256) ==\n");
    println!(
        "{:<22} {:>6} {:>14} {:>14} {:>12}",
        "family", "depth", "distortion", "storage(bits)", "build time"
    );
    for fam in [Family::Dense, Family::Hd3, Family::Hdg, Family::Circulant] {
        for depth in [4usize, 6, 8] {
            let mut dist = 0.0;
            let mut bits = 0;
            let runs = 3u64;
            let t0 = Instant::now();
            for s in 0..runs {
                let tree = RpTree::build(&pts, fam, depth, 20 + s);
                dist += distortion(&tree, &pts);
                bits = tree.param_bits();
            }
            let dt = t0.elapsed() / runs as u32;
            println!(
                "{:<22} {:>6} {:>14.5} {:>14} {:>12}",
                fam.label(),
                depth,
                dist / runs as f64,
                bits,
                format!("{dt:?}")
            );
        }
    }
    println!("\n(expected: distortion falls with depth identically for all families —\n the split directions' distribution is all that matters, Remark 4)");

    // ---------------- JLT ----------------
    println!("\n== JLT: max pairwise distortion, 40 points in R^1024 ==\n");
    let mut rng = Rng::new(30);
    let cloud: Vec<Vec<f32>> = (0..40).map(|_| rng.gaussian_vec(1024)).collect();
    println!(
        "{:<22} {:>8} {:>12} {:>14}",
        "family", "k", "distortion", "embed time"
    );
    for fam in [Family::Dense, Family::Hd3, Family::Toeplitz] {
        for k in [64usize, 256, 1024] {
            let mut worst = 0.0;
            let runs = 3u64;
            let mut embed_time = std::time::Duration::ZERO;
            for s in 0..runs {
                let jlt = Jlt::new(fam, k, 1024, 40 + s);
                let t0 = Instant::now();
                let d = max_distortion(&jlt, &cloud);
                embed_time += t0.elapsed();
                worst += d;
            }
            println!(
                "{:<22} {:>8} {:>12.4} {:>14}",
                fam.label(),
                k,
                worst / runs as f64,
                format!("{:?}", embed_time / (runs as u32 * 40))
            );
        }
    }
    println!(
        "\n(expected: distortion ~ sqrt(8 ln m / k), identical across families;\n HD3 embeds in O(n log n) — its per-point embed time is flat in k)"
    );
}
