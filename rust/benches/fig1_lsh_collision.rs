//! Figure 1 — cross-polytope LSH collision probability vs distance.
//!
//! The paper: one hash function, 100 runs × 20 000 points, low-dim
//! setting; matrices G, GToeplitz·D2HD1, Gskew-circ·D2HD1, HDg·HD2HD1,
//! HD3·HD2HD1. All curves should coincide: high collision probability at
//! small distance, low at large, no family separated from the Gaussian.
//!
//!     cargo bench --bench fig1_lsh_collision   (TS_FULL=1 for paper-scale)

use triplespin::lsh::collision::collision_curve;
use triplespin::transform::Family;

fn main() {
    let full = std::env::var("TS_FULL").is_ok();
    let n = 128usize;
    let (hash_draws, pairs) = if full { (100, 1000) } else { (40, 250) };
    let distances: Vec<f64> = (1..=20).map(|i| i as f64 * 1.99 / 20.0).collect();

    println!("== Figure 1: collision probability vs distance (n={n}, {hash_draws} draws x {pairs} pairs) ==\n");

    let families = [
        Family::Dense,
        Family::Toeplitz,
        Family::SkewCirculant,
        Family::Hdg,
        Family::Hd3,
    ];

    print!("{:<10}", "distance");
    for f in families {
        print!(" {:>18}", f.label());
    }
    println!();

    let curves: Vec<Vec<f64>> = families
        .iter()
        .map(|f| {
            collision_curve(*f, n, &distances, hash_draws, pairs, 42)
                .into_iter()
                .map(|p| p.probability)
                .collect()
        })
        .collect();

    for (i, d) in distances.iter().enumerate() {
        print!("{d:<10.3}");
        for c in &curves {
            print!(" {:>18.4}", c[i]);
        }
        println!();
    }

    // summary: max deviation of each structured curve from the Gaussian one
    println!("\nmax |p_struct - p_gaussian| over all distances:");
    for (fi, f) in families.iter().enumerate().skip(1) {
        let dev = curves[0]
            .iter()
            .zip(&curves[fi])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!("  {:<20} {dev:.4}", f.label());
    }
    println!("\n(paper: curves 'almost identical' — deviations at MC-noise level)");
}
