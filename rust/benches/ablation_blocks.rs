//! Ablation (ours) — block height `m` as the "structuredness level"
//! (paper §3.1): Gram reconstruction error vs block size at a fixed
//! feature budget. `m = n` is maximally structured (fewest random bits),
//! `m = 1` fully unstructured rows.
//!
//!     cargo bench --bench ablation_blocks

use triplespin::data::uspst;
use triplespin::kernels::{exact, gram, FeatureKind, FeatureMap};
use triplespin::transform::{Family, StackedTransform, Transform};
use triplespin::util::rng::Rng;

fn main() {
    let points = uspst::dataset_n(250, 4);
    let n = uspst::DIM;
    let sigma = exact::median_bandwidth(&points, 200);
    let feats = 256usize;
    let k_exact = exact::gram(&points, |a, b| exact::gaussian(a, b, sigma));

    println!("== ablation: block height m vs accuracy (n={n}, {feats} features, σ={sigma:.3}) ==\n");
    println!(
        "{:<10} {:>12} {:>16} {:>14}",
        "m", "#blocks", "Gram rel. err", "storage(bits)"
    );
    let runs = 4u64;
    for m in [1usize, 4, 16, 64, 128, 256] {
        let mut err = 0.0;
        let mut bits = 0usize;
        for s in 0..runs {
            let t = StackedTransform::new(Family::Hd3, feats, n, m, &mut Rng::new(10 + s));
            bits = t.param_bits();
            let fm = FeatureMap::new(Box::new(t), FeatureKind::GaussianRff, sigma);
            err += gram::reconstruction_error(&fm, &points, &k_exact);
        }
        println!(
            "{:<10} {:>12} {:>16.4} {:>14}",
            m,
            feats.div_ceil(m),
            err / runs as f64,
            bits
        );
    }
    println!(
        "\n(paper §3.1: larger m = more structured = fewer random bits; the\n accuracy cost is small — error stays within MC noise of m=1 until m≈n)"
    );
}
