//! Figure 2 — Gram-matrix reconstruction error vs number of random
//! features, USPST, Gaussian + angular kernels.
//!
//! The paper: 2007 points, n = 258 (we synthesize stroke images at n = 256;
//! DESIGN.md §4), σ = 9.4338 on real USPST — we use the median heuristic on
//! the synthetic set, which is how that value was derived. Errors are
//! `||K - K̃||_F / ||K||_F`, averaged over runs.
//!
//! Default subsamples 400 points / 3 runs (the metric is point-count
//! stable); `TS_FULL=1` uses all 2007 points / 10 runs.
//!
//!     cargo bench --bench fig2_kernel_uspst

use triplespin::data::uspst;
use triplespin::kernels::{exact, gram, FeatureKind, FeatureMap};
use triplespin::transform::{make, Family};
use triplespin::util::rng::Rng;

fn main() {
    let full = std::env::var("TS_FULL").is_ok();
    let (count, runs) = if full { (2007, 10) } else { (400, 3) };
    let points = uspst::dataset_n(count, 1);
    let n = uspst::DIM;
    let sigma = exact::median_bandwidth(&points, 300);
    let feature_counts: Vec<usize> = if full {
        (4..=11).map(|e| 1usize << e).collect()
    } else {
        vec![16, 32, 64, 128, 256, 512, 1024]
    };

    println!(
        "== Figure 2: Gram reconstruction error, USPST-like ({count} pts, n={n}, σ={sigma:.4}, {runs} runs) =="
    );

    let families = [
        Family::Dense,
        Family::Toeplitz,
        Family::SkewCirculant,
        Family::Hdg,
        Family::Hd3,
    ];

    for (kname, kind) in [
        ("Gaussian kernel", FeatureKind::GaussianRff),
        ("angular kernel", FeatureKind::Angular),
    ] {
        let k_exact = match kind {
            FeatureKind::GaussianRff => {
                exact::gram(&points, |a, b| exact::gaussian(a, b, sigma))
            }
            _ => exact::gram(&points, exact::angular),
        };
        println!("\n--- {kname} ---");
        print!("{:<22}", "family \\ #features");
        for f in &feature_counts {
            print!(" {f:>8}");
        }
        println!();
        for fam in families {
            print!("{:<22}", fam.label());
            for &feats in &feature_counts {
                let mut err = 0.0;
                for s in 0..runs {
                    let t = make(fam, feats, n, n, &mut Rng::new(100 + s as u64));
                    let fm = FeatureMap::new(t, kind, sigma);
                    err += gram::reconstruction_error(&fm, &points, &k_exact);
                }
                print!(" {:>8.4}", err / runs as f64);
            }
            println!();
        }
    }
    println!(
        "\n(paper: all TripleSpin curves track the Gaussian curve; HD3HD2HD1 best.\n error decays ~1/√k with feature count k)"
    );
}
