//! Serving benchmark (ours) — coordinator throughput and latency,
//! native vs PJRT backends, batch-size sweep, plus coordinator overhead
//! over raw backend calls.
//!
//!     make artifacts && cargo bench --bench serving

use std::sync::Arc;
use std::time::{Duration, Instant};
use triplespin::coordinator::{Backend, Config, Coordinator, NativeBackend, PjrtBackend};
use triplespin::runtime::{Op, RuntimeService};
use triplespin::util::rng::Rng;

const N: usize = 256;
const REQUESTS: usize = 2000;

fn throughput(c: &Coordinator, op: Op) -> (f64, u64, u64) {
    let mut rng = Rng::new(5);
    let start = Instant::now();
    let mut pending = Vec::with_capacity(REQUESTS);
    for _ in 0..REQUESTS {
        loop {
            match c.submit(op, rng.gaussian_vec(N)) {
                Ok(p) => {
                    pending.push(p);
                    break;
                }
                Err(triplespin::coordinator::SubmitError::Busy) => {
                    if let Some((_, rx)) = pending.pop() {
                        let _ = rx.recv();
                    }
                }
                Err(e) => panic!("{e}"),
            }
        }
    }
    for (_, rx) in pending {
        rx.recv().unwrap().result.unwrap();
    }
    let dt = start.elapsed();
    let rps = REQUESTS as f64 / dt.as_secs_f64();
    let m = c.metrics();
    let (_, lm) = m.iter().find(|((o, _), _)| *o == op).unwrap();
    (
        rps,
        lm.latency.percentile_us(0.5),
        lm.latency.percentile_us(0.95),
    )
}

fn bench_backend(name: &str, make_backend: &dyn Fn() -> Arc<dyn Backend>) {
    println!("\n--- backend: {name} ---");
    println!(
        "{:<14} {:>12} {:>12} {:>10} {:>10}",
        "op", "max_batch", "req/s", "p50(µs)", "p95(µs)"
    );
    for op in [Op::Transform, Op::Rff, Op::CrossPolytope] {
        for max_batch in [1usize, 16, 64] {
            let config = Config {
                lanes: vec![(op, N)],
                max_batch,
                max_wait: Duration::from_micros(200),
                queue_cap: 1024,
                sigma: 1.0,
                seed: 42,
                ..Config::default()
            };
            let c = Coordinator::start(config, make_backend());
            let (rps, p50, p95) = throughput(&c, op);
            println!("{op:<14} {max_batch:>12} {rps:>12.0} {p50:>10} {p95:>10}");
            c.shutdown();
        }
    }
}

fn main() {
    println!("== serving: coordinator throughput/latency (n={N}, {REQUESTS} reqs, 1 client burst) ==");

    // native backend
    bench_backend("native (Rust FWHT)", &|| {
        Arc::new(NativeBackend::new(&[N], 1.0, 42)) as Arc<dyn Backend>
    });

    // coordinator overhead vs raw backend calls (native, batch=1)
    {
        let be = NativeBackend::new(&[N], 1.0, 42);
        let mut rng = Rng::new(6);
        let xs: Vec<Vec<f32>> = (0..REQUESTS).map(|_| rng.gaussian_vec(N)).collect();
        let t0 = Instant::now();
        for x in &xs {
            std::hint::black_box(be.run_batch(Op::Transform, N, 1, x).unwrap());
        }
        let raw = t0.elapsed();
        println!(
            "\nraw native backend, batch=1: {:.0} req/s (coordinator overhead = routing+channels+batching)",
            REQUESTS as f64 / raw.as_secs_f64()
        );
    }

    // pjrt backend (requires artifacts)
    match RuntimeService::spawn("artifacts".into()) {
        Ok(svc) => {
            let handle = svc.handle();
            bench_backend("pjrt (AOT Pallas/JAX artifacts)", &|| {
                Arc::new(PjrtBackend::new(handle.clone(), &[N], 1.0, 42).unwrap())
                    as Arc<dyn Backend>
            });
            svc.shutdown();
        }
        Err(e) => println!("\n(pjrt backend skipped: {e} — run `make artifacts`)"),
    }
}
