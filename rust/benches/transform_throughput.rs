//! Batch-throughput sweep for the pool-resident, zero-allocation execution
//! engine: family × n × batch-rows, three execution modes per shape —
//!
//! * `per_row`  — seed-style allocating `apply` loop (the baseline PR 1
//!   replaced);
//! * `serial`   — `apply_batch_into` pinned to one worker (batch-level
//!   kernels, no threading);
//! * `pooled`   — `apply_batch_into` on a persistent [`WorkerPool`]
//!   (`TS_WORKERS`-tunable, threads spawned once and reused).
//!
//! Plus the NativeBackend `Op::Transform` / `Op::Rff` batch lanes, a
//! `simd_vs_scalar` sweep (the serial batch kernel under the detected SIMD
//! dispatch level vs forced `TS_NO_SIMD`-style scalar — both paths are
//! bit-identical, so this isolates pure throughput), an `fft_variant`
//! sweep (the default RFFT radix-4 convolution engine vs the legacy
//! complex radix-2 `TS_FFT=complex` lane on the same circulant/Toeplitz
//! transforms, serial + pooled), a `binary_vs_float` sweep (sign-quantized
//! packed embedding vs the f32 batch on the same transform, a popcount
//! Hamming vs f32-dot rerank micro, and the bytes-per-embedding ledger),
//! a `diag_micro` entry timing the packed sign-XOR diagonal against
//! the dense f32 multiply it replaced, and a `serving_fault` sweep timing
//! the coordinator's terminal error paths (healthy call vs injected
//! backend error vs injected backend panic through `catch_unwind`) so
//! error-path latency is measured rather than assumed zero. An
//! `admission` sweep does the same for the overload-refusal paths: a
//! granted call through an active token bucket vs a `throttled` refusal
//! vs an `overloaded` shed — refusals must be far cheaper than serving,
//! or shedding would not shed load. A `serving_batch` sweep drives the
//! coalescing ingress: 32 concurrent single-row clients on one lane
//! (recorded batch-size histogram, mean coalesced batch must exceed 4),
//! dedup fan-out from one leader computation across identical concurrent
//! requests, and response-cache hit latency vs an honest recompute of
//! the same request. A `router_merge` sweep times the
//! fleet tier's pure-CPU routing arithmetic (request keying + rendezvous
//! ordering, and the scatter-gather top-k merge) so the per-query cost
//! the `ShardRouter` adds on top of the network hops it hides stays
//! measured.
//!
//! Writes `BENCH_transform_throughput.json` at the repo root to extend the
//! perf trajectory. Set `TS_FULL=1` for the larger dims / row counts and
//! `TS_WORKERS=k` to pin the worker count.
//!
//!     cargo bench --bench transform_throughput

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use triplespin::binary::{BinaryEmbedding, BitMatrix};
use triplespin::coordinator::{
    admission, codec, Backend, Batcher, Config, Coordinator, FaultInjectingBackend, FaultPlan,
    IngressOptions, NativeBackend, SubmitOptions,
};
use triplespin::linalg::fft;
use triplespin::linalg::simd;
use triplespin::linalg::vecops::{dot, scale_by};
use triplespin::router::merge_topk;
use triplespin::router::topology::{rendezvous_order, request_key};
use triplespin::runtime::{Op, WorkerPool};
use triplespin::transform::{make_square, Family, SignDiag};
use triplespin::util::bench;
use triplespin::util::json::Json;
use triplespin::util::rng::Rng;

/// Repo root regardless of whether cargo ran from the workspace root or
/// from `rust/`.
fn out_path() -> &'static str {
    if std::path::Path::new("rust/Cargo.toml").exists() {
        "BENCH_transform_throughput.json"
    } else {
        "../BENCH_transform_throughput.json"
    }
}

#[allow(clippy::too_many_arguments)]
fn entry(
    kind: &str,
    family: &str,
    n: usize,
    rows: usize,
    per_row_ns: f64,
    serial_ns: f64,
    pooled_ns: f64,
) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(kind.into())),
        ("family", Json::Str(family.into())),
        ("n", Json::Num(n as f64)),
        ("rows", Json::Num(rows as f64)),
        ("per_row_loop_ns", Json::Num(per_row_ns)),
        ("batch_serial_ns", Json::Num(serial_ns)),
        ("batch_ns", Json::Num(pooled_ns)),
        (
            "batch_rows_per_sec",
            Json::Num(rows as f64 / (pooled_ns / 1e9)),
        ),
        ("speedup_serial", Json::Num(per_row_ns / serial_ns)),
        ("speedup", Json::Num(per_row_ns / pooled_ns)),
    ])
}

fn main() {
    let full = std::env::var("TS_FULL").map(|v| v != "0").unwrap_or(false);
    let dims: Vec<usize> = if full {
        vec![256, 1024, 4096]
    } else {
        vec![256, 1024]
    };
    let row_counts: Vec<usize> = if full {
        vec![8, 128, 512]
    } else {
        vec![8, 128]
    };
    let opts = bench::quick();
    let pool = WorkerPool::from_env();
    let workers = pool.size();
    println!("== transform throughput (workers={workers}) ==\n");

    let mut entries: Vec<Json> = Vec::new();

    // Transform trait path: seed-style allocating per-row loop vs the
    // serial batch kernel vs the persistent-pool engine.
    let serial_pool = WorkerPool::new(1);
    for fam in [
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
    ] {
        for &n in &dims {
            let t = make_square(fam, n, &mut Rng::new(1));
            for &rows in &row_counts {
                let xs = Rng::new(2).gaussian_vec(rows * n);
                let label = format!("{} n={n} rows={rows}", fam.name());
                let per_row = bench::bench(&format!("{label} per-row"), opts, || {
                    let mut out: Vec<f32> = Vec::with_capacity(rows * n);
                    for r in xs.chunks_exact(n) {
                        out.extend_from_slice(&t.apply(r));
                    }
                    std::hint::black_box(&out);
                });
                let mut out = vec![0.0f32; rows * n];
                let serial = bench::bench(&format!("{label} serial"), opts, || {
                    t.apply_batch_into(&xs, &mut out, &serial_pool);
                    std::hint::black_box(&out);
                });
                let pooled = bench::bench(&format!("{label} pooled"), opts, || {
                    t.apply_batch_into(&xs, &mut out, &pool);
                    std::hint::black_box(&out);
                });
                println!(
                    "{label:<34} per-row {:>10}  serial {:>10}  pooled {:>10}  x{:.2}",
                    bench::fmt_ns(per_row.mean_ns),
                    bench::fmt_ns(serial.mean_ns),
                    bench::fmt_ns(pooled.mean_ns),
                    per_row.mean_ns / pooled.mean_ns
                );
                entries.push(entry(
                    "transform",
                    fam.name(),
                    n,
                    rows,
                    per_row.mean_ns,
                    serial.mean_ns,
                    pooled.mean_ns,
                ));
            }
        }
    }

    // NativeBackend lanes: rows×run_batch(rows=1) (the seed per-row loop)
    // vs one batch call on a single-worker backend vs the pooled backend.
    for op in [Op::Transform, Op::Rff] {
        for &n in &dims {
            let be = NativeBackend::new(&[n], 1.0, 3);
            let be_serial = NativeBackend::with_workers(&[n], 1.0, 3, 1);
            for &rows in &row_counts {
                let xs = Rng::new(4).gaussian_vec(rows * n);
                let label = format!("native {op} n={n} rows={rows}");
                let per_row = bench::bench(&format!("{label} per-row"), opts, || {
                    for r in xs.chunks_exact(n) {
                        std::hint::black_box(be_serial.run_batch(op, n, 1, r).unwrap());
                    }
                });
                let serial = bench::bench(&format!("{label} serial"), opts, || {
                    std::hint::black_box(be_serial.run_batch(op, n, rows, &xs).unwrap());
                });
                let pooled = bench::bench(&format!("{label} pooled"), opts, || {
                    std::hint::black_box(be.run_batch(op, n, rows, &xs).unwrap());
                });
                println!(
                    "{label:<34} per-row {:>10}  serial {:>10}  pooled {:>10}  x{:.2}",
                    bench::fmt_ns(per_row.mean_ns),
                    bench::fmt_ns(serial.mean_ns),
                    bench::fmt_ns(pooled.mean_ns),
                    per_row.mean_ns / pooled.mean_ns
                );
                entries.push(entry(
                    &format!("native_{op}"),
                    "hd3_chain",
                    n,
                    rows,
                    per_row.mean_ns,
                    serial.mean_ns,
                    pooled.mean_ns,
                ));
            }
        }
    }

    // SIMD-vs-scalar sweep: the serial batch kernel (one worker, no pool
    // noise) under the detected dispatch level vs forced scalar. The two
    // paths are bit-identical (tests/simd_equivalence.rs), so the ratio is
    // pure kernel throughput.
    let simd_level = simd::active();
    println!("\n== simd vs scalar (level={simd_level}) ==\n");
    for fam in [
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
    ] {
        for &n in &dims {
            let t = make_square(fam, n, &mut Rng::new(1));
            let rows = *row_counts.last().unwrap();
            let xs = Rng::new(2).gaussian_vec(rows * n);
            let mut out = vec![0.0f32; rows * n];
            let label = format!("{} n={n} rows={rows}", fam.name());
            simd::force(Some(simd::Level::Scalar));
            let scalar = bench::bench(&format!("{label} scalar"), opts, || {
                t.apply_batch_into(&xs, &mut out, &serial_pool);
                std::hint::black_box(&out);
            });
            simd::force(None);
            let vectored = bench::bench(&format!("{label} {simd_level}"), opts, || {
                t.apply_batch_into(&xs, &mut out, &serial_pool);
                std::hint::black_box(&out);
            });
            println!(
                "{label:<34} scalar {:>10}  {simd_level} {:>10}  x{:.2}",
                bench::fmt_ns(scalar.mean_ns),
                bench::fmt_ns(vectored.mean_ns),
                scalar.mean_ns / vectored.mean_ns
            );
            entries.push(Json::obj(vec![
                ("kind", Json::Str("simd_vs_scalar".into())),
                ("family", Json::Str(fam.name().into())),
                ("n", Json::Num(n as f64)),
                ("rows", Json::Num(rows as f64)),
                ("scalar_ns", Json::Num(scalar.mean_ns)),
                ("simd_ns", Json::Num(vectored.mean_ns)),
                ("simd_level", Json::Str(simd_level.into())),
                ("simd_speedup", Json::Num(scalar.mean_ns / vectored.mean_ns)),
            ]));
        }
    }

    // FFT-variant sweep: the same circulant/Toeplitz transform (same
    // seeds, same inputs) built on the default RFFT radix-4 engine vs the
    // legacy complex radix-2 path (the TS_FFT=complex lane), serial and
    // pooled. Outputs agree to f64 round-off (tests/fft_variant.rs), so
    // the ratio is pure convolution-engine throughput.
    println!("\n== fft variant (complex radix-2 vs rfft radix-4) ==\n");
    for fam in [Family::Circulant, Family::Toeplitz] {
        for &n in &dims {
            let rows = *row_counts.last().unwrap();
            let xs = Rng::new(2).gaussian_vec(rows * n);
            let mut out = vec![0.0f32; rows * n];
            fft::force_variant(Some(fft::FftVariant::Complex));
            let t_c = make_square(fam, n, &mut Rng::new(1));
            fft::force_variant(Some(fft::FftVariant::Rfft));
            let t_r = make_square(fam, n, &mut Rng::new(1));
            fft::force_variant(None);
            let label = format!("{} n={n} rows={rows}", fam.name());
            let c_serial = bench::bench(&format!("{label} complex serial"), opts, || {
                t_c.apply_batch_into(&xs, &mut out, &serial_pool);
                std::hint::black_box(&out);
            });
            let r_serial = bench::bench(&format!("{label} rfft serial"), opts, || {
                t_r.apply_batch_into(&xs, &mut out, &serial_pool);
                std::hint::black_box(&out);
            });
            let c_pooled = bench::bench(&format!("{label} complex pooled"), opts, || {
                t_c.apply_batch_into(&xs, &mut out, &pool);
                std::hint::black_box(&out);
            });
            let r_pooled = bench::bench(&format!("{label} rfft pooled"), opts, || {
                t_r.apply_batch_into(&xs, &mut out, &pool);
                std::hint::black_box(&out);
            });
            println!(
                "{label:<34} complex {:>10}  rfft {:>10}  serial x{:.2}  pooled x{:.2}",
                bench::fmt_ns(c_serial.mean_ns),
                bench::fmt_ns(r_serial.mean_ns),
                c_serial.mean_ns / r_serial.mean_ns,
                c_pooled.mean_ns / r_pooled.mean_ns
            );
            entries.push(Json::obj(vec![
                ("kind", Json::Str("fft_variant".into())),
                ("family", Json::Str(fam.name().into())),
                ("n", Json::Num(n as f64)),
                ("rows", Json::Num(rows as f64)),
                ("complex_serial_ns", Json::Num(c_serial.mean_ns)),
                ("rfft_serial_ns", Json::Num(r_serial.mean_ns)),
                ("complex_pooled_ns", Json::Num(c_pooled.mean_ns)),
                ("rfft_pooled_ns", Json::Num(r_pooled.mean_ns)),
                ("simd_level", Json::Str(simd_level.into())),
                (
                    "rfft_speedup_serial",
                    Json::Num(c_serial.mean_ns / r_serial.mean_ns),
                ),
                (
                    "rfft_speedup_pooled",
                    Json::Num(c_pooled.mean_ns / r_pooled.mean_ns),
                ),
            ]));
        }
    }

    // Binary-vs-float sweep: the sign-quantized packed lane against the
    // f32 lane it compresses — (a) embed (transform + fused pack) vs the
    // plain float batch on the same transform/seeds/inputs, (b) a rerank
    // micro (one query against every stored row: popcount Hamming over
    // packed codes vs f32 dot over dense outputs), (c) the
    // bytes-per-embedding ledger behind the 32x serving story.
    println!("\n== binary vs float (sign-quantized packed lane) ==\n");
    for fam in [Family::Hd3, Family::Toeplitz] {
        for &n in &dims {
            let rows = *row_counts.last().unwrap();
            let t = make_square(fam, n, &mut Rng::new(1));
            let emb = BinaryEmbedding::new(make_square(fam, n, &mut Rng::new(1)));
            let xs = Rng::new(2).gaussian_vec(rows * n);
            let label = format!("{} n={n} rows={rows}", fam.name());
            let mut fout = vec![0.0f32; rows * n];
            let float_b = bench::bench(&format!("{label} float batch"), opts, || {
                t.apply_batch_into(&xs, &mut fout, &serial_pool);
                std::hint::black_box(&fout);
            });
            let mut codes = BitMatrix::zeros(rows, n);
            let embed_b = bench::bench(&format!("{label} binary embed"), opts, || {
                emb.embed_batch_into(&xs, &mut codes, &serial_pool);
                std::hint::black_box(&codes);
            });
            let q = fout[..n].to_vec();
            let qcode: Vec<u64> = codes.row(0).to_vec();
            let dot_b = bench::bench(&format!("{label} f32 dot rerank"), opts, || {
                let mut acc = 0.0f64;
                for r in fout.chunks_exact(n) {
                    acc += dot(r, &q);
                }
                std::hint::black_box(acc);
            });
            let ham_b = bench::bench(&format!("{label} hamming rerank"), opts, || {
                let mut acc = 0u64;
                for r in 0..rows {
                    acc += codes.hamming_to(r, &qcode);
                }
                std::hint::black_box(acc);
            });
            let bytes_float = 4 * n;
            let bytes_binary = codes.words_per_row() * 8;
            println!(
                "{label:<34} float {:>10}  embed {:>10}  dot {:>10}  hamming {:>10}  x{:.1}  {}B->{}B",
                bench::fmt_ns(float_b.mean_ns),
                bench::fmt_ns(embed_b.mean_ns),
                bench::fmt_ns(dot_b.mean_ns),
                bench::fmt_ns(ham_b.mean_ns),
                dot_b.mean_ns / ham_b.mean_ns,
                bytes_float,
                bytes_binary,
            );
            entries.push(Json::obj(vec![
                ("kind", Json::Str("binary_vs_float".into())),
                ("family", Json::Str(fam.name().into())),
                ("n", Json::Num(n as f64)),
                ("rows", Json::Num(rows as f64)),
                ("float_batch_ns", Json::Num(float_b.mean_ns)),
                ("binary_embed_ns", Json::Num(embed_b.mean_ns)),
                (
                    "embed_overhead",
                    Json::Num(embed_b.mean_ns / float_b.mean_ns),
                ),
                ("dot_ns", Json::Num(dot_b.mean_ns)),
                ("hamming_ns", Json::Num(ham_b.mean_ns)),
                (
                    "hamming_speedup",
                    Json::Num(dot_b.mean_ns / ham_b.mean_ns),
                ),
                ("bytes_per_embedding_float", Json::Num(bytes_float as f64)),
                ("bytes_per_embedding_binary", Json::Num(bytes_binary as f64)),
                ("simd_level", Json::Str(simd_level.into())),
            ]));
        }
    }

    // Diagonal micro: packed sign-XOR application vs the dense f32
    // multiply it replaced (same ±1 diagonal, bit-identical results; the
    // packed operand stream is 32x smaller — the win shows once the dense
    // diagonal stops fitting in L1 next to the data, hence the 64k size).
    println!("\n== diagonal micro (sign-xor vs f32 multiply) ==\n");
    for &n in dims.iter().chain(&[1usize << 16]) {
        let dense = Rng::new(5).rademacher_vec(n);
        let sd = SignDiag::from_f32(&dense);
        let mut buf = Rng::new(6).gaussian_vec(n);
        let mul = bench::bench(&format!("diag mul n={n}"), opts, || {
            scale_by(&mut buf, &dense);
            std::hint::black_box(&buf);
        });
        let xor = bench::bench(&format!("diag xor n={n}"), opts, || {
            sd.apply(&mut buf);
            std::hint::black_box(&buf);
        });
        println!(
            "diag n={n:<6} f32-mul {:>10}  sign-xor {:>10}  x{:.2}",
            bench::fmt_ns(mul.mean_ns),
            bench::fmt_ns(xor.mean_ns),
            mul.mean_ns / xor.mean_ns
        );
        entries.push(Json::obj(vec![
            ("kind", Json::Str("diag_micro".into())),
            ("family", Json::Str("sign_diag".into())),
            ("n", Json::Num(n as f64)),
            ("rows", Json::Num(1.0)),
            ("mul_ns", Json::Num(mul.mean_ns)),
            ("xor_ns", Json::Num(xor.mean_ns)),
            ("simd_level", Json::Str(simd_level.into())),
            ("xor_speedup", Json::Num(mul.mean_ns / xor.mean_ns)),
        ]));
    }

    // Serving-fault sweep: the coordinator's terminal paths end to end —
    // a healthy call vs an injected backend error vs an injected backend
    // panic (caught by the lane's `catch_unwind`, answered as a typed
    // error). Error replies still pay admission, batching, channel and
    // unwind costs; measuring them keeps the degraded-mode latency story
    // honest instead of assumed-zero.
    println!("\n== serving fault paths (ok vs err vs panic) ==\n");
    for &n in &dims {
        let serve = |plan: &str| {
            let be = Arc::new(FaultInjectingBackend::new(
                Arc::new(NativeBackend::new(&[n], 1.0, 3)),
                FaultPlan::parse(plan).expect("bench fault plan"),
            ));
            Coordinator::start(
                Config {
                    lanes: vec![(Op::Transform, n)],
                    max_batch: 8,
                    max_wait: Duration::from_micros(50),
                    queue_cap: 256,
                    sigma: 1.0,
                    seed: 3,
                    // measure the raw error paths, not breaker shedding
                    breaker_threshold: 0,
                    ..Config::default()
                },
                be,
            )
        };
        let x = Rng::new(8).gaussian_vec(n);
        let c_ok = serve("");
        let ok_b = bench::bench(&format!("serve ok n={n}"), opts, || {
            std::hint::black_box(c_ok.call(Op::Transform, x.clone()).expect("healthy lane"));
        });
        let c_err = serve("err:1,seed:5");
        let err_b = bench::bench(&format!("serve err n={n}"), opts, || {
            std::hint::black_box(c_err.call(Op::Transform, x.clone()).expect_err("err plan"));
        });
        let c_panic = serve("panic:1,seed:5");
        // the injected panics ARE the measurement — silence the default
        // hook's per-panic stderr spam for the duration, then restore it
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let panic_b = bench::bench(&format!("serve panic n={n}"), opts, || {
            std::hint::black_box(c_panic.call(Op::Transform, x.clone()).expect_err("panic plan"));
        });
        std::panic::set_hook(hook);
        for c in [c_ok, c_err, c_panic] {
            c.shutdown();
        }
        println!(
            "serve n={n:<6} ok {:>10}  err {:>10} (x{:.2})  panic {:>10} (x{:.2})",
            bench::fmt_ns(ok_b.mean_ns),
            bench::fmt_ns(err_b.mean_ns),
            err_b.mean_ns / ok_b.mean_ns,
            bench::fmt_ns(panic_b.mean_ns),
            panic_b.mean_ns / ok_b.mean_ns
        );
        entries.push(Json::obj(vec![
            ("kind", Json::Str("serving_fault".into())),
            ("family", Json::Str("hd3_chain".into())),
            ("n", Json::Num(n as f64)),
            ("rows", Json::Num(1.0)),
            ("ok_call_ns", Json::Num(ok_b.mean_ns)),
            ("err_call_ns", Json::Num(err_b.mean_ns)),
            ("panic_call_ns", Json::Num(panic_b.mean_ns)),
            ("err_overhead", Json::Num(err_b.mean_ns / ok_b.mean_ns)),
            ("panic_overhead", Json::Num(panic_b.mean_ns / ok_b.mean_ns)),
        ]));
    }

    // Admission sweep: the overload-refusal paths next to the path they
    // protect. `accept` is a full healthy call through an active token
    // bucket (admission is on, budget ample); `throttle` is a submit
    // against a drained bucket (refused before any backend time);
    // `shed` is a low-priority submit against a primed queue-delay
    // shedder. Refusals must be orders cheaper than serving — that gap
    // is the entire value of admission control under overload.
    println!("\n== admission paths (accept vs throttle vs shed) ==\n");
    for &n in &dims {
        let mk = |rate: f64, shed_target: Duration| {
            Coordinator::start(
                Config {
                    lanes: vec![(Op::Transform, n)],
                    max_batch: 8,
                    max_wait: Duration::from_micros(50),
                    queue_cap: 256,
                    sigma: 1.0,
                    seed: 3,
                    breaker_threshold: 0,
                    admission_rate: rate,
                    shed_target,
                    // zero window: one over-target observation arms, the
                    // next escalates — deterministic for the bench
                    shed_window: Duration::ZERO,
                    ..Config::default()
                },
                Arc::new(NativeBackend::new(&[n], 1.0, 3)) as Arc<dyn Backend>,
            )
        };
        let x = Rng::new(8).gaussian_vec(n);
        // accept: bucket active but ample — the admission check is paid
        // on the granted path
        let c_acc = mk(1e12, Duration::ZERO);
        let acc_b = bench::bench(&format!("admit accept n={n}"), opts, || {
            std::hint::black_box(c_acc.call(Op::Transform, x.clone()).expect("ample budget"));
        });
        // throttle: a bucket that effectively never refills — every
        // submit after the first is a `throttled` refusal
        let c_thr = mk(1e-9, Duration::ZERO);
        let thr_b = bench::bench(&format!("admit throttle n={n}"), opts, || {
            match c_thr.submit_with_opts(Op::Transform, x.clone(), SubmitOptions::default()) {
                Err(e) => {
                    std::hint::black_box(e.code());
                }
                // ~one stray grant per second of refill is possible;
                // drain it so the lane never backs up
                Ok((_, rx)) => {
                    let _ = rx.recv();
                }
            }
        });
        // shed: prime the shedder past its 1µs target (real queue delays
        // are tens of µs under max_wait batching), then measure the
        // low-priority refusal path
        let c_shed = mk(0.0, Duration::from_micros(1));
        let low = SubmitOptions {
            priority: admission::PRIORITY_LOW,
            ..Default::default()
        };
        let mut primed = false;
        for _ in 0..1000 {
            let _ = c_shed.call(Op::Transform, x.clone());
            match c_shed.submit_with_opts(Op::Transform, x.clone(), low) {
                Err(_) => {
                    primed = true;
                    break;
                }
                Ok((_, rx)) => {
                    let _ = rx.recv();
                }
            }
        }
        assert!(primed, "shedder must engage under sustained queue delay");
        let shed_b = bench::bench(&format!("admit shed n={n}"), opts, || {
            let e = c_shed
                .submit_with_opts(Op::Transform, x.clone(), low)
                .expect_err("primed shedder sheds low priority");
            std::hint::black_box(e.code());
        });
        for c in [c_acc, c_thr, c_shed] {
            c.shutdown();
        }
        println!(
            "admit n={n:<6} accept {:>10}  throttle {:>10} (x{:.1})  shed {:>10} (x{:.1})",
            bench::fmt_ns(acc_b.mean_ns),
            bench::fmt_ns(thr_b.mean_ns),
            acc_b.mean_ns / thr_b.mean_ns,
            bench::fmt_ns(shed_b.mean_ns),
            acc_b.mean_ns / shed_b.mean_ns
        );
        entries.push(Json::obj(vec![
            ("kind", Json::Str("admission".into())),
            ("family", Json::Str("hd3_chain".into())),
            ("n", Json::Num(n as f64)),
            ("rows", Json::Num(1.0)),
            ("accept_ns", Json::Num(acc_b.mean_ns)),
            ("throttle_ns", Json::Num(thr_b.mean_ns)),
            ("shed_ns", Json::Num(shed_b.mean_ns)),
            ("throttle_speedup", Json::Num(acc_b.mean_ns / thr_b.mean_ns)),
            ("shed_speedup", Json::Num(acc_b.mean_ns / shed_b.mean_ns)),
        ]));
    }

    // Serving-batch sweep: the coalescing ingress end to end. 32
    // concurrent single-row clients on one lane must coalesce into pooled
    // batches (mean batch size > 4 — the whole amortization story at the
    // serving tier), identical concurrent requests must fan out from one
    // leader computation, and a response-cache hit must answer in less
    // time than a recompute of the same request. All three are measured,
    // not assumed: the backend records every batch shape it actually ran.
    println!("\n== serving batch (coalesce / dedup fan-out / cache) ==\n");
    {
        let n = 256usize;
        /// Backend that records each call's row count behind a short
        /// stall — the stall is what lets concurrent clients pile up into
        /// coalesced batches, exactly like a real accelerator dispatch.
        struct RecordingBackend {
            inner: NativeBackend,
            delay: Duration,
            sizes: Mutex<Vec<usize>>,
        }
        impl Backend for RecordingBackend {
            fn run_batch(
                &self,
                op: Op,
                n: usize,
                rows: usize,
                xs: &[f32],
            ) -> Result<triplespin::runtime::Output, String> {
                self.sizes.lock().unwrap().push(rows);
                if !self.delay.is_zero() {
                    std::thread::sleep(self.delay);
                }
                self.inner.run_batch(op, n, rows, xs)
            }
            fn name(&self) -> &'static str {
                "recording"
            }
        }
        let mk_req = |vector: Vec<f32>, no_cache: bool| codec::Request {
            id: Json::Num(1.0),
            op: Op::Transform,
            timeout: None,
            client_id: None,
            priority: admission::PRIORITY_NORMAL,
            no_cache,
            vector,
        };
        let be = Arc::new(RecordingBackend {
            inner: NativeBackend::new(&[n], 1.0, 3),
            delay: Duration::from_millis(1),
            sizes: Mutex::new(Vec::new()),
        });
        let c = Arc::new(Coordinator::start(
            Config {
                lanes: vec![(Op::Transform, n)],
                max_batch: 32,
                max_wait: Duration::from_millis(5),
                queue_cap: 1024,
                sigma: 1.0,
                seed: 3,
                breaker_threshold: 0,
                ..Config::default()
            },
            Arc::clone(&be) as Arc<dyn Backend>,
        ));
        let batcher = Batcher::new(Arc::clone(&c), IngressOptions::default());

        // phase 1 — coalescing: 32 clients, 8 distinct single-row
        // requests each; the 1ms dispatch stall piles arrivals into the
        // lane queue and the flush window batches them
        let (clients, rounds) = (32usize, 8usize);
        std::thread::scope(|s| {
            for t in 0..clients {
                let batcher = &batcher;
                let mk_req = &mk_req;
                s.spawn(move || {
                    for r in 0..rounds {
                        let v = Rng::new(1000 + (t * rounds + r) as u64).gaussian_vec(n);
                        let doc = batcher.respond(mk_req(v, false), "bench");
                        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
                    }
                });
            }
        });
        let sizes = be.sizes.lock().unwrap().clone();
        let total_rows: usize = sizes.iter().sum();
        assert_eq!(total_rows, clients * rounds, "every row reaches the backend once");
        let mean_batch = total_rows as f64 / sizes.len() as f64;
        assert!(
            mean_batch > 4.0,
            "32 concurrent clients must coalesce: mean {mean_batch:.2} over {sizes:?}"
        );
        // histogram over size buckets 1 / 2 / 3-4 / 5-8 / 9-16 / 17-32
        let mut hist = [0u64; 6];
        for &sz in &sizes {
            let bucket = match sz {
                1 => 0,
                2 => 1,
                3..=4 => 2,
                5..=8 => 3,
                9..=16 => 4,
                _ => 5,
            };
            hist[bucket] += 1;
        }

        // phase 2 — dedup fan-out: 16 clients send the SAME request
        // (no_cache, so dedup and not the cache must provide the sharing);
        // one leader computes inside the 1ms stall, the rest subscribe
        let metrics = c.lane_metrics(Op::Transform, n).expect("bench lane");
        let followers_before = metrics.dedup_followers.load(Ordering::Relaxed);
        let dup: Vec<f32> = Rng::new(4242).gaussian_vec(n);
        let gate = Barrier::new(16);
        std::thread::scope(|s| {
            for _ in 0..16 {
                let batcher = &batcher;
                let mk_req = &mk_req;
                let dup = &dup;
                let gate = &gate;
                s.spawn(move || {
                    gate.wait();
                    let doc = batcher.respond(mk_req(dup.clone(), true), "bench");
                    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
                });
            }
        });
        let dedup_fanout = metrics.dedup_followers.load(Ordering::Relaxed) - followers_before;
        assert!(dedup_fanout >= 1, "a 1ms compute window must catch followers");

        // phase 3 — cache hit vs recompute, on a stall-free stack so the
        // compute number is the honest lane cost, not the injected delay
        let fast_be = Arc::new(NativeBackend::new(&[n], 1.0, 3));
        let fast_c = Arc::new(Coordinator::start(
            Config {
                lanes: vec![(Op::Transform, n)],
                max_batch: 8,
                max_wait: Duration::from_micros(50),
                queue_cap: 256,
                sigma: 1.0,
                seed: 3,
                breaker_threshold: 0,
                ..Config::default()
            },
            fast_be as Arc<dyn Backend>,
        ));
        let fast = Batcher::new(Arc::clone(&fast_c), IngressOptions::default());
        let v: Vec<f32> = Rng::new(777).gaussian_vec(n);
        let primed = fast.respond(mk_req(v.clone(), false), "bench");
        assert_eq!(primed.get("ok"), Some(&Json::Bool(true)), "{primed}");
        let hit_b = bench::bench(&format!("ingress cache hit n={n}"), opts, || {
            std::hint::black_box(fast.respond(mk_req(v.clone(), false), "bench"));
        });
        let comp_b = bench::bench(&format!("ingress recompute n={n}"), opts, || {
            std::hint::black_box(fast.respond(mk_req(v.clone(), true), "bench"));
        });
        assert!(
            hit_b.mean_ns < comp_b.mean_ns,
            "a cache hit must be answered without backend time"
        );
        let fast_metrics = fast_c.lane_metrics(Op::Transform, n).expect("fast lane");
        let cache_hits = fast_metrics.cache_hits.load(Ordering::Relaxed);
        let cache_misses = fast_metrics.cache_misses.load(Ordering::Relaxed);

        println!(
            "ingress n={n:<5} mean batch {mean_batch:.1} ({} calls, hist {hist:?})\n\
             ingress dedup fan-out {dedup_fanout} followers / 16 identical clients\n\
             ingress cache hit {:>10}  recompute {:>10}  (x{:.1}, {cache_hits} hits)",
            sizes.len(),
            bench::fmt_ns(hit_b.mean_ns),
            bench::fmt_ns(comp_b.mean_ns),
            comp_b.mean_ns / hit_b.mean_ns,
        );
        entries.push(Json::obj(vec![
            ("kind", Json::Str("serving_batch".into())),
            ("family", Json::Str("hd3_chain".into())),
            ("n", Json::Num(n as f64)),
            ("rows", Json::Num(clients as f64)),
            ("clients", Json::Num(clients as f64)),
            ("requests", Json::Num((clients * rounds) as f64)),
            ("mean_coalesced_batch", Json::Num(mean_batch)),
            (
                "batch_hist",
                Json::Arr(hist.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            ("dedup_fanout", Json::Num(dedup_fanout as f64)),
            ("cache_hit_ns", Json::Num(hit_b.mean_ns)),
            ("compute_ns", Json::Num(comp_b.mean_ns)),
            ("cache_speedup", Json::Num(comp_b.mean_ns / hit_b.mean_ns)),
            ("cache_hits", Json::Num(cache_hits as f64)),
            ("cache_misses", Json::Num(cache_misses as f64)),
        ]));
        drop(batcher);
        drop(fast);
        for c in [c, fast_c] {
            if let Ok(c) = Arc::try_unwrap(c) {
                c.shutdown();
            }
        }
    }

    // Router-merge sweep: the fleet tier's pure-CPU hot path, no sockets.
    // `route` is what every request pays before a byte moves — hashing the
    // (op, vector) key and rendezvous-ordering the shard groups; `merge`
    // is the scatter-gather combine of S per-shard top-k lists. Both must
    // stay trivially cheap next to a network hop, and this keeps them
    // measured rather than assumed free.
    println!("\n== router merge (rendezvous + scatter-gather top-k) ==\n");
    {
        let n = *dims.last().unwrap();
        let k = 16usize;
        let queries: Vec<Vec<f32>> = (0..64u64).map(|i| Rng::new(9000 + i).unit_vec(n)).collect();
        for &shards in &[2usize, 4, 8] {
            let names: Vec<String> = (0..shards).map(|i| format!("s{i}")).collect();
            let route_b = bench::bench(&format!("route shards={shards}"), opts, || {
                let mut acc = 0usize;
                for q in &queries {
                    acc += rendezvous_order(&names, request_key("lsh_query", q))[0];
                }
                std::hint::black_box(acc);
            });
            let mut rng = Rng::new(77);
            let parts: Vec<Vec<(u32, u64)>> = (0..shards)
                .map(|s| {
                    let mut dists: Vec<u64> = (0..k)
                        .map(|_| (rng.gaussian().abs() * 40.0) as u64)
                        .collect();
                    dists.sort_unstable();
                    dists
                        .iter()
                        .enumerate()
                        .map(|(i, &d)| ((s * k + i) as u32, d))
                        .collect()
                })
                .collect();
            let merge_b = bench::bench(&format!("merge shards={shards}"), opts, || {
                std::hint::black_box(merge_topk(&parts, k));
            });
            let route_ns = route_b.mean_ns / queries.len() as f64;
            println!(
                "router shards={shards:<2} route {:>10}/q  merge {:>10}  (n={n}, k={k})",
                bench::fmt_ns(route_ns),
                bench::fmt_ns(merge_b.mean_ns),
            );
            entries.push(Json::obj(vec![
                ("kind", Json::Str("router_merge".into())),
                ("family", Json::Str("fleet".into())),
                ("n", Json::Num(n as f64)),
                ("rows", Json::Num(shards as f64)),
                ("shards", Json::Num(shards as f64)),
                ("k", Json::Num(k as f64)),
                ("route_ns", Json::Num(route_ns)),
                ("merge_ns", Json::Num(merge_b.mean_ns)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("transform_throughput".into())),
        ("generated", Json::Bool(true)),
        ("provenance", Json::Str("cargo_bench".into())),
        ("workers", Json::Num(workers as f64)),
        ("simd_level", Json::Str(simd_level.into())),
        ("fft_variant", Json::Str(fft::variant().name().into())),
        ("full_sweep", Json::Bool(full)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = out_path();
    std::fs::write(path, format!("{doc}\n")).expect("write bench json");
    println!("\nwrote {path}");
}
