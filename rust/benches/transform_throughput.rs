//! Batch-throughput sweep for the zero-allocation, batch-first execution
//! engine: family × n × batch-rows, seed-style per-row `apply` loop vs the
//! sharded `apply_batch_into` path, plus the NativeBackend `Op::Transform` /
//! `Op::Rff` batch lanes.
//!
//! Writes `BENCH_transform_throughput.json` at the repo root to seed the
//! perf trajectory. Set `TS_FULL=1` for the larger dims / row counts and
//! `TS_WORKERS=k` to pin the worker count.
//!
//!     cargo bench --bench transform_throughput

use triplespin::coordinator::{Backend, NativeBackend};
use triplespin::linalg::WorkspacePool;
use triplespin::runtime::Op;
use triplespin::transform::{make_square, Family};
use triplespin::util::bench;
use triplespin::util::json::Json;
use triplespin::util::rng::Rng;

/// Repo root regardless of whether cargo ran from the workspace root or
/// from `rust/`.
fn out_path() -> &'static str {
    if std::path::Path::new("rust/Cargo.toml").exists() {
        "BENCH_transform_throughput.json"
    } else {
        "../BENCH_transform_throughput.json"
    }
}

fn entry(kind: &str, family: &str, n: usize, rows: usize, per_row_ns: f64, batch_ns: f64) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(kind.into())),
        ("family", Json::Str(family.into())),
        ("n", Json::Num(n as f64)),
        ("rows", Json::Num(rows as f64)),
        ("per_row_loop_ns", Json::Num(per_row_ns)),
        ("batch_ns", Json::Num(batch_ns)),
        (
            "batch_rows_per_sec",
            Json::Num(rows as f64 / (batch_ns / 1e9)),
        ),
        ("speedup", Json::Num(per_row_ns / batch_ns)),
    ])
}

fn main() {
    let full = std::env::var("TS_FULL").is_ok();
    let dims: Vec<usize> = if full {
        vec![256, 1024, 4096]
    } else {
        vec![256, 1024]
    };
    let row_counts: Vec<usize> = if full {
        vec![8, 128, 512]
    } else {
        vec![8, 128]
    };
    let opts = bench::quick();
    let workers = WorkspacePool::from_env().workers();
    println!("== transform throughput (workers={workers}) ==\n");

    let mut entries: Vec<Json> = Vec::new();

    // Transform trait path: seed-style allocating per-row loop vs the
    // batch-first engine.
    for fam in [
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
    ] {
        for &n in &dims {
            let t = make_square(fam, n, &mut Rng::new(1));
            for &rows in &row_counts {
                let xs = Rng::new(2).gaussian_vec(rows * n);
                let label = format!("{} n={n} rows={rows}", fam.name());
                let per_row = bench::bench(&format!("{label} per-row"), opts, || {
                    let mut out: Vec<f32> = Vec::with_capacity(rows * n);
                    for r in xs.chunks_exact(n) {
                        out.extend_from_slice(&t.apply(r));
                    }
                    std::hint::black_box(&out);
                });
                let mut pool = WorkspacePool::from_env();
                let mut out = vec![0.0f32; rows * n];
                let batch = bench::bench(&format!("{label} batch"), opts, || {
                    t.apply_batch_into(&xs, &mut out, &mut pool);
                    std::hint::black_box(&out);
                });
                println!(
                    "{label:<36} per-row {:>11}  batch {:>11}  x{:.2}",
                    bench::fmt_ns(per_row.mean_ns),
                    bench::fmt_ns(batch.mean_ns),
                    per_row.mean_ns / batch.mean_ns
                );
                entries.push(entry(
                    "transform",
                    fam.name(),
                    n,
                    rows,
                    per_row.mean_ns,
                    batch.mean_ns,
                ));
            }
        }
    }

    // NativeBackend lanes: rows×run_batch(rows=1) (the seed per-row loop)
    // vs one sharded batch call.
    for op in [Op::Transform, Op::Rff] {
        for &n in &dims {
            let be = NativeBackend::new(&[n], 1.0, 3);
            for &rows in &row_counts {
                let xs = Rng::new(4).gaussian_vec(rows * n);
                let label = format!("native {op} n={n} rows={rows}");
                let per_row = bench::bench(&format!("{label} per-row"), opts, || {
                    for r in xs.chunks_exact(n) {
                        std::hint::black_box(be.run_batch(op, n, 1, r).unwrap());
                    }
                });
                let batch = bench::bench(&format!("{label} batch"), opts, || {
                    std::hint::black_box(be.run_batch(op, n, rows, &xs).unwrap());
                });
                println!(
                    "{label:<36} per-row {:>11}  batch {:>11}  x{:.2}",
                    bench::fmt_ns(per_row.mean_ns),
                    bench::fmt_ns(batch.mean_ns),
                    per_row.mean_ns / batch.mean_ns
                );
                entries.push(entry(
                    &format!("native_{op}"),
                    "hd3_chain",
                    n,
                    rows,
                    per_row.mean_ns,
                    batch.mean_ns,
                ));
            }
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("transform_throughput".into())),
        ("generated", Json::Bool(true)),
        ("workers", Json::Num(workers as f64)),
        ("full_sweep", Json::Bool(full)),
        ("entries", Json::Arr(entries)),
    ]);
    let path = out_path();
    std::fs::write(path, format!("{doc}\n")).expect("write bench json");
    println!("\nwrote {path}");
}
