//! Figure 3 — Newton sketch: convergence (left) and Hessian-sketch
//! wall-clock time vs dimension (right).
//!
//! Left: optimality gap vs iteration for exact Newton, Gaussian sketch, and
//! TripleSpin sketches on logistic regression with `Σ_ij = 0.99^|i-j|`
//! design rows. Right: time to *form the sketched Hessian* — the paper's
//! `O(nd²)` exact vs `O(dn log n + md²)` structured comparison.
//!
//!     cargo bench --bench fig3_newton   (TS_FULL=1 for larger n sweep)

use std::time::Instant;
use triplespin::data::logistic;
use triplespin::sketch::logistic::gram_t;
use triplespin::sketch::newton::sketch_apply;
use triplespin::sketch::{newton_solve, NewtonOptions, SketchKind};
use triplespin::transform::Family;
use triplespin::util::bench::{self, Opts};
use triplespin::util::rng::Rng;

fn main() {
    let full = std::env::var("TS_FULL").is_ok();

    // ---------- left panel: convergence ----------
    let (n, d) = (4096usize, 64usize);
    let m = 4 * d;
    println!("== Figure 3 (left): optimality gap vs iteration (n={n}, d={d}, sketch m={m}) ==\n");
    let p = logistic::generate(n, d, 0.99, 1);
    let f_star = *newton_solve(
        &p,
        SketchKind::Exact,
        NewtonOptions {
            max_iters: 60,
            ..Default::default()
        },
    )
    .values
    .last()
    .unwrap();

    let kinds = [
        SketchKind::Exact,
        SketchKind::Gaussian,
        SketchKind::Struct(Family::Hd3),
        SketchKind::Struct(Family::Hdg),
        SketchKind::Struct(Family::Toeplitz),
        SketchKind::Struct(Family::SkewCirculant),
    ];
    let iters_shown = [1usize, 2, 3, 4, 6, 8, 12, 16, 20];
    print!("{:<26}", "sketch \\ iteration");
    for it in iters_shown {
        print!(" {it:>9}");
    }
    println!();
    for kind in kinds {
        let trace = newton_solve(
            &p,
            kind,
            NewtonOptions {
                sketch_rows: m,
                max_iters: 20,
                ..Default::default()
            },
        );
        let gaps = trace.gaps(f_star);
        print!("{:<26}", kind.label());
        for it in iters_shown {
            if it < gaps.len() {
                print!(" {:>9.2e}", gaps[it]);
            } else {
                print!(" {:>9}", "conv");
            }
        }
        println!();
    }
    println!("\n(paper: sketched variants converge linearly, a constant factor behind\n exact Newton; all TripleSpin curves overlap the Gaussian-sketch curve)");

    // ---------- right panel: Hessian-sketch wall-clock ----------
    // exact Hessian formation is O(n d²); TripleSpin sketch O(d n log n + m d²)
    // with m = 4d — the structured win appears once d >> log n, so we sweep
    // both n and the problem dimension d.
    let max_exp = if full { 15 } else { 13 };
    let ns: Vec<usize> = (11..=max_exp).map(|e| 1usize << e).collect();
    let sketch_kinds = [
        SketchKind::Exact,
        SketchKind::Gaussian,
        SketchKind::Struct(Family::Hd3),
        SketchKind::Struct(Family::Hdg),
        SketchKind::Struct(Family::Toeplitz),
    ];
    for d in [64usize, 256] {
        let m = 4 * d;
        println!("\n== Figure 3 (right): time to form the sketched Hessian (d={d}, m={m}) ==\n");
        print!("{:<26}", "sketch \\ n");
        for n in &ns {
            print!(" {:>10}", format!("2^{}", n.trailing_zeros()));
        }
        println!();
        for kind in sketch_kinds {
            print!("{:<26}", kind.label());
            for &nn in &ns {
                // fresh problem at this n; time sketch + d×d Gram formation
                let p = logistic::generate(nn, d, 0.99, 2);
                let x0 = vec![0.0f64; d];
                let b = p.hessian_sqrt(&x0);
                let opts = Opts {
                    warmup: std::time::Duration::from_millis(20),
                    measure: std::time::Duration::from_millis(150),
                    max_samples: 8,
                };
                let mut rng = Rng::new(3);
                let s = bench::bench("hessian", opts, || {
                    let t0 = Instant::now();
                    let sb = sketch_apply(kind, &b, m, &mut rng);
                    let h = gram_t(&sb, 1e-8);
                    std::hint::black_box((h, t0));
                });
                print!(" {:>10}", bench::fmt_ns(s.mean_ns));
            }
            println!();
        }
    }
    println!("\n(paper: exact/Gaussian grow ~linearly in n with a large constant;\n Hadamard-based sketches cheapest once d >> log n — visible in the d=256 table)");
}
