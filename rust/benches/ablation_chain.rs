//! Ablation (ours) — number of HD "spins": 1 vs 2 vs 3 (the paper's name
//! comes from the three-factor product; [1] found HD3HD2HD1 the sweet
//! spot). Measures (a) LSH collision-curve deviation from the Gaussian
//! reference and (b) Gram reconstruction error, per spin count.
//!
//!     cargo bench --bench ablation_chain

use triplespin::data::uspst;
use triplespin::kernels::{exact, gram, FeatureKind, FeatureMap};
use triplespin::linalg::vecops::argmax_abs_signed;
use triplespin::linalg::Workspace;
use triplespin::lsh::collision::pair_at_distance;
use triplespin::transform::hd::HdChain;
use triplespin::transform::{make_square, Family, Transform};
use triplespin::util::rng::Rng;

/// A pair of **sparse** unit vectors at the given distance: supported on a
/// random 4-coordinate subspace. Sparse inputs are the adversarial case for
/// shallow chains — one HD spin spreads a spike perfectly evenly, making
/// |projections| tie and the cross-polytope argmax degenerate; additional
/// spins randomize the signs pattern the way a Gaussian matrix would.
fn sparse_pair_at_distance(
    n: usize,
    dist: f64,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<f32>) {
    let s = 4;
    let (xs, ys) = pair_at_distance(s, dist, rng);
    let perm = rng.permutation(n);
    let mut x = vec![0.0f32; n];
    let mut y = vec![0.0f32; n];
    for i in 0..s {
        x[perm[i]] = xs[i];
        y[perm[i]] = ys[i];
    }
    (x, y)
}

/// Collision probability with a k-spin chain at the given distance.
fn collision_prob(k: usize, n: usize, dist: f64, draws: u64, pairs: usize) -> f64 {
    let mut coll = 0usize;
    let mut total = 0usize;
    for d in 0..draws {
        let chain = HdChain::spins(n, k, &mut Rng::new(1000 + d));
        let mut rng = Rng::new(2000 + d);
        for _ in 0..pairs {
            let (x, y) = sparse_pair_at_distance(n, dist, &mut rng);
            let hx = argmax_abs_signed(&chain.apply(&x));
            let hy = argmax_abs_signed(&chain.apply(&y));
            if hx == hy {
                coll += 1;
            }
            total += 1;
        }
    }
    coll as f64 / total as f64
}

fn main() {
    let n = 128usize;
    let distances = [0.3f64, 0.7, 1.1, 1.5];
    let (draws, pairs) = (30u64, 150usize);

    println!("== ablation: spin count k in (HD)^k (n={n}) ==\n");
    println!("--- LSH collision probability vs distance (4-sparse inputs) ---");
    print!("{:<18}", "variant \\ dist");
    for d in distances {
        print!(" {d:>8.2}");
    }
    println!();

    // Gaussian reference
    {
        print!("{:<18}", "G (reference)");
        for &dist in &distances {
            let mut coll = 0usize;
            let mut total = 0usize;
            for dr in 0..draws {
                let g = make_square(Family::Dense, n, &mut Rng::new(3000 + dr));
                let mut rng = Rng::new(4000 + dr);
                for _ in 0..pairs {
                    let (x, y) = sparse_pair_at_distance(n, dist, &mut rng);
                    if argmax_abs_signed(&g.apply(&x)) == argmax_abs_signed(&g.apply(&y)) {
                        coll += 1;
                    }
                    total += 1;
                }
            }
            print!(" {:>8.4}", coll as f64 / total as f64);
        }
        println!();
    }
    for k in 1..=4 {
        print!("{:<18}", format!("(HD)^{k}"));
        for &d in &distances {
            print!(" {:>8.4}", collision_prob(k, n, d, draws, pairs));
        }
        println!();
    }

    println!("\n--- Gram reconstruction error (Gaussian kernel, 256 features) ---");
    let points = uspst::dataset_n(200, 5);
    let np = uspst::DIM;
    let sigma = exact::median_bandwidth(&points, 150);
    let k_exact = exact::gram(&points, |a, b| exact::gaussian(a, b, sigma));
    let runs = 4u64;
    // dense reference
    {
        let mut err = 0.0;
        for s in 0..runs {
            let t = triplespin::transform::make(Family::Dense, 256, np, np, &mut Rng::new(50 + s));
            let fm = FeatureMap::new(t, FeatureKind::GaussianRff, sigma);
            err += gram::reconstruction_error(&fm, &points, &k_exact);
        }
        println!("{:<18} {:.4}", "G (reference)", err / runs as f64);
    }
    for k in 1..=4 {
        let mut err = 0.0;
        for s in 0..runs {
            // stack k-spin blocks to 256 rows
            let chain_maker = |rng: &mut Rng| -> Box<dyn Transform> {
                Box::new(HdChain::spins(np, k, rng))
            };
            // build a stacked transform manually from chains
            let t = StackedOfChains::new(256, np, k, 60 + s, chain_maker);
            let fm = FeatureMap::new(Box::new(t), FeatureKind::GaussianRff, sigma);
            err += gram::reconstruction_error(&fm, &points, &k_exact);
        }
        println!("{:<18} {:.4}", format!("(HD)^{k}"), err / runs as f64);
    }
    println!(
        "\n(expected: k=1 under-mixes (visible error/curve gap for structured inputs);\n k=2 close; k=3 matches Gaussian — the paper's choice; k=4 no further gain)"
    );
}

/// Minimal vertical stacking of independent k-spin chains (the §3.1
/// mechanism, specialized for this ablation).
struct StackedOfChains {
    k_rows: usize,
    n: usize,
    blocks: Vec<HdChain>,
}

impl StackedOfChains {
    fn new(
        k_rows: usize,
        n: usize,
        spins: usize,
        seed: u64,
        _mk: impl Fn(&mut Rng) -> Box<dyn Transform>,
    ) -> StackedOfChains {
        let mut rng = Rng::new(seed);
        let blocks = (0..k_rows.div_ceil(n))
            .map(|_| HdChain::spins(n, spins, &mut rng.fork()))
            .collect();
        StackedOfChains { k_rows, n, blocks }
    }
}

impl Transform for StackedOfChains {
    fn dim_in(&self) -> usize {
        self.n
    }
    fn dim_out(&self) -> usize {
        self.k_rows
    }
    fn apply_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let mut buf = ws.take_f32(self.n);
        let mut off = 0;
        for b in &self.blocks {
            b.apply_into(x, &mut buf, ws);
            let take = self.n.min(self.k_rows - off);
            out[off..off + take].copy_from_slice(&buf[..take]);
            off += take;
            if off == self.k_rows {
                break;
            }
        }
        ws.put_f32(buf);
    }
    fn name(&self) -> &'static str {
        "hdk-stacked"
    }
    fn param_bits(&self) -> usize {
        self.blocks.iter().map(|b| b.param_bits()).sum()
    }
}
