//! Table 1 — matvec speedups `time(G) / time(T)` per TripleSpin family.
//!
//! The paper reports dims 2^9..2^15 (single thread, MKL dense baseline).
//! Default here sweeps 2^9..2^13; set `TS_FULL=1` for 2^14 and 2^15 (the
//! dense baseline alone needs 1 GiB / 4 GiB and minutes of RNG).
//!
//!     cargo bench --bench table1_speedups

use triplespin::transform::{make_square, Family};
use triplespin::util::bench::{self, Opts};
use triplespin::util::rng::Rng;

fn main() {
    let full = std::env::var("TS_FULL").is_ok();
    let max_exp = if full { 15 } else { 13 };
    let dims: Vec<usize> = (9..=max_exp).map(|e| 1usize << e).collect();

    println!("== Table 1: matvec speedups time(G)/time(T) ==");
    println!("(paper: x1.4..x316 over dims 2^9..2^15; shape should match — speedup grows ~n/log n)\n");

    // dense baseline times per dim
    let mut dense_ns = Vec::new();
    let opts = Opts::default();
    for &n in &dims {
        let t = make_square(Family::Dense, n, &mut Rng::new(1));
        let x = Rng::new(2).unit_vec(n);
        let s = bench::bench(&format!("dense n={n}"), opts, || {
            std::hint::black_box(t.apply(std::hint::black_box(&x)));
        });
        dense_ns.push(s.mean_ns);
        eprintln!("baseline dense n={n}: {}", bench::fmt_ns(s.mean_ns));
    }

    let columns: Vec<String> = dims.iter().map(|n| format!("2^{}", n.trailing_zeros())).collect();
    let mut rows = Vec::new();
    for fam in Family::PAPER_SET {
        let mut vals = Vec::new();
        for (i, &n) in dims.iter().enumerate() {
            let t = make_square(fam, n, &mut Rng::new(3));
            let x = Rng::new(4).unit_vec(n);
            let s = bench::bench(&format!("{} n={n}", fam.name()), opts, || {
                std::hint::black_box(t.apply(std::hint::black_box(&x)));
            });
            vals.push(format!("x{:.1}", dense_ns[i] / s.mean_ns));
        }
        rows.push((fam.label().to_string(), vals));
    }
    bench::print_table("speedup over dense Gaussian matvec", &columns, &rows);

    // absolute times for the record
    let mut abs_rows = Vec::new();
    for fam in [Family::Dense, Family::Hd3, Family::Hdg, Family::Toeplitz, Family::SkewCirculant] {
        let mut vals = Vec::new();
        for &n in &dims {
            let t = make_square(fam, n, &mut Rng::new(3));
            let x = Rng::new(4).unit_vec(n);
            let s = bench::bench("abs", Opts::default(), || {
                std::hint::black_box(t.apply(std::hint::black_box(&x)));
            });
            vals.push(bench::fmt_ns(s.mean_ns));
        }
        abs_rows.push((fam.label().to_string(), vals));
    }
    bench::print_table("absolute matvec time", &columns, &abs_rows);
}
