//! Runtime-dispatched SIMD inner kernels for the transform hot loops.
//!
//! Every arithmetic inner loop of the execution engine — FWHT butterflies,
//! complex FFT butterflies (radix-2 [`fft_butterfly`] and the RFFT
//! engine's fused radix-4 [`fft_butterfly4`]), spectrum multiplies (full
//! [`cmul`] and the conjugate-aware half-spectrum [`cmul_half`]), the
//! elementwise diagonal/sign passes, and the binary lane's sign
//! quantization + Hamming popcount ([`pack_signs`] / [`hamming`]) —
//! funnels through this module. At first use the
//! module probes the CPU once (`is_x86_feature_detected!` on x86-64, NEON
//! on aarch64) and caches a dispatch [`Level`]; every public kernel then
//! routes to the widest available implementation.
//!
//! ## Bit-identity contract
//!
//! **Every SIMD path computes byte-identical results to the scalar path.**
//! This is what keeps `TS_NO_SIMD=1` (and non-x86 hosts) interchangeable
//! with the vectorized build, and it is enforced by
//! `tests/simd_equivalence.rs` across every transform family. The contract
//! holds because each kernel is element-independent (no horizontal
//! reductions, no reassociation) and both paths perform the same IEEE
//! operations in the same per-element order:
//!
//! * butterflies are a single add/sub pair per element;
//! * complex butterflies evaluate `v = t·w` then `u ± v` with discrete
//!   mul/sub/add steps — **no FMA contraction** on either path (Rust never
//!   contracts; the intrinsics used here are plain `mul`/`add`/`sub`);
//! * sign application is a sign-bit XOR, which is exactly `x * ±1.0` for
//!   every non-NaN input, followed (when a fold-in scale is present) by one
//!   multiply — the same two steps both paths take.
//!
//! ## Dispatch rules
//!
//! * `TS_NO_SIMD=1` (any value other than `0`) pins [`Level::Scalar`].
//! * x86-64 picks AVX2 (8×f32 / 4×f64) when detected, else SSE2 (always
//!   present on x86-64, 4×f32 / 2×f64).
//! * aarch64 picks NEON for the pure-f32 kernels (butterflies, scale,
//!   sign application); the f64 FFT kernels and the f32→f64 promotion
//!   stay on the (identical-result) scalar path there — as do the
//!   cold-path [`rfft_split`]/[`rfft_merge`] helpers on every tier.
//! * [`force`] overrides the cached level at runtime — the hook the
//!   equivalence tests and the `simd_vs_scalar` bench sweep use to compare
//!   paths inside one process.

use std::sync::atomic::{AtomicU8, Ordering};

/// Dispatch tier, ordered by preference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar loops — always compiled, selected by `TS_NO_SIMD=1`
    /// and on targets without a SIMD implementation.
    Scalar,
    /// 4×f32 / 2×f64 (baseline on every x86-64).
    Sse2,
    /// 8×f32 / 4×f64.
    Avx2,
    /// 4×f32 on aarch64 (f64 kernels fall back to scalar).
    Neon,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }
}

const LEVEL_UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn detect() -> Level {
    if std::env::var("TS_NO_SIMD").map(|v| v != "0").unwrap_or(false) {
        return Level::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Level {
    if is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        // SSE2 is part of the x86-64 baseline.
        Level::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Level {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Level::Neon
    } else {
        Level::Scalar
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Level {
    Level::Scalar
}

#[inline]
fn decode(v: u8) -> Level {
    match v {
        1 => Level::Sse2,
        2 => Level::Avx2,
        3 => Level::Neon,
        _ => Level::Scalar,
    }
}

#[inline]
fn encode(l: Level) -> u8 {
    match l {
        Level::Scalar => 0,
        Level::Sse2 => 1,
        Level::Avx2 => 2,
        Level::Neon => 3,
    }
}

/// The active dispatch level (detected once, cached; see [`force`]).
#[inline]
pub fn level() -> Level {
    // ORDERING: Relaxed — LEVEL is an idempotent cache of a pure CPU probe;
    // racing threads may both run detect() and store the same value, and no
    // other memory is published through this atomic.
    let v = LEVEL.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return decode(v);
    }
    let l = detect();
    // ORDERING: Relaxed — same-value idempotent cache fill (see load above).
    LEVEL.store(encode(l), Ordering::Relaxed);
    l
}

/// Override the dispatch level (`None` = re-detect from CPU + `TS_NO_SIMD`).
///
/// Testing/bench hook: the equivalence suite and the `simd_vs_scalar`
/// bench sweep pin [`Level::Scalar`] to compare both paths in one process.
/// Forcing a level the CPU cannot execute is the caller's responsibility
/// (stick to `Scalar` and the detected level).
pub fn force(l: Option<Level>) {
    // ORDERING: Relaxed — test/bench hook; callers only read the level back
    // through `level()` on the same thread, and kernels re-load it per call,
    // so no cross-thread ordering is implied or needed.
    match l {
        Some(l) => LEVEL.store(encode(l), Ordering::Relaxed),
        None => LEVEL.store(encode(detect()), Ordering::Relaxed),
    }
}

/// Name of the active dispatch level ("avx2" / "sse2" / "neon" /
/// "scalar") — recorded by the throughput bench next to its measurements.
pub fn active() -> &'static str {
    level().name()
}

// ---------------------------------------------------------------------------
// Public kernels
// ---------------------------------------------------------------------------

/// FWHT butterfly across a level: `head[i], tail[i] = head[i] + tail[i],
/// head[i] - tail[i]`. The innermost loop of every Hadamard family.
#[inline]
pub fn butterfly(head: &mut [f32], tail: &mut [f32]) {
    assert_eq!(head.len(), tail.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::butterfly_avx2(head, tail) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::butterfly_sse2(head, tail) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: this arm runs only when `level()` resolved Neon, so the
        // NEON target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Neon => unsafe { neon::butterfly_neon(head, tail) },
        _ => scalar::butterfly(head, tail),
    }
}

/// FWHT butterfly with a fused output scale: `head[i], tail[i] =
/// (head[i] + tail[i]) * s, (head[i] - tail[i]) * s`. The last level of
/// `fwht_normalized`, carrying the folded `1/√n`.
#[inline]
pub fn butterfly_scaled(head: &mut [f32], tail: &mut [f32], s: f32) {
    assert_eq!(head.len(), tail.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::butterfly_scaled_avx2(head, tail, s) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::butterfly_scaled_sse2(head, tail, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: this arm runs only when `level()` resolved Neon, so the
        // NEON target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Neon => unsafe { neon::butterfly_scaled_neon(head, tail, s) },
        _ => scalar::butterfly_scaled(head, tail, s),
    }
}

/// Elementwise multiply `a[i] *= d[i]` — the dense-diagonal `D` pass.
#[inline]
pub fn scale(a: &mut [f32], d: &[f32]) {
    assert_eq!(a.len(), d.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::scale_avx2(a, d) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::scale_sse2(a, d) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: this arm runs only when `level()` resolved Neon, so the
        // NEON target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Neon => unsafe { neon::scale_neon(a, d) },
        _ => scalar::scale(a, d),
    }
}

/// Apply a packed ±1 diagonal: flip the sign of `x[i]` where bit `i` of
/// `signs` is set (bit `i` lives in `signs[i / 64]` at position `i % 64`).
/// A sign-bit XOR — exactly `x[i] * ±1.0f32` for non-NaN inputs, with no
/// multiply and a 32× smaller operand stream.
#[inline]
pub fn apply_signs(x: &mut [f32], signs: &[u64]) {
    assert!(signs.len() * 64 >= x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::apply_signs_avx2(x, signs) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::apply_signs_sse2(x, signs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: this arm runs only when `level()` resolved Neon, so the
        // NEON target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Neon => unsafe { neon::apply_signs_neon(x, signs) },
        _ => scalar::apply_signs(x, signs),
    }
}

/// [`apply_signs`] followed by a uniform multiply: `x[i] = ±x[i] * s`.
/// Bit-identical to multiplying by a dense diagonal whose entries are
/// `±s` (the sign flip commutes exactly with the magnitude multiply).
#[inline]
pub fn apply_signs_scaled(x: &mut [f32], signs: &[u64], s: f32) {
    assert!(signs.len() * 64 >= x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::apply_signs_scaled_avx2(x, signs, s) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::apply_signs_scaled_sse2(x, signs, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: this arm runs only when `level()` resolved Neon, so the
        // NEON target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Neon => unsafe { neon::apply_signs_scaled_neon(x, signs, s) },
        _ => scalar::apply_signs_scaled(x, signs, s),
    }
}

/// Fused sign + scale + f64 promotion: `dst[i] = ((±src[i]) * s) as f64`.
/// The circulant-family hand-off from the f32 FWHT stage into the f64 FFT
/// buffer (`D2 · 1/√n` fold).
#[inline]
pub fn promote_signs_scaled(src: &[f32], signs: &[u64], s: f32, dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len());
    assert!(signs.len() * 64 >= src.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::promote_signs_scaled_avx2(src, signs, s, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::promote_signs_scaled_sse2(src, signs, s, dst) },
        _ => scalar::promote_signs_scaled(src, signs, s, dst),
    }
}

/// Pointwise complex multiply (split layout): `(re, im)[i] *= (kr, ki)[i]`.
/// The spectrum stage of every `ConvPlan` matvec.
#[inline]
pub fn cmul(re: &mut [f64], im: &mut [f64], kr: &[f64], ki: &[f64]) {
    assert_eq!(re.len(), im.len());
    assert_eq!(re.len(), kr.len());
    assert_eq!(re.len(), ki.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::cmul_avx2(re, im, kr, ki) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::cmul_sse2(re, im, kr, ki) },
        _ => scalar::cmul(re, im, kr, ki),
    }
}

/// One block of a radix-2 complex butterfly level with table twiddles:
/// for each `j`, with `w = (twr[j·stride], sign · twi[j·stride])`,
///
/// ```text
/// v = (re_t[j], im_t[j]) · w
/// (re_h[j], im_h[j]), (re_t[j], im_t[j]) = u + v, u - v
/// ```
///
/// All four slices have the same length (`half`); `twr`/`twi` are the
/// plan-shared `exp(-2πi k/n)` tables read at `stride = n / len`.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn fft_butterfly(
    re_h: &mut [f64],
    im_h: &mut [f64],
    re_t: &mut [f64],
    im_t: &mut [f64],
    twr: &[f64],
    twi: &[f64],
    stride: usize,
    sign: f64,
) {
    assert_eq!(re_h.len(), re_t.len());
    assert_eq!(im_h.len(), im_t.len());
    assert_eq!(re_h.len(), im_h.len());
    assert!(twr.len() >= (re_h.len().saturating_sub(1)) * stride + 1 || re_h.is_empty());
    assert!(twi.len() >= (re_h.len().saturating_sub(1)) * stride + 1 || re_h.is_empty());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe {
            x86::fft_butterfly_avx2(re_h, im_h, re_t, im_t, twr, twi, stride, sign)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe {
            x86::fft_butterfly_sse2(re_h, im_h, re_t, im_t, twr, twi, stride, sign)
        },
        _ => scalar::fft_butterfly(re_h, im_h, re_t, im_t, twr, twi, stride, sign),
    }
}

/// One block of a **radix-4** complex butterfly level with table twiddles —
/// the fused form of two consecutive radix-2 levels, used by the RFFT
/// engine's half-size FFT. The four slices are the block's quarters at
/// memory offsets `0, L, 2L, 3L`; in bit-reversed order they hold the
/// sub-DFTs of the residue-`0, 2, 1, 3` subsequences. With
/// `W_q = exp(-2πi q·j/len) = tw[q·j·stride]` (conjugated when
/// `sign = -1.0`, the inverse):
///
/// ```text
/// a = q0[j]        c = W2 · q1[j]     b = W1 · q2[j]     d = W3 · q3[j]
/// t0 = a + c   t1 = a - c   t2 = b + d   t3 = b - d
/// q0[j] = t0 + t2          q2[j] = t0 - t2
/// q1[j] = t1 - i·sign·t3   q3[j] = t1 + i·sign·t3
/// ```
///
/// Twiddle indices reach `3·(L-1)·stride`, so the plan tables extend to
/// `3n/4` entries (see `linalg::fft`). One radix-2 cleanup level handles
/// odd `log2` sizes.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn fft_butterfly4(
    re0: &mut [f64],
    im0: &mut [f64],
    re1: &mut [f64],
    im1: &mut [f64],
    re2: &mut [f64],
    im2: &mut [f64],
    re3: &mut [f64],
    im3: &mut [f64],
    twr: &[f64],
    twi: &[f64],
    stride: usize,
    sign: f64,
) {
    let l = re0.len();
    for s in [&*im0, &*re1, &*im1, &*re2, &*im2, &*re3, &*im3] {
        assert_eq!(s.len(), l);
    }
    assert!(l == 0 || twr.len() > 3 * (l - 1) * stride);
    assert!(l == 0 || twi.len() > 3 * (l - 1) * stride);
    if l < 4 {
        // sub-vector blocks (the len=4/len=8 levels): the SIMD bodies
        // would run their scalar tail for every lane anyway, so skip the
        // vector entry entirely (identical results by construction).
        return scalar::fft_butterfly4(
            re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign,
        );
    }
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe {
            x86::fft_butterfly4_avx2(re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe {
            x86::fft_butterfly4_sse2(re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign)
        },
        _ => scalar::fft_butterfly4(re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign),
    }
}

/// Conjugate-aware half-spectrum convolution multiply — the RFFT
/// replacement for [`cmul`]. `zre`/`zim` hold the `h = n/2`-point spectrum
/// `Z` of a packed real row (`z[k] = x[2k] + i·x[2k+1]`); `kr`/`ki` hold
/// the kernel's half spectrum (`h + 1` bins, `ki[0] == ki[h] == 0` for a
/// real kernel). In one pass over conjugate pairs `(k, h-k)` this fuses:
/// the split recovering the real row's n-point half spectrum
/// `X[k] = Ze[k] + w_n^k·Zo[k]`, the pointwise multiply `X[k] *= K[k]`,
/// and the merge back to the packed spectrum `Z'` that the half-size
/// inverse FFT turns into the convolved row. Only `tw[k] = exp(-2πi k/n)`
/// for `k < h/2` is read (bins `0`, `h` and the middle bin fold their
/// twiddles analytically).
#[inline]
pub fn cmul_half(
    zre: &mut [f64],
    zim: &mut [f64],
    kr: &[f64],
    ki: &[f64],
    twr: &[f64],
    twi: &[f64],
) {
    let h = zre.len();
    assert!(h <= 1 || h % 2 == 0, "cmul_half needs even h (got {h})");
    assert_eq!(zim.len(), h);
    assert_eq!(kr.len(), h + 1);
    assert_eq!(ki.len(), h + 1);
    assert!(twr.len() >= h / 2 && twi.len() >= h / 2);
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::cmul_half_avx2(zre, zim, kr, ki, twr, twi) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::cmul_half_sse2(zre, zim, kr, ki, twr, twi) },
        _ => scalar::cmul_half(zre, zim, kr, ki, twr, twi),
    }
}

/// Conjugate-symmetric split: half spectrum `X` (bins `0..=h`) of a real
/// `n = 2h`-point row from the `h`-point spectrum `Z` of its packed form.
/// Construction/one-shot path only (the hot loop fuses the split into
/// [`cmul_half`]), so every tier runs the identical-result scalar body —
/// the same rule the f64 kernels follow on NEON.
#[inline]
pub fn rfft_split(
    zre: &[f64],
    zim: &[f64],
    xr: &mut [f64],
    xi: &mut [f64],
    twr: &[f64],
    twi: &[f64],
) {
    let h = zre.len();
    assert_eq!(zim.len(), h);
    assert_eq!(xr.len(), h + 1);
    assert_eq!(xi.len(), h + 1);
    assert!(twr.len() >= h / 2 && twi.len() >= h / 2); // only k < h/2 is read
    scalar::rfft_split(zre, zim, xr, xi, twr, twi);
}

/// Inverse of [`rfft_split`]: merge the half spectrum `X` back into the
/// packed `h`-point spectrum `Z` whose (scaled) inverse FFT is the real
/// row. Construction/one-shot path only; scalar body on every tier.
#[inline]
pub fn rfft_merge(
    xr: &[f64],
    xi: &[f64],
    zre: &mut [f64],
    zim: &mut [f64],
    twr: &[f64],
    twi: &[f64],
) {
    let h = zre.len();
    assert_eq!(zim.len(), h);
    assert_eq!(xr.len(), h + 1);
    assert_eq!(xi.len(), h + 1);
    assert!(twr.len() >= h / 2 && twi.len() >= h / 2); // only k < h/2 is read
    scalar::rfft_merge(xr, xi, zre, zim, twr, twi);
}

/// Pack the IEEE sign bits of `src` into `dst` words: bit `i % 64` of
/// `dst[i / 64]` is set iff `src[i]` is sign-negative — the same "bit set =
/// negative" convention as [`crate::transform::SignDiag`], and exactly
/// `f32::is_sign_negative` for every input including `-0.0` and negative
/// NaNs. Trailing bits of the last word are cleared. This is the
/// sign-quantization kernel of the binary embedding lane
/// (`binary::BinaryEmbedding`): on x86 a `movemask` sweep extracts 8 (AVX2)
/// or 4 (SSE2) sign bits per instruction, which reads precisely the sign
/// bit, so every tier is bit-identical by construction.
#[inline]
pub fn pack_signs(src: &[f32], dst: &mut [u64]) {
    assert_eq!(dst.len(), src.len().div_ceil(64));
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::pack_signs_avx2(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Sse2, so the
        // SSE2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Sse2 => unsafe { x86::pack_signs_sse2(src, dst) },
        _ => scalar::pack_signs(src, dst),
    }
}

/// Hamming distance between two packed bit vectors of equal word length:
/// `popcount(a ^ b)` summed over the words. The distance kernel of the
/// binary serving lane (packed codes from [`pack_signs`]). AVX2 runs the
/// nibble-LUT popcount (`vpshufb` + `vpsadbw`, 256 bits per step); the
/// SSE2 tier dispatches to the scalar `count_ones` loop (no byte shuffle
/// below SSSE3 — the same "identical-result fallback" rule the NEON f64
/// kernels use). Integer arithmetic, so every tier is trivially
/// bit-identical.
#[inline]
pub fn hamming(a: &[u64], b: &[u64]) -> u64 {
    assert_eq!(a.len(), b.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm runs only when `level()` resolved Avx2, so the
        // AVX2 target feature is present; slice preconditions are the
        // kernel's own documented contract, checked by the caller.
        Level::Avx2 => unsafe { x86::hamming_avx2(a, b) },
        _ => scalar::hamming(a, b),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference path (always compiled; the TS_NO_SIMD=1 lane and the
// per-op bit-identity oracle for the unit tests below)
// ---------------------------------------------------------------------------

pub(crate) mod scalar {
    #[inline]
    fn sign_mask(signs: &[u64], i: usize) -> u32 {
        (((signs[i >> 6] >> (i & 63)) & 1) as u32) << 31
    }

    pub fn butterfly(head: &mut [f32], tail: &mut [f32]) {
        for (u, v) in head.iter_mut().zip(tail.iter_mut()) {
            let a = *u;
            let b = *v;
            *u = a + b;
            *v = a - b;
        }
    }

    pub fn butterfly_scaled(head: &mut [f32], tail: &mut [f32], s: f32) {
        for (u, v) in head.iter_mut().zip(tail.iter_mut()) {
            let a = *u;
            let b = *v;
            *u = (a + b) * s;
            *v = (a - b) * s;
        }
    }

    pub fn scale(a: &mut [f32], d: &[f32]) {
        for (x, s) in a.iter_mut().zip(d) {
            *x *= *s;
        }
    }

    pub fn apply_signs(x: &mut [f32], signs: &[u64]) {
        for (i, v) in x.iter_mut().enumerate() {
            *v = f32::from_bits(v.to_bits() ^ sign_mask(signs, i));
        }
    }

    pub fn apply_signs_scaled(x: &mut [f32], signs: &[u64], s: f32) {
        for (i, v) in x.iter_mut().enumerate() {
            *v = f32::from_bits(v.to_bits() ^ sign_mask(signs, i)) * s;
        }
    }

    pub fn promote_signs_scaled(src: &[f32], signs: &[u64], s: f32, dst: &mut [f64]) {
        for (i, (v, o)) in src.iter().zip(dst.iter_mut()).enumerate() {
            *o = (f32::from_bits(v.to_bits() ^ sign_mask(signs, i)) * s) as f64;
        }
    }

    pub fn pack_signs(src: &[f32], dst: &mut [u64]) {
        dst.fill(0);
        for (i, v) in src.iter().enumerate() {
            dst[i >> 6] |= ((v.to_bits() >> 31) as u64) << (i & 63);
        }
    }

    pub fn hamming(a: &[u64], b: &[u64]) -> u64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x ^ y).count_ones() as u64)
            .sum()
    }

    pub fn cmul(re: &mut [f64], im: &mut [f64], kr: &[f64], ki: &[f64]) {
        for i in 0..re.len() {
            let (r, m) = (re[i] * kr[i] - im[i] * ki[i], re[i] * ki[i] + im[i] * kr[i]);
            re[i] = r;
            im[i] = m;
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn fft_butterfly(
        re_h: &mut [f64],
        im_h: &mut [f64],
        re_t: &mut [f64],
        im_t: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        for j in 0..re_h.len() {
            let wr = twr[j * stride];
            let wi = sign * twi[j * stride];
            let (ur, ui) = (re_h[j], im_h[j]);
            let (vr, vi) = (re_t[j] * wr - im_t[j] * wi, re_t[j] * wi + im_t[j] * wr);
            re_h[j] = ur + vr;
            im_h[j] = ui + vi;
            re_t[j] = ur - vr;
            im_t[j] = ui - vi;
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn fft_butterfly4(
        re0: &mut [f64],
        im0: &mut [f64],
        re1: &mut [f64],
        im1: &mut [f64],
        re2: &mut [f64],
        im2: &mut [f64],
        re3: &mut [f64],
        im3: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        fft_butterfly4_from(
            re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign, 0,
        );
    }

    /// [`fft_butterfly4`] starting at lane `j0` — the SIMD paths' tail
    /// cleanup. The twiddle indices `j, 2j, 3j` are affine in `j`, so the
    /// tail cannot simply rebase the twiddle slices the way the radix-2
    /// kernel does; it keeps absolute indexing instead.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fft_butterfly4_from(
        re0: &mut [f64],
        im0: &mut [f64],
        re1: &mut [f64],
        im1: &mut [f64],
        re2: &mut [f64],
        im2: &mut [f64],
        re3: &mut [f64],
        im3: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
        j0: usize,
    ) {
        for j in j0..re0.len() {
            let w1r = twr[j * stride];
            let w1i = sign * twi[j * stride];
            let w2r = twr[2 * j * stride];
            let w2i = sign * twi[2 * j * stride];
            let w3r = twr[3 * j * stride];
            let w3i = sign * twi[3 * j * stride];
            let (ar, ai) = (re0[j], im0[j]);
            // bit-reversed residue order: offset L holds the residue-2
            // sub-DFT, offset 2L the residue-1 one
            let (cr, ci) = (re1[j] * w2r - im1[j] * w2i, re1[j] * w2i + im1[j] * w2r);
            let (br, bi) = (re2[j] * w1r - im2[j] * w1i, re2[j] * w1i + im2[j] * w1r);
            let (dr, di) = (re3[j] * w3r - im3[j] * w3i, re3[j] * w3i + im3[j] * w3r);
            let (t0r, t0i) = (ar + cr, ai + ci);
            let (t1r, t1i) = (ar - cr, ai - ci);
            let (t2r, t2i) = (br + dr, bi + di);
            let (t3r, t3i) = (br - dr, bi - di);
            re0[j] = t0r + t2r;
            im0[j] = t0i + t2i;
            re2[j] = t0r - t2r;
            im2[j] = t0i - t2i;
            // X[j+L] = t1 - i·sign·t3, X[j+3L] = t1 + i·sign·t3
            re1[j] = t1r + sign * t3i;
            im1[j] = t1i - sign * t3r;
            re3[j] = t1r - sign * t3i;
            im3[j] = t1i + sign * t3r;
        }
    }

    /// The conjugate-pair body of [`cmul_half`] over `k` in `k0..k1`
    /// (paired with `h - k`): split → kernel multiply → merge, all from
    /// the single twiddle `w = tw[k]`. Shared by the SIMD paths as their
    /// head/tail cleanup so every tier performs the identical per-pair
    /// operations.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn cmul_half_pairs(
        zre: &mut [f64],
        zim: &mut [f64],
        kr: &[f64],
        ki: &[f64],
        twr: &[f64],
        twi: &[f64],
        k0: usize,
        k1: usize,
    ) {
        let h = zre.len();
        for k in k0..k1 {
            let j = h - k;
            let (wr, wi) = (twr[k], twi[k]);
            let (zkr, zki) = (zre[k], zim[k]);
            let (zjr, zji) = (zre[j], zim[j]);
            // split: Ze = (Z[k] + conj(Z[j]))/2, P = w^k·Zo with
            // Zo = (Z[k] - conj(Z[j]))/(2i)
            let er = 0.5 * (zkr + zjr);
            let ei = 0.5 * (zki - zji);
            let onr = 0.5 * (zki + zji);
            let oni = 0.5 * (zjr - zkr);
            let pr = onr * wr - oni * wi;
            let pi = onr * wi + oni * wr;
            // X[k] = Ze + P ; X[j] = conj(Ze - P)
            let (xkr, xki) = (er + pr, ei + pi);
            let (xjr, xji) = (er - pr, pi - ei);
            // pointwise kernel multiply on both bins of the pair
            let (ykr, yki) = (xkr * kr[k] - xki * ki[k], xkr * ki[k] + xki * kr[k]);
            let (yjr, yji) = (xjr * kr[j] - xji * ki[j], xjr * ki[j] + xji * kr[j]);
            // merge: E = (Yk + conj(Yj))/2, Q = conj(w^k)·(Yk - conj(Yj))/2
            let epr = 0.5 * (ykr + yjr);
            let epi = 0.5 * (yki - yji);
            let dr = 0.5 * (ykr - yjr);
            let di = 0.5 * (yki + yji);
            let qr = dr * wr + di * wi;
            let qi = di * wr - dr * wi;
            // Z'[k] = E + i·Q ; Z'[j] = conj(E) + i·conj(Q)
            zre[k] = epr - qi;
            zim[k] = epi + qr;
            zre[j] = epr + qi;
            zim[j] = qr - epi;
        }
    }

    /// The twiddle-free ends of the half-spectrum multiply: bins `0` and
    /// `h` (both real combinations of `Z[0]`, `w^0 = 1`) and — when `h` is
    /// even and positive — the self-paired middle bin (`w^{h/2} = -i`
    /// folded analytically: `X = conj(Z)`, `Z' = conj(X·K)`).
    pub(crate) fn cmul_half_ends(zre: &mut [f64], zim: &mut [f64], kr: &[f64], ki: &[f64]) {
        let h = zre.len();
        if h == 0 {
            return;
        }
        let (r0, i0) = (zre[0], zim[0]);
        let x0 = r0 + i0; // X[0], real
        let xh = r0 - i0; // X[h], real
        let (y0r, y0i) = (x0 * kr[0], x0 * ki[0]);
        let (yhr, yhi) = (xh * kr[h], xh * ki[h]);
        let (er, ei) = (0.5 * (y0r + yhr), 0.5 * (y0i - yhi));
        let (dr, di) = (0.5 * (y0r - yhr), 0.5 * (y0i + yhi));
        zre[0] = er - di;
        zim[0] = ei + dr;
        if h >= 2 {
            let m = h / 2;
            let (xr, xi) = (zre[m], -zim[m]);
            let (yr, yi) = (xr * kr[m] - xi * ki[m], xr * ki[m] + xi * kr[m]);
            zre[m] = yr;
            zim[m] = -yi;
        }
    }

    pub fn cmul_half(
        zre: &mut [f64],
        zim: &mut [f64],
        kr: &[f64],
        ki: &[f64],
        twr: &[f64],
        twi: &[f64],
    ) {
        let h = zre.len();
        cmul_half_ends(zre, zim, kr, ki);
        cmul_half_pairs(zre, zim, kr, ki, twr, twi, 1, h / 2);
    }

    pub fn rfft_split(
        zre: &[f64],
        zim: &[f64],
        xr: &mut [f64],
        xi: &mut [f64],
        twr: &[f64],
        twi: &[f64],
    ) {
        let h = zre.len();
        if h == 0 {
            return;
        }
        xr[0] = zre[0] + zim[0];
        xi[0] = 0.0;
        xr[h] = zre[0] - zim[0];
        xi[h] = 0.0;
        if h >= 2 {
            let m = h / 2;
            xr[m] = zre[m];
            xi[m] = -zim[m];
        }
        for k in 1..h / 2 {
            let j = h - k;
            let (wr, wi) = (twr[k], twi[k]);
            let (zkr, zki) = (zre[k], zim[k]);
            let (zjr, zji) = (zre[j], zim[j]);
            let er = 0.5 * (zkr + zjr);
            let ei = 0.5 * (zki - zji);
            let onr = 0.5 * (zki + zji);
            let oni = 0.5 * (zjr - zkr);
            let pr = onr * wr - oni * wi;
            let pi = onr * wi + oni * wr;
            xr[k] = er + pr;
            xi[k] = ei + pi;
            xr[j] = er - pr;
            xi[j] = pi - ei;
        }
    }

    pub fn rfft_merge(
        xr: &[f64],
        xi: &[f64],
        zre: &mut [f64],
        zim: &mut [f64],
        twr: &[f64],
        twi: &[f64],
    ) {
        let h = zre.len();
        if h == 0 {
            return;
        }
        // pair (0, h): w^0 = 1
        let (er, ei) = (0.5 * (xr[0] + xr[h]), 0.5 * (xi[0] - xi[h]));
        let (dr, di) = (0.5 * (xr[0] - xr[h]), 0.5 * (xi[0] + xi[h]));
        zre[0] = er - di;
        zim[0] = ei + dr;
        if h >= 2 {
            let m = h / 2;
            zre[m] = xr[m];
            zim[m] = -xi[m];
        }
        for k in 1..h / 2 {
            let j = h - k;
            let (wr, wi) = (twr[k], twi[k]);
            let epr = 0.5 * (xr[k] + xr[j]);
            let epi = 0.5 * (xi[k] - xi[j]);
            let dr = 0.5 * (xr[k] - xr[j]);
            let di = 0.5 * (xi[k] + xi[j]);
            let qr = dr * wr + di * wi;
            let qi = di * wr - dr * wi;
            zre[k] = epr - qi;
            zim[k] = epi + qr;
            zre[j] = epr + qi;
            zim[j] = qr - epi;
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64: SSE2 (baseline) and AVX2
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::scalar;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    // --- f32 butterflies ---

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_avx2(head: &mut [f32], tail: &mut [f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let mut i = 0;
            while i + 8 <= n {
                let a = _mm256_loadu_ps(head.as_ptr().add(i));
                let b = _mm256_loadu_ps(tail.as_ptr().add(i));
                _mm256_storeu_ps(head.as_mut_ptr().add(i), _mm256_add_ps(a, b));
                _mm256_storeu_ps(tail.as_mut_ptr().add(i), _mm256_sub_ps(a, b));
                i += 8;
            }
            scalar::butterfly(&mut head[i..], &mut tail[i..]);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_sse2(head: &mut [f32], tail: &mut [f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let mut i = 0;
            while i + 4 <= n {
                let a = _mm_loadu_ps(head.as_ptr().add(i));
                let b = _mm_loadu_ps(tail.as_ptr().add(i));
                _mm_storeu_ps(head.as_mut_ptr().add(i), _mm_add_ps(a, b));
                _mm_storeu_ps(tail.as_mut_ptr().add(i), _mm_sub_ps(a, b));
                i += 4;
            }
            scalar::butterfly(&mut head[i..], &mut tail[i..]);
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_scaled_avx2(head: &mut [f32], tail: &mut [f32], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let sv = _mm256_set1_ps(s);
            let mut i = 0;
            while i + 8 <= n {
                let a = _mm256_loadu_ps(head.as_ptr().add(i));
                let b = _mm256_loadu_ps(tail.as_ptr().add(i));
                _mm256_storeu_ps(head.as_mut_ptr().add(i), _mm256_mul_ps(_mm256_add_ps(a, b), sv));
                _mm256_storeu_ps(tail.as_mut_ptr().add(i), _mm256_mul_ps(_mm256_sub_ps(a, b), sv));
                i += 8;
            }
            scalar::butterfly_scaled(&mut head[i..], &mut tail[i..], s);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_scaled_sse2(head: &mut [f32], tail: &mut [f32], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i + 4 <= n {
                let a = _mm_loadu_ps(head.as_ptr().add(i));
                let b = _mm_loadu_ps(tail.as_ptr().add(i));
                _mm_storeu_ps(head.as_mut_ptr().add(i), _mm_mul_ps(_mm_add_ps(a, b), sv));
                _mm_storeu_ps(tail.as_mut_ptr().add(i), _mm_mul_ps(_mm_sub_ps(a, b), sv));
                i += 4;
            }
            scalar::butterfly_scaled(&mut head[i..], &mut tail[i..], s);
        }
    }

    // --- f32 elementwise scale ---

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn scale_avx2(a: &mut [f32], d: &[f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = a.len();
            let mut i = 0;
            while i + 8 <= n {
                let x = _mm256_loadu_ps(a.as_ptr().add(i));
                let s = _mm256_loadu_ps(d.as_ptr().add(i));
                _mm256_storeu_ps(a.as_mut_ptr().add(i), _mm256_mul_ps(x, s));
                i += 8;
            }
            scalar::scale(&mut a[i..], &d[i..]);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn scale_sse2(a: &mut [f32], d: &[f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = a.len();
            let mut i = 0;
            while i + 4 <= n {
                let x = _mm_loadu_ps(a.as_ptr().add(i));
                let s = _mm_loadu_ps(d.as_ptr().add(i));
                _mm_storeu_ps(a.as_mut_ptr().add(i), _mm_mul_ps(x, s));
                i += 4;
            }
            scalar::scale(&mut a[i..], &d[i..]);
        }
    }

    // --- packed-sign application ---

    /// byte → 8-lane f32 sign-bit masks (lane `l` = `0x8000_0000` iff bit
    /// `l` of the byte is set), built at compile time. 8 KiB; the lower 4
    /// lanes of entries 0..16 double as the SSE2 nibble table. A LUT load
    /// replaces the `set1 + sllv + and` expansion, which measured ~2.5x
    /// slower (it bottlenecked the whole sign pass below the f32 multiply
    /// it was meant to beat — see the diag_micro bench entry).
    static SIGN_LUT: [[u32; 8]; 256] = build_sign_lut();

    const fn build_sign_lut() -> [[u32; 8]; 256] {
        let mut lut = [[0u32; 8]; 256];
        let mut b = 0;
        while b < 256 {
            let mut l = 0;
            while l < 8 {
                lut[b][l] = (((b >> l) & 1) as u32) << 31;
                l += 1;
            }
            b += 1;
        }
        lut
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn xor_byte_mask_avx2(p: *mut f32, byte: usize) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let mask = _mm256_loadu_si256(SIGN_LUT[byte].as_ptr() as *const __m256i);
            _mm256_storeu_ps(p, _mm256_xor_ps(_mm256_loadu_ps(p), _mm256_castsi256_ps(mask)));
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_avx2(x: &mut [f32], signs: &[u64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let mut i = 0;
            // word-hoisted main loop: one sign word feeds eight 8-lane XORs
            while i + 64 <= n {
                let word = signs[i >> 6];
                let mut k = 0;
                while k < 8 {
                    let byte = ((word >> (8 * k)) & 0xFF) as usize;
                    xor_byte_mask_avx2(x.as_mut_ptr().add(i + 8 * k), byte);
                    k += 1;
                }
                i += 64;
            }
            while i + 8 <= n {
                let byte = ((signs[i >> 6] >> (i & 63)) & 0xFF) as usize;
                xor_byte_mask_avx2(x.as_mut_ptr().add(i), byte);
                i += 8;
            }
            scalar::apply_signs(&mut x[i..], &shifted_signs(signs, i));
        }
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn xor_byte_mask_scaled_avx2(p: *mut f32, byte: usize, sv: __m256) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let mask = _mm256_loadu_si256(SIGN_LUT[byte].as_ptr() as *const __m256i);
            let flipped = _mm256_xor_ps(_mm256_loadu_ps(p), _mm256_castsi256_ps(mask));
            _mm256_storeu_ps(p, _mm256_mul_ps(flipped, sv));
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_scaled_avx2(x: &mut [f32], signs: &[u64], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let sv = _mm256_set1_ps(s);
            let mut i = 0;
            while i + 64 <= n {
                let word = signs[i >> 6];
                let mut k = 0;
                while k < 8 {
                    xor_byte_mask_scaled_avx2(
                        x.as_mut_ptr().add(i + 8 * k),
                        ((word >> (8 * k)) & 0xFF) as usize,
                        sv,
                    );
                    k += 1;
                }
                i += 64;
            }
            while i + 8 <= n {
                let byte = ((signs[i >> 6] >> (i & 63)) & 0xFF) as usize;
                xor_byte_mask_scaled_avx2(x.as_mut_ptr().add(i), byte, sv);
                i += 8;
            }
            scalar::apply_signs_scaled(&mut x[i..], &shifted_signs(signs, i), s);
        }
    }

    /// 4-lane sign mask for bits `[i, i+4)`: the nibble indexes the shared
    /// LUT (whose upper four lanes are zero for entries < 16).
    #[target_feature(enable = "sse2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn quad_sign_mask_sse2(signs: &[u64], i: usize) -> __m128 {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let nib = ((signs[i >> 6] >> (i & 63)) & 0xF) as usize;
            _mm_castsi128_ps(_mm_loadu_si128(SIGN_LUT[nib].as_ptr() as *const __m128i))
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_sse2(x: &mut [f32], signs: &[u64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask_sse2(signs, i);
                let v = _mm_loadu_ps(x.as_ptr().add(i));
                _mm_storeu_ps(x.as_mut_ptr().add(i), _mm_xor_ps(v, mask));
                i += 4;
            }
            scalar::apply_signs(&mut x[i..], &shifted_signs(signs, i));
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_scaled_sse2(x: &mut [f32], signs: &[u64], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask_sse2(signs, i);
                let v = _mm_loadu_ps(x.as_ptr().add(i));
                _mm_storeu_ps(x.as_mut_ptr().add(i), _mm_mul_ps(_mm_xor_ps(v, mask), sv));
                i += 4;
            }
            scalar::apply_signs_scaled(&mut x[i..], &shifted_signs(signs, i), s);
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn promote_signs_scaled_avx2(
        src: &[f32],
        signs: &[u64],
        s: f32,
        dst: &mut [f64],
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = src.len();
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask_sse2(signs, i);
                let v = _mm_loadu_ps(src.as_ptr().add(i));
                let scaled = _mm_mul_ps(_mm_xor_ps(v, mask), sv);
                _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_cvtps_pd(scaled));
                i += 4;
            }
            scalar::promote_signs_scaled(&src[i..], &shifted_signs(signs, i), s, &mut dst[i..]);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn promote_signs_scaled_sse2(
        src: &[f32],
        signs: &[u64],
        s: f32,
        dst: &mut [f64],
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = src.len();
            let sv = _mm_set1_ps(s);
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask_sse2(signs, i);
                let v = _mm_loadu_ps(src.as_ptr().add(i));
                let scaled = _mm_mul_ps(_mm_xor_ps(v, mask), sv);
                _mm_storeu_pd(dst.as_mut_ptr().add(i), _mm_cvtps_pd(scaled));
                _mm_storeu_pd(
                    dst.as_mut_ptr().add(i + 2),
                    _mm_cvtps_pd(_mm_movehl_ps(scaled, scaled)),
                );
                i += 4;
            }
            scalar::promote_signs_scaled(&src[i..], &shifted_signs(signs, i), s, &mut dst[i..]);
        }
    }

    /// Rebase a packed sign stream so the scalar tail sees its bits from
    /// index 0. Every caller's tail starts at a multiple of the vector
    /// width with fewer than 8 elements left, so `(i % 64) + tail_len <=
    /// 64` always holds — the whole tail lives in one word. `None` only
    /// when the tail is empty (the scalar fns then never read the word).
    fn shifted_signs(signs: &[u64], i: usize) -> [u64; 1] {
        match signs.get(i >> 6) {
            Some(w) => [w >> (i & 63)],
            None => [0],
        }
    }

    // --- sign quantization + Hamming popcount (the binary embedding lane) ---

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn pack_signs_avx2(src: &[f32], dst: &mut [u64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let full_words = src.len() / 64;
            for (w, slot) in dst[..full_words].iter_mut().enumerate() {
                // eight movemasks assemble one sign word; movemask reads the
                // IEEE sign bit, matching is_sign_negative for every value
                let mut word = 0u64;
                let mut k = 0;
                while k < 64 {
                    let v = _mm256_loadu_ps(src.as_ptr().add(w * 64 + k));
                    word |= (_mm256_movemask_ps(v) as u32 as u64) << k;
                    k += 8;
                }
                *slot = word;
            }
            scalar::pack_signs(&src[full_words * 64..], &mut dst[full_words..]);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn pack_signs_sse2(src: &[f32], dst: &mut [u64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let full_words = src.len() / 64;
            for (w, slot) in dst[..full_words].iter_mut().enumerate() {
                let mut word = 0u64;
                let mut k = 0;
                while k < 64 {
                    let v = _mm_loadu_ps(src.as_ptr().add(w * 64 + k));
                    word |= (_mm_movemask_ps(v) as u32 as u64) << k;
                    k += 4;
                }
                *slot = word;
            }
            scalar::pack_signs(&src[full_words * 64..], &mut dst[full_words..]);
        }
    }

    /// Nibble-LUT popcount over the XOR stream: `vpshufb` looks up per-byte
    /// bit counts for both nibbles, `vpsadbw` folds the 32 byte counts into
    /// four u64 lanes. Exact integer arithmetic — identical to the scalar
    /// `count_ones` loop by construction.
    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn hamming_avx2(a: &[u64], b: &[u64]) -> u64 {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = a.len();
            #[rustfmt::skip]
            let lut = _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            );
            let low_mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let mut acc = zero;
            let mut i = 0;
            while i + 4 <= n {
                let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
                let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
                let x = _mm256_xor_si256(va, vb);
                let lo = _mm256_and_si256(x, low_mask);
                let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low_mask);
                let cnt =
                    _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
                i += 4;
            }
            let mut lanes = [0u64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            lanes.iter().sum::<u64>() + scalar::hamming(&a[i..], &b[i..])
        }
    }

    // --- f64 complex kernels ---

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn cmul_avx2(re: &mut [f64], im: &mut [f64], kr: &[f64], ki: &[f64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = re.len();
            let mut i = 0;
            while i + 4 <= n {
                let a = _mm256_loadu_pd(re.as_ptr().add(i));
                let b = _mm256_loadu_pd(im.as_ptr().add(i));
                let cr = _mm256_loadu_pd(kr.as_ptr().add(i));
                let ci = _mm256_loadu_pd(ki.as_ptr().add(i));
                let r = _mm256_sub_pd(_mm256_mul_pd(a, cr), _mm256_mul_pd(b, ci));
                let m = _mm256_add_pd(_mm256_mul_pd(a, ci), _mm256_mul_pd(b, cr));
                _mm256_storeu_pd(re.as_mut_ptr().add(i), r);
                _mm256_storeu_pd(im.as_mut_ptr().add(i), m);
                i += 4;
            }
            scalar::cmul(&mut re[i..], &mut im[i..], &kr[i..], &ki[i..]);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn cmul_sse2(re: &mut [f64], im: &mut [f64], kr: &[f64], ki: &[f64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = re.len();
            let mut i = 0;
            while i + 2 <= n {
                let a = _mm_loadu_pd(re.as_ptr().add(i));
                let b = _mm_loadu_pd(im.as_ptr().add(i));
                let cr = _mm_loadu_pd(kr.as_ptr().add(i));
                let ci = _mm_loadu_pd(ki.as_ptr().add(i));
                let r = _mm_sub_pd(_mm_mul_pd(a, cr), _mm_mul_pd(b, ci));
                let m = _mm_add_pd(_mm_mul_pd(a, ci), _mm_mul_pd(b, cr));
                _mm_storeu_pd(re.as_mut_ptr().add(i), r);
                _mm_storeu_pd(im.as_mut_ptr().add(i), m);
                i += 2;
            }
            scalar::cmul(&mut re[i..], &mut im[i..], &kr[i..], &ki[i..]);
        }
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn fft_butterfly_avx2(
        re_h: &mut [f64],
        im_h: &mut [f64],
        re_t: &mut [f64],
        im_t: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let half = re_h.len();
            let sv = _mm256_set1_pd(sign);
            let mut j = 0;
            while j + 4 <= half {
                let (wr, wi_raw) = if stride == 1 {
                    (
                        _mm256_loadu_pd(twr.as_ptr().add(j)),
                        _mm256_loadu_pd(twi.as_ptr().add(j)),
                    )
                } else {
                    (
                        _mm256_setr_pd(
                            twr[j * stride],
                            twr[(j + 1) * stride],
                            twr[(j + 2) * stride],
                            twr[(j + 3) * stride],
                        ),
                        _mm256_setr_pd(
                            twi[j * stride],
                            twi[(j + 1) * stride],
                            twi[(j + 2) * stride],
                            twi[(j + 3) * stride],
                        ),
                    )
                };
                let wi = _mm256_mul_pd(sv, wi_raw);
                let ur = _mm256_loadu_pd(re_h.as_ptr().add(j));
                let ui = _mm256_loadu_pd(im_h.as_ptr().add(j));
                let tr = _mm256_loadu_pd(re_t.as_ptr().add(j));
                let ti = _mm256_loadu_pd(im_t.as_ptr().add(j));
                let vr = _mm256_sub_pd(_mm256_mul_pd(tr, wr), _mm256_mul_pd(ti, wi));
                let vi = _mm256_add_pd(_mm256_mul_pd(tr, wi), _mm256_mul_pd(ti, wr));
                _mm256_storeu_pd(re_h.as_mut_ptr().add(j), _mm256_add_pd(ur, vr));
                _mm256_storeu_pd(im_h.as_mut_ptr().add(j), _mm256_add_pd(ui, vi));
                _mm256_storeu_pd(re_t.as_mut_ptr().add(j), _mm256_sub_pd(ur, vr));
                _mm256_storeu_pd(im_t.as_mut_ptr().add(j), _mm256_sub_pd(ui, vi));
                j += 4;
            }
            if j < half {
                scalar::fft_butterfly(
                    &mut re_h[j..],
                    &mut im_h[j..],
                    &mut re_t[j..],
                    &mut im_t[j..],
                    &twr[j * stride..],
                    &twi[j * stride..],
                    stride,
                    sign,
                );
            }
        }
    }

    /// 4 twiddles at `(j..j+4)·stride`; contiguous load when `stride == 1`.
    #[target_feature(enable = "avx2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn tw_gather4(t: &[f64], stride: usize, j: usize) -> __m256d {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            if stride == 1 {
                _mm256_loadu_pd(t.as_ptr().add(j))
            } else {
                _mm256_setr_pd(
                    t[j * stride],
                    t[(j + 1) * stride],
                    t[(j + 2) * stride],
                    t[(j + 3) * stride],
                )
            }
        }
    }

    #[target_feature(enable = "sse2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn tw_gather2(t: &[f64], stride: usize, j: usize) -> __m128d {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            if stride == 1 {
                _mm_loadu_pd(t.as_ptr().add(j))
            } else {
                _mm_setr_pd(t[j * stride], t[(j + 1) * stride])
            }
        }
    }

    /// Reversed 4-lane load: lanes `[p[3], p[2], p[1], p[0]]` — the
    /// descending `h - k` side of a conjugate-pair walk.
    #[target_feature(enable = "avx2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn rev_load4(p: *const f64) -> __m256d {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            _mm256_permute4x64_pd::<0x1B>(_mm256_loadu_pd(p))
        }
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn rev_store4(p: *mut f64, v: __m256d) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            _mm256_storeu_pd(p, _mm256_permute4x64_pd::<0x1B>(v));
        }
    }

    #[target_feature(enable = "sse2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn rev_load2(p: *const f64) -> __m128d {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let v = _mm_loadu_pd(p);
            _mm_shuffle_pd::<0b01>(v, v)
        }
    }

    #[target_feature(enable = "sse2")]
    #[inline]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn rev_store2(p: *mut f64, v: __m128d) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            _mm_storeu_pd(p, _mm_shuffle_pd::<0b01>(v, v));
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn cmul_half_avx2(
        zre: &mut [f64],
        zim: &mut [f64],
        kr: &[f64],
        ki: &[f64],
        twr: &[f64],
        twi: &[f64],
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let h = zre.len();
            scalar::cmul_half_ends(zre, zim, kr, ki);
            let k1 = h / 2;
            let half = _mm256_set1_pd(0.5);
            let mut k = 1usize;
            while k + 4 <= k1 {
                let jb = h - k - 3; // memory base of the descending j = h-k side
                let wr = _mm256_loadu_pd(twr.as_ptr().add(k));
                let wi = _mm256_loadu_pd(twi.as_ptr().add(k));
                let zkr = _mm256_loadu_pd(zre.as_ptr().add(k));
                let zki = _mm256_loadu_pd(zim.as_ptr().add(k));
                let zjr = rev_load4(zre.as_ptr().add(jb));
                let zji = rev_load4(zim.as_ptr().add(jb));
                let er = _mm256_mul_pd(half, _mm256_add_pd(zkr, zjr));
                let ei = _mm256_mul_pd(half, _mm256_sub_pd(zki, zji));
                let onr = _mm256_mul_pd(half, _mm256_add_pd(zki, zji));
                let oni = _mm256_mul_pd(half, _mm256_sub_pd(zjr, zkr));
                let pr = _mm256_sub_pd(_mm256_mul_pd(onr, wr), _mm256_mul_pd(oni, wi));
                let pi = _mm256_add_pd(_mm256_mul_pd(onr, wi), _mm256_mul_pd(oni, wr));
                let xkr = _mm256_add_pd(er, pr);
                let xki = _mm256_add_pd(ei, pi);
                let xjr = _mm256_sub_pd(er, pr);
                let xji = _mm256_sub_pd(pi, ei);
                let kkr = _mm256_loadu_pd(kr.as_ptr().add(k));
                let kki = _mm256_loadu_pd(ki.as_ptr().add(k));
                let kjr = rev_load4(kr.as_ptr().add(jb));
                let kji = rev_load4(ki.as_ptr().add(jb));
                let ykr = _mm256_sub_pd(_mm256_mul_pd(xkr, kkr), _mm256_mul_pd(xki, kki));
                let yki = _mm256_add_pd(_mm256_mul_pd(xkr, kki), _mm256_mul_pd(xki, kkr));
                let yjr = _mm256_sub_pd(_mm256_mul_pd(xjr, kjr), _mm256_mul_pd(xji, kji));
                let yji = _mm256_add_pd(_mm256_mul_pd(xjr, kji), _mm256_mul_pd(xji, kjr));
                let epr = _mm256_mul_pd(half, _mm256_add_pd(ykr, yjr));
                let epi = _mm256_mul_pd(half, _mm256_sub_pd(yki, yji));
                let dr = _mm256_mul_pd(half, _mm256_sub_pd(ykr, yjr));
                let di = _mm256_mul_pd(half, _mm256_add_pd(yki, yji));
                let qr = _mm256_add_pd(_mm256_mul_pd(dr, wr), _mm256_mul_pd(di, wi));
                let qi = _mm256_sub_pd(_mm256_mul_pd(di, wr), _mm256_mul_pd(dr, wi));
                _mm256_storeu_pd(zre.as_mut_ptr().add(k), _mm256_sub_pd(epr, qi));
                _mm256_storeu_pd(zim.as_mut_ptr().add(k), _mm256_add_pd(epi, qr));
                rev_store4(zre.as_mut_ptr().add(jb), _mm256_add_pd(epr, qi));
                rev_store4(zim.as_mut_ptr().add(jb), _mm256_sub_pd(qr, epi));
                k += 4;
            }
            scalar::cmul_half_pairs(zre, zim, kr, ki, twr, twi, k, k1);
        }
    }

    #[target_feature(enable = "sse2")]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn cmul_half_sse2(
        zre: &mut [f64],
        zim: &mut [f64],
        kr: &[f64],
        ki: &[f64],
        twr: &[f64],
        twi: &[f64],
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let h = zre.len();
            scalar::cmul_half_ends(zre, zim, kr, ki);
            let k1 = h / 2;
            let half = _mm_set1_pd(0.5);
            let mut k = 1usize;
            while k + 2 <= k1 {
                let jb = h - k - 1;
                let wr = _mm_loadu_pd(twr.as_ptr().add(k));
                let wi = _mm_loadu_pd(twi.as_ptr().add(k));
                let zkr = _mm_loadu_pd(zre.as_ptr().add(k));
                let zki = _mm_loadu_pd(zim.as_ptr().add(k));
                let zjr = rev_load2(zre.as_ptr().add(jb));
                let zji = rev_load2(zim.as_ptr().add(jb));
                let er = _mm_mul_pd(half, _mm_add_pd(zkr, zjr));
                let ei = _mm_mul_pd(half, _mm_sub_pd(zki, zji));
                let onr = _mm_mul_pd(half, _mm_add_pd(zki, zji));
                let oni = _mm_mul_pd(half, _mm_sub_pd(zjr, zkr));
                let pr = _mm_sub_pd(_mm_mul_pd(onr, wr), _mm_mul_pd(oni, wi));
                let pi = _mm_add_pd(_mm_mul_pd(onr, wi), _mm_mul_pd(oni, wr));
                let xkr = _mm_add_pd(er, pr);
                let xki = _mm_add_pd(ei, pi);
                let xjr = _mm_sub_pd(er, pr);
                let xji = _mm_sub_pd(pi, ei);
                let kkr = _mm_loadu_pd(kr.as_ptr().add(k));
                let kki = _mm_loadu_pd(ki.as_ptr().add(k));
                let kjr = rev_load2(kr.as_ptr().add(jb));
                let kji = rev_load2(ki.as_ptr().add(jb));
                let ykr = _mm_sub_pd(_mm_mul_pd(xkr, kkr), _mm_mul_pd(xki, kki));
                let yki = _mm_add_pd(_mm_mul_pd(xkr, kki), _mm_mul_pd(xki, kkr));
                let yjr = _mm_sub_pd(_mm_mul_pd(xjr, kjr), _mm_mul_pd(xji, kji));
                let yji = _mm_add_pd(_mm_mul_pd(xjr, kji), _mm_mul_pd(xji, kjr));
                let epr = _mm_mul_pd(half, _mm_add_pd(ykr, yjr));
                let epi = _mm_mul_pd(half, _mm_sub_pd(yki, yji));
                let dr = _mm_mul_pd(half, _mm_sub_pd(ykr, yjr));
                let di = _mm_mul_pd(half, _mm_add_pd(yki, yji));
                let qr = _mm_add_pd(_mm_mul_pd(dr, wr), _mm_mul_pd(di, wi));
                let qi = _mm_sub_pd(_mm_mul_pd(di, wr), _mm_mul_pd(dr, wi));
                _mm_storeu_pd(zre.as_mut_ptr().add(k), _mm_sub_pd(epr, qi));
                _mm_storeu_pd(zim.as_mut_ptr().add(k), _mm_add_pd(epi, qr));
                rev_store2(zre.as_mut_ptr().add(jb), _mm_add_pd(epr, qi));
                rev_store2(zim.as_mut_ptr().add(jb), _mm_sub_pd(qr, epi));
                k += 2;
            }
            scalar::cmul_half_pairs(zre, zim, kr, ki, twr, twi, k, k1);
        }
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: contract — the executing CPU must support AVX2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn fft_butterfly4_avx2(
        re0: &mut [f64],
        im0: &mut [f64],
        re1: &mut [f64],
        im1: &mut [f64],
        re2: &mut [f64],
        im2: &mut [f64],
        re3: &mut [f64],
        im3: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let l = re0.len();
            let sv = _mm256_set1_pd(sign);
            let mut j = 0;
            while j + 4 <= l {
                let w1r = tw_gather4(twr, stride, j);
                let w1i = _mm256_mul_pd(sv, tw_gather4(twi, stride, j));
                let w2r = tw_gather4(twr, 2 * stride, j);
                let w2i = _mm256_mul_pd(sv, tw_gather4(twi, 2 * stride, j));
                let w3r = tw_gather4(twr, 3 * stride, j);
                let w3i = _mm256_mul_pd(sv, tw_gather4(twi, 3 * stride, j));
                let ar = _mm256_loadu_pd(re0.as_ptr().add(j));
                let ai = _mm256_loadu_pd(im0.as_ptr().add(j));
                let q1r = _mm256_loadu_pd(re1.as_ptr().add(j));
                let q1i = _mm256_loadu_pd(im1.as_ptr().add(j));
                let q2r = _mm256_loadu_pd(re2.as_ptr().add(j));
                let q2i = _mm256_loadu_pd(im2.as_ptr().add(j));
                let q3r = _mm256_loadu_pd(re3.as_ptr().add(j));
                let q3i = _mm256_loadu_pd(im3.as_ptr().add(j));
                let cr = _mm256_sub_pd(_mm256_mul_pd(q1r, w2r), _mm256_mul_pd(q1i, w2i));
                let ci = _mm256_add_pd(_mm256_mul_pd(q1r, w2i), _mm256_mul_pd(q1i, w2r));
                let br = _mm256_sub_pd(_mm256_mul_pd(q2r, w1r), _mm256_mul_pd(q2i, w1i));
                let bi = _mm256_add_pd(_mm256_mul_pd(q2r, w1i), _mm256_mul_pd(q2i, w1r));
                let dr = _mm256_sub_pd(_mm256_mul_pd(q3r, w3r), _mm256_mul_pd(q3i, w3i));
                let di = _mm256_add_pd(_mm256_mul_pd(q3r, w3i), _mm256_mul_pd(q3i, w3r));
                let t0r = _mm256_add_pd(ar, cr);
                let t0i = _mm256_add_pd(ai, ci);
                let t1r = _mm256_sub_pd(ar, cr);
                let t1i = _mm256_sub_pd(ai, ci);
                let t2r = _mm256_add_pd(br, dr);
                let t2i = _mm256_add_pd(bi, di);
                let t3r = _mm256_mul_pd(sv, _mm256_sub_pd(br, dr));
                let t3i = _mm256_mul_pd(sv, _mm256_sub_pd(bi, di));
                _mm256_storeu_pd(re0.as_mut_ptr().add(j), _mm256_add_pd(t0r, t2r));
                _mm256_storeu_pd(im0.as_mut_ptr().add(j), _mm256_add_pd(t0i, t2i));
                _mm256_storeu_pd(re2.as_mut_ptr().add(j), _mm256_sub_pd(t0r, t2r));
                _mm256_storeu_pd(im2.as_mut_ptr().add(j), _mm256_sub_pd(t0i, t2i));
                _mm256_storeu_pd(re1.as_mut_ptr().add(j), _mm256_add_pd(t1r, t3i));
                _mm256_storeu_pd(im1.as_mut_ptr().add(j), _mm256_sub_pd(t1i, t3r));
                _mm256_storeu_pd(re3.as_mut_ptr().add(j), _mm256_sub_pd(t1r, t3i));
                _mm256_storeu_pd(im3.as_mut_ptr().add(j), _mm256_add_pd(t1i, t3r));
                j += 4;
            }
            if j < l {
                scalar::fft_butterfly4_from(
                    re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign, j,
                );
            }
        }
    }

    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn fft_butterfly4_sse2(
        re0: &mut [f64],
        im0: &mut [f64],
        re1: &mut [f64],
        im1: &mut [f64],
        re2: &mut [f64],
        im2: &mut [f64],
        re3: &mut [f64],
        im3: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let l = re0.len();
            let sv = _mm_set1_pd(sign);
            let mut j = 0;
            while j + 2 <= l {
                let w1r = tw_gather2(twr, stride, j);
                let w1i = _mm_mul_pd(sv, tw_gather2(twi, stride, j));
                let w2r = tw_gather2(twr, 2 * stride, j);
                let w2i = _mm_mul_pd(sv, tw_gather2(twi, 2 * stride, j));
                let w3r = tw_gather2(twr, 3 * stride, j);
                let w3i = _mm_mul_pd(sv, tw_gather2(twi, 3 * stride, j));
                let ar = _mm_loadu_pd(re0.as_ptr().add(j));
                let ai = _mm_loadu_pd(im0.as_ptr().add(j));
                let q1r = _mm_loadu_pd(re1.as_ptr().add(j));
                let q1i = _mm_loadu_pd(im1.as_ptr().add(j));
                let q2r = _mm_loadu_pd(re2.as_ptr().add(j));
                let q2i = _mm_loadu_pd(im2.as_ptr().add(j));
                let q3r = _mm_loadu_pd(re3.as_ptr().add(j));
                let q3i = _mm_loadu_pd(im3.as_ptr().add(j));
                let cr = _mm_sub_pd(_mm_mul_pd(q1r, w2r), _mm_mul_pd(q1i, w2i));
                let ci = _mm_add_pd(_mm_mul_pd(q1r, w2i), _mm_mul_pd(q1i, w2r));
                let br = _mm_sub_pd(_mm_mul_pd(q2r, w1r), _mm_mul_pd(q2i, w1i));
                let bi = _mm_add_pd(_mm_mul_pd(q2r, w1i), _mm_mul_pd(q2i, w1r));
                let dr = _mm_sub_pd(_mm_mul_pd(q3r, w3r), _mm_mul_pd(q3i, w3i));
                let di = _mm_add_pd(_mm_mul_pd(q3r, w3i), _mm_mul_pd(q3i, w3r));
                let t0r = _mm_add_pd(ar, cr);
                let t0i = _mm_add_pd(ai, ci);
                let t1r = _mm_sub_pd(ar, cr);
                let t1i = _mm_sub_pd(ai, ci);
                let t2r = _mm_add_pd(br, dr);
                let t2i = _mm_add_pd(bi, di);
                let t3r = _mm_mul_pd(sv, _mm_sub_pd(br, dr));
                let t3i = _mm_mul_pd(sv, _mm_sub_pd(bi, di));
                _mm_storeu_pd(re0.as_mut_ptr().add(j), _mm_add_pd(t0r, t2r));
                _mm_storeu_pd(im0.as_mut_ptr().add(j), _mm_add_pd(t0i, t2i));
                _mm_storeu_pd(re2.as_mut_ptr().add(j), _mm_sub_pd(t0r, t2r));
                _mm_storeu_pd(im2.as_mut_ptr().add(j), _mm_sub_pd(t0i, t2i));
                _mm_storeu_pd(re1.as_mut_ptr().add(j), _mm_add_pd(t1r, t3i));
                _mm_storeu_pd(im1.as_mut_ptr().add(j), _mm_sub_pd(t1i, t3r));
                _mm_storeu_pd(re3.as_mut_ptr().add(j), _mm_sub_pd(t1r, t3i));
                _mm_storeu_pd(im3.as_mut_ptr().add(j), _mm_add_pd(t1i, t3r));
                j += 2;
            }
            if j < l {
                scalar::fft_butterfly4_from(
                    re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign, j,
                );
            }
        }
    }

    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    // SAFETY: contract — the executing CPU must support SSE2 (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn fft_butterfly_sse2(
        re_h: &mut [f64],
        im_h: &mut [f64],
        re_t: &mut [f64],
        im_t: &mut [f64],
        twr: &[f64],
        twi: &[f64],
        stride: usize,
        sign: f64,
    ) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let half = re_h.len();
            let sv = _mm_set1_pd(sign);
            let mut j = 0;
            while j + 2 <= half {
                let (wr, wi_raw) = if stride == 1 {
                    (
                        _mm_loadu_pd(twr.as_ptr().add(j)),
                        _mm_loadu_pd(twi.as_ptr().add(j)),
                    )
                } else {
                    (
                        _mm_setr_pd(twr[j * stride], twr[(j + 1) * stride]),
                        _mm_setr_pd(twi[j * stride], twi[(j + 1) * stride]),
                    )
                };
                let wi = _mm_mul_pd(sv, wi_raw);
                let ur = _mm_loadu_pd(re_h.as_ptr().add(j));
                let ui = _mm_loadu_pd(im_h.as_ptr().add(j));
                let tr = _mm_loadu_pd(re_t.as_ptr().add(j));
                let ti = _mm_loadu_pd(im_t.as_ptr().add(j));
                let vr = _mm_sub_pd(_mm_mul_pd(tr, wr), _mm_mul_pd(ti, wi));
                let vi = _mm_add_pd(_mm_mul_pd(tr, wi), _mm_mul_pd(ti, wr));
                _mm_storeu_pd(re_h.as_mut_ptr().add(j), _mm_add_pd(ur, vr));
                _mm_storeu_pd(im_h.as_mut_ptr().add(j), _mm_add_pd(ui, vi));
                _mm_storeu_pd(re_t.as_mut_ptr().add(j), _mm_sub_pd(ur, vr));
                _mm_storeu_pd(im_t.as_mut_ptr().add(j), _mm_sub_pd(ui, vi));
                j += 2;
            }
            if j < half {
                scalar::fft_butterfly(
                    &mut re_h[j..],
                    &mut im_h[j..],
                    &mut re_t[j..],
                    &mut im_t[j..],
                    &twr[j * stride..],
                    &twi[j * stride..],
                    stride,
                    sign,
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON (f32 kernels; the f64 FFT kernels dispatch to scalar there)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::scalar;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_neon(head: &mut [f32], tail: &mut [f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let mut i = 0;
            while i + 4 <= n {
                let a = vld1q_f32(head.as_ptr().add(i));
                let b = vld1q_f32(tail.as_ptr().add(i));
                vst1q_f32(head.as_mut_ptr().add(i), vaddq_f32(a, b));
                vst1q_f32(tail.as_mut_ptr().add(i), vsubq_f32(a, b));
                i += 4;
            }
            scalar::butterfly(&mut head[i..], &mut tail[i..]);
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn butterfly_scaled_neon(head: &mut [f32], tail: &mut [f32], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = head.len();
            let sv = vdupq_n_f32(s);
            let mut i = 0;
            while i + 4 <= n {
                let a = vld1q_f32(head.as_ptr().add(i));
                let b = vld1q_f32(tail.as_ptr().add(i));
                vst1q_f32(head.as_mut_ptr().add(i), vmulq_f32(vaddq_f32(a, b), sv));
                vst1q_f32(tail.as_mut_ptr().add(i), vmulq_f32(vsubq_f32(a, b), sv));
                i += 4;
            }
            scalar::butterfly_scaled(&mut head[i..], &mut tail[i..], s);
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn scale_neon(a: &mut [f32], d: &[f32]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = a.len();
            let mut i = 0;
            while i + 4 <= n {
                let x = vld1q_f32(a.as_ptr().add(i));
                let s = vld1q_f32(d.as_ptr().add(i));
                vst1q_f32(a.as_mut_ptr().add(i), vmulq_f32(x, s));
                i += 4;
            }
            scalar::scale(&mut a[i..], &d[i..]);
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    unsafe fn quad_sign_mask(signs: &[u64], i: usize) -> uint32x4_t {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let w = signs[i >> 6] >> (i & 63);
            let lanes: [u32; 4] = [
                ((w & 1) as u32) << 31,
                (((w >> 1) & 1) as u32) << 31,
                (((w >> 2) & 1) as u32) << 31,
                (((w >> 3) & 1) as u32) << 31,
            ];
            vld1q_u32(lanes.as_ptr())
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_neon(x: &mut [f32], signs: &[u64]) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask(signs, i);
                let v = vreinterpretq_u32_f32(vld1q_f32(x.as_ptr().add(i)));
                vst1q_f32(x.as_mut_ptr().add(i), vreinterpretq_f32_u32(veorq_u32(v, mask)));
                i += 4;
            }
            for k in i..n {
                let m = (((signs[k >> 6] >> (k & 63)) & 1) as u32) << 31;
                x[k] = f32::from_bits(x[k].to_bits() ^ m);
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: contract — the executing CPU must support NEON (the
    // dispatcher only routes here after detection, and tests only force
    // levels the host reported); no other preconditions.
    pub(super) unsafe fn apply_signs_scaled_neon(x: &mut [f32], signs: &[u64], s: f32) {
        // SAFETY: the intrinsics below require only the target feature the
        // fn contract establishes; every pointer is derived from a slice
        // argument and stays within its length by the loop bounds.
        unsafe {
            let n = x.len();
            let sv = vdupq_n_f32(s);
            let mut i = 0;
            while i + 4 <= n {
                let mask = quad_sign_mask(signs, i);
                let v = vreinterpretq_u32_f32(vld1q_f32(x.as_ptr().add(i)));
                let flipped = vreinterpretq_f32_u32(veorq_u32(v, mask));
                vst1q_f32(x.as_mut_ptr().add(i), vmulq_f32(flipped, sv));
                i += 4;
            }
            for k in i..n {
                let m = (((signs[k >> 6] >> (k & 63)) & 1) as u32) << 31;
                x[k] = f32::from_bits(x[k].to_bits() ^ m) * s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_signs(words: usize, rng: &mut Rng) -> Vec<u64> {
        (0..words).map(|_| rng.next_u64()).collect()
    }

    /// Every dispatched kernel must be byte-identical to the scalar oracle
    /// on ragged lengths (SIMD body + scalar tail both exercised).
    #[test]
    fn dispatched_kernels_match_scalar_bitwise() {
        let mut rng = Rng::new(42);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 31, 64, 65, 127, 256] {
            let head0 = rng.gaussian_vec(n);
            let tail0 = rng.gaussian_vec(n);
            let signs = rand_signs(n.div_ceil(64).max(1), &mut rng);
            let s = 0.123_f32;

            let (mut h1, mut t1) = (head0.clone(), tail0.clone());
            let (mut h2, mut t2) = (head0.clone(), tail0.clone());
            butterfly(&mut h1, &mut t1);
            scalar::butterfly(&mut h2, &mut t2);
            assert_eq!(h1, h2, "butterfly n={n}");
            assert_eq!(t1, t2, "butterfly n={n}");

            let (mut h1, mut t1) = (head0.clone(), tail0.clone());
            let (mut h2, mut t2) = (head0.clone(), tail0.clone());
            butterfly_scaled(&mut h1, &mut t1, s);
            scalar::butterfly_scaled(&mut h2, &mut t2, s);
            assert_eq!(h1, h2, "butterfly_scaled n={n}");
            assert_eq!(t1, t2, "butterfly_scaled n={n}");

            let (mut a1, mut a2) = (head0.clone(), head0.clone());
            scale(&mut a1, &tail0);
            scalar::scale(&mut a2, &tail0);
            assert_eq!(a1, a2, "scale n={n}");

            let (mut a1, mut a2) = (head0.clone(), head0.clone());
            apply_signs(&mut a1, &signs);
            scalar::apply_signs(&mut a2, &signs);
            assert_eq!(a1, a2, "apply_signs n={n}");

            let (mut a1, mut a2) = (head0.clone(), head0.clone());
            apply_signs_scaled(&mut a1, &signs, s);
            scalar::apply_signs_scaled(&mut a2, &signs, s);
            assert_eq!(a1, a2, "apply_signs_scaled n={n}");

            let (mut d1, mut d2) = (vec![0.0f64; n], vec![0.0f64; n]);
            promote_signs_scaled(&head0, &signs, s, &mut d1);
            scalar::promote_signs_scaled(&head0, &signs, s, &mut d2);
            assert_eq!(d1, d2, "promote_signs_scaled n={n}");
        }
    }

    #[test]
    fn dispatched_f64_kernels_match_scalar_bitwise() {
        let mut rng = Rng::new(7);
        for half in [0usize, 1, 2, 3, 4, 5, 8, 13, 16, 64, 100] {
            let mk = |rng: &mut Rng| -> Vec<f64> { (0..half).map(|_| rng.gaussian()).collect() };
            let (re0, im0, kr, ki) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let (mut r1, mut i1) = (re0.clone(), im0.clone());
            let (mut r2, mut i2) = (re0.clone(), im0.clone());
            cmul(&mut r1, &mut i1, &kr, &ki);
            scalar::cmul(&mut r2, &mut i2, &kr, &ki);
            assert_eq!(r1, r2, "cmul half={half}");
            assert_eq!(i1, i2, "cmul half={half}");

            for stride in [1usize, 2, 4] {
                let tw_len = (half.saturating_sub(1)) * stride + 1;
                let twr: Vec<f64> = (0..tw_len).map(|_| rng.gaussian()).collect();
                let twi: Vec<f64> = (0..tw_len).map(|_| rng.gaussian()).collect();
                for sign in [1.0f64, -1.0] {
                    let (rh0, ih0, rt0, it0) =
                        (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
                    let (mut a, mut b, mut c, mut d) =
                        (rh0.clone(), ih0.clone(), rt0.clone(), it0.clone());
                    let (mut e, mut f, mut g, mut h) =
                        (rh0.clone(), ih0.clone(), rt0.clone(), it0.clone());
                    fft_butterfly(&mut a, &mut b, &mut c, &mut d, &twr, &twi, stride, sign);
                    scalar::fft_butterfly(&mut e, &mut f, &mut g, &mut h, &twr, &twi, stride, sign);
                    assert_eq!(a, e, "fft_butterfly half={half} stride={stride}");
                    assert_eq!(b, f, "fft_butterfly half={half} stride={stride}");
                    assert_eq!(c, g, "fft_butterfly half={half} stride={stride}");
                    assert_eq!(d, h, "fft_butterfly half={half} stride={stride}");
                }
            }
        }
    }

    #[test]
    fn dispatched_radix4_butterfly_matches_scalar_bitwise() {
        let mut rng = Rng::new(21);
        for l in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 16, 64, 100] {
            let mk = |rng: &mut Rng| -> Vec<f64> { (0..l).map(|_| rng.gaussian()).collect() };
            for stride in [1usize, 2, 4] {
                let tw_len = 3 * l.saturating_sub(1) * stride + 1;
                let twr: Vec<f64> = (0..tw_len).map(|_| rng.gaussian()).collect();
                let twi: Vec<f64> = (0..tw_len).map(|_| rng.gaussian()).collect();
                for sign in [1.0f64, -1.0] {
                    let qs0: [Vec<f64>; 8] = std::array::from_fn(|_| mk(&mut rng));
                    let mut a = qs0.clone();
                    let mut b = qs0.clone();
                    {
                        let [r0, i0, r1, i1, r2, i2, r3, i3] = a.each_mut();
                        fft_butterfly4(r0, i0, r1, i1, r2, i2, r3, i3, &twr, &twi, stride, sign);
                    }
                    {
                        let [r0, i0, r1, i1, r2, i2, r3, i3] = b.each_mut();
                        scalar::fft_butterfly4(
                            r0, i0, r1, i1, r2, i2, r3, i3, &twr, &twi, stride, sign,
                        );
                    }
                    assert_eq!(a, b, "fft_butterfly4 l={l} stride={stride} sign={sign}");
                }
            }
        }
    }

    #[test]
    fn dispatched_cmul_half_matches_scalar_bitwise() {
        let mut rng = Rng::new(23);
        for h in [0usize, 1, 2, 4, 8, 16, 32, 64, 128, 256] {
            let mk = |len: usize, rng: &mut Rng| -> Vec<f64> {
                (0..len).map(|_| rng.gaussian()).collect()
            };
            let (zre0, zim0) = (mk(h, &mut rng), mk(h, &mut rng));
            let (kr, ki) = (mk(h + 1, &mut rng), mk(h + 1, &mut rng));
            let tw_len = (h / 2).max(1);
            let (twr, twi) = (mk(tw_len, &mut rng), mk(tw_len, &mut rng));
            let (mut r1, mut i1) = (zre0.clone(), zim0.clone());
            let (mut r2, mut i2) = (zre0.clone(), zim0.clone());
            cmul_half(&mut r1, &mut i1, &kr, &ki, &twr, &twi);
            scalar::cmul_half(&mut r2, &mut i2, &kr, &ki, &twr, &twi);
            assert_eq!(r1, r2, "cmul_half h={h}");
            assert_eq!(i1, i2, "cmul_half h={h}");
        }
    }

    #[test]
    fn rfft_split_merge_round_trip() {
        // merge(split(Z)) must reproduce Z (up to |w|^2 rounding) — the
        // pairing the RFFT engine's forward/inverse hand-off relies on.
        let mut rng = Rng::new(29);
        for h in [1usize, 2, 4, 8, 64, 256] {
            let n = 2 * h;
            let mut twr = Vec::with_capacity(h / 2 + 1);
            let mut twi = Vec::with_capacity(h / 2 + 1);
            for k in 0..=h / 2 {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                twr.push(ang.cos());
                twi.push(ang.sin());
            }
            let zre0: Vec<f64> = (0..h).map(|_| rng.gaussian()).collect();
            let zim0: Vec<f64> = (0..h).map(|_| rng.gaussian()).collect();
            let (mut xr, mut xi) = (vec![0.0; h + 1], vec![0.0; h + 1]);
            rfft_split(&zre0, &zim0, &mut xr, &mut xi, &twr, &twi);
            let (mut zre, mut zim) = (vec![0.0; h], vec![0.0; h]);
            rfft_merge(&xr, &xi, &mut zre, &mut zim, &twr, &twi);
            for k in 0..h {
                assert!((zre[k] - zre0[k]).abs() < 1e-12, "h={h} k={k}");
                assert!((zim[k] - zim0[k]).abs() < 1e-12, "h={h} k={k}");
            }
        }
    }

    #[test]
    fn dispatched_pack_signs_and_hamming_match_scalar() {
        let mut rng = Rng::new(63);
        for n in [0usize, 1, 5, 8, 31, 63, 64, 65, 128, 200, 513] {
            let mut src = rng.gaussian_vec(n);
            if n > 2 {
                // sign-bit corner cases: movemask and to_bits()>>31 must
                // agree on negative zero and NaN payloads too
                src[0] = -0.0;
                src[1] = f32::NAN;
                src[2] = f32::from_bits(0xFFC0_0000); // negative NaN
            }
            let words = n.div_ceil(64);
            let mut d1 = vec![u64::MAX; words]; // dirty: kernels must clear
            let mut d2 = vec![u64::MAX; words];
            pack_signs(&src, &mut d1);
            scalar::pack_signs(&src, &mut d2);
            assert_eq!(d1, d2, "pack_signs n={n}");
            for (i, v) in src.iter().enumerate() {
                let bit = (d1[i / 64] >> (i % 64)) & 1 == 1;
                assert_eq!(bit, v.is_sign_negative(), "n={n} i={i}");
            }
            // bits beyond n stay clear (stable bucket keys / distances)
            if words > 0 && n % 64 != 0 {
                assert_eq!(d1[words - 1] >> (n % 64), 0, "trailing bits n={n}");
            }

            let a = rand_signs(words, &mut rng);
            let b = rand_signs(words, &mut rng);
            let naive: u64 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones() as u64).sum();
            assert_eq!(hamming(&a, &b), naive, "hamming words={words}");
            assert_eq!(scalar::hamming(&a, &b), naive);
            assert_eq!(hamming(&a, &a), 0);
        }
    }

    #[test]
    fn sign_xor_equals_f32_multiply() {
        // the packed representation's load-bearing identity: XOR-ing the
        // sign bit is exactly multiplication by ±1.0 (and, scaled, by ±s).
        let mut rng = Rng::new(9);
        let n = 200;
        let x0 = rng.gaussian_vec(n);
        let d = rng.rademacher_vec(n);
        let mut signs = vec![0u64; n.div_ceil(64)];
        for (i, v) in d.iter().enumerate() {
            if *v < 0.0 {
                signs[i / 64] |= 1 << (i % 64);
            }
        }
        let mut by_mul = x0.clone();
        scalar::scale(&mut by_mul, &d);
        let mut by_xor = x0.clone();
        apply_signs(&mut by_xor, &signs);
        assert_eq!(by_mul, by_xor);

        let s = 0.037_f32;
        let ds: Vec<f32> = d.iter().map(|v| v * s).collect();
        let mut by_mul = x0.clone();
        scalar::scale(&mut by_mul, &ds);
        let mut by_xor = x0;
        apply_signs_scaled(&mut by_xor, &signs, s);
        assert_eq!(by_mul, by_xor);
    }

    // NOTE: no unit test calls `force` — it mutates process-global dispatch
    // state, and the lib test binary runs tests on parallel threads where a
    // mid-test level flip could race another test's bitwise comparison.
    // Force-based coverage lives in tests/simd_equivalence.rs, which keeps
    // everything inside one #[test].
}
