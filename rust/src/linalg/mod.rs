//! Linear-algebra substrate: FWHT, FFT-based structured matvecs, dense
//! matrices and small SPD solvers.

pub mod dense;
pub mod fft;
pub mod fwht;
pub mod vecops;

pub use dense::Mat;
