//! Linear-algebra substrate: FWHT, FFT-based structured matvecs, dense
//! matrices, small SPD solvers, the runtime-dispatched SIMD inner kernels,
//! and the reusable scratch workspaces behind the zero-allocation transform
//! execution path.

pub mod dense;
pub mod fft;
pub mod fwht;
pub mod simd;
pub mod vecops;
pub mod workspace;

pub use dense::Mat;
pub use workspace::Workspace;
