//! Radix-2 complex FFT and FFT-based structured matvecs.
//!
//! Circulant / Toeplitz / Hankel / skew-circulant Gaussian matrices (the
//! `G_circ D2 H D1`-style TripleSpin members, Lemma 1 of the paper) multiply
//! a vector in `O(n log n)` via circular convolution. NumPy's `numpy.fft`
//! played this role in the paper's experiments; here it is self-contained.
//!
//! All transforms work on split complex (re, im) `f64` buffers — the extra
//! precision is free at these sizes and keeps the structured matvec within
//! f32 round-off of the dense reference.

use crate::linalg::simd;
use std::f64::consts::PI;

/// In-place iterative radix-2 Cooley–Tukey FFT.
/// `re.len() == im.len()` must be a power of two. `inverse` applies the
/// conjugate transform *including* the 1/n scaling.
pub fn fft(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    debug_assert_eq!(n, im.len());
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }

    bit_reverse(re, im);

    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in i..i + len / 2 {
                let (ur, ui) = (re[k], im[k]);
                let (vr, vi) = (
                    re[k + len / 2] * cr - im[k + len / 2] * ci,
                    re[k + len / 2] * ci + im[k + len / 2] * cr,
                );
                re[k] = ur + vr;
                im[k] = ui + vi;
                re[k + len / 2] = ur - vr;
                im[k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }

    if inverse {
        let s = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Circular convolution `a ⊛ b` of two real vectors of equal power-of-two
/// length, via FFT.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    debug_assert!(n.is_power_of_two());
    let mut ar = a.to_vec();
    let mut ai = vec![0.0; n];
    let mut br = b.to_vec();
    let mut bi = vec![0.0; n];
    fft(&mut ar, &mut ai, false);
    fft(&mut br, &mut bi, false);
    for i in 0..n {
        let (r, im) = (
            ar[i] * br[i] - ai[i] * bi[i],
            ar[i] * bi[i] + ai[i] * br[i],
        );
        ar[i] = r;
        ai[i] = im;
    }
    fft(&mut ar, &mut ai, true);
    ar
}

/// Bit-reversal permutation shared by [`fft`] and the table-driven plan
/// kernels.
#[inline]
fn bit_reverse(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    if n <= 2 {
        return;
    }
    let mut j = 0usize;
    for i in 0..n - 1 {
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
        let mut m = n >> 1;
        while m >= 1 && j & m != 0 {
            j ^= m;
            m >>= 1;
        }
        j |= m;
    }
}

/// One radix-2 butterfly level (span `len`) over one row, twiddles looked
/// up from a precomputed `exp(-2πi k/n)` table (stride `n/len`). The table
/// drive replaces the per-stage trig recurrence of [`fft`]: no serial
/// dependency in the inner loop, every row of a batch reuses the same
/// table entries, and each block's complex butterflies run through the
/// dispatched SIMD kernel ([`simd::fft_butterfly`] — bit-identical to its
/// scalar path, no FMA contraction).
#[inline]
fn butterfly_level(
    re: &mut [f64],
    im: &mut [f64],
    len: usize,
    inverse: bool,
    twr: &[f64],
    twi: &[f64],
) {
    let n = re.len();
    let half = len / 2;
    let stride = n / len;
    let sign = if inverse { -1.0 } else { 1.0 };
    let mut i = 0;
    while i < n {
        let (re_h, re_t) = re[i..i + len].split_at_mut(half);
        let (im_h, im_t) = im[i..i + len].split_at_mut(half);
        simd::fft_butterfly(re_h, im_h, re_t, im_t, twr, twi, stride, sign);
        i += len;
    }
}

/// Full table-driven FFT over one row (used by the plan kernels; the
/// standalone [`fft`] keeps its table-free form for one-shot callers).
#[inline]
fn fft_tabled(re: &mut [f64], im: &mut [f64], inverse: bool, twr: &[f64], twi: &[f64]) {
    let n = re.len();
    if n <= 1 {
        return;
    }
    bit_reverse(re, im);
    let mut len = 2;
    while len <= n {
        butterfly_level(re, im, len, inverse, twr, twi);
        len <<= 1;
    }
    if inverse {
        let s = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Rows per block of the batch convolution kernel: bounds the f64 scratch
/// (`2 * block * n` doubles) while amortizing the twiddle stream across
/// rows. Consumers size their workspace scratch with
/// [`ConvPlan::batch_block_rows`].
const MAX_FFT_BLOCK_ROWS: usize = 8;

/// Precomputed spectrum of a circulant (or skew-/Toeplitz-embedded) kernel
/// **plus its twiddle tables**, so repeated matvecs pay only two
/// table-driven FFTs — and batches of rows share one twiddle stream
/// ([`ConvPlan::apply_batch_in_place`]) instead of re-deriving the
/// per-stage trig recurrence once per row.
#[derive(Clone, Debug)]
pub struct ConvPlan {
    n: usize,
    kr: Vec<f64>,
    ki: Vec<f64>,
    /// `exp(-2πi k/n)` for `k < max(n/2, 1)` (forward; inverse conjugates).
    twr: Vec<f64>,
    twi: Vec<f64>,
}

impl ConvPlan {
    /// Plan for circular convolution with fixed kernel `k` (power-of-two len).
    pub fn new(k: &[f64]) -> ConvPlan {
        let n = k.len();
        assert!(n.is_power_of_two());
        let half = (n / 2).max(1);
        let mut twr = Vec::with_capacity(half);
        let mut twi = Vec::with_capacity(half);
        for i in 0..half {
            let ang = -2.0 * PI * i as f64 / n as f64;
            twr.push(ang.cos());
            twi.push(ang.sin());
        }
        let mut kr = k.to_vec();
        let mut ki = vec![0.0; n];
        fft_tabled(&mut kr, &mut ki, false, &twr, &twi);
        ConvPlan { n, kr, ki, twr, twi }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// How many rows the batch kernel processes per block — size per-block
    /// scratch as `batch_block_rows() * len()`.
    pub fn batch_block_rows(&self) -> usize {
        // keep a block's two f64 buffers within ~256 KiB
        ((1usize << 14) / self.n.max(1)).clamp(1, MAX_FFT_BLOCK_ROWS)
    }

    /// `out = kernel ⊛ x` (circular).
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.n);
        let mut re = x.to_vec();
        let mut im = vec![0.0; self.n];
        self.apply_in_place(&mut re, &mut im);
        re
    }

    /// `re = kernel ⊛ re` (circular), in place. `im` is caller-provided
    /// scratch of the same length, overwritten. The single-row case of
    /// [`ConvPlan::apply_batch_in_place`] — the two share one code path so
    /// the per-row and batch engines stay bit-for-bit identical.
    pub fn apply_in_place(&self, re: &mut [f64], im: &mut [f64]) {
        debug_assert_eq!(re.len(), self.n);
        self.apply_batch_in_place(re, im);
    }

    /// Multi-row circular convolution: `re` holds `rows` row-major rows of
    /// `len()` each (`re = kernel ⊛ re` per row), `im` is caller scratch of
    /// the same length, overwritten. The plan's precomputed twiddle tables
    /// and the caller's blocked scratch are shared across every row; within
    /// the block each row runs to completion (forward FFT, spectrum
    /// multiply, inverse FFT) so it stays L1-resident — a level-major
    /// ordering across rows was tried and REVERTED: re-streaming the block
    /// once per butterfly level measured slower than per-row traversal at
    /// n >= 512 (C-mirror calibration, PR 2). This is the batch kernel
    /// under every circulant/Toeplitz/Hankel/skew family.
    pub fn apply_batch_in_place(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(re.len() % n.max(1), 0);
        debug_assert_eq!(im.len(), re.len());
        im.fill(0.0);
        if n <= 1 {
            // 1-point FFT: pointwise scale by the kernel only.
            for v in re.iter_mut() {
                *v *= self.kr[0];
            }
            return;
        }
        for (rr, ri) in re.chunks_exact_mut(n).zip(im.chunks_exact_mut(n)) {
            fft_tabled(rr, ri, false, &self.twr, &self.twi);
            simd::cmul(rr, ri, &self.kr, &self.ki);
            fft_tabled(rr, ri, true, &self.twr, &self.twi);
        }
    }
}

/// Multiply by the circulant matrix whose **first row** is `row`:
/// `y_i = sum_j row_{(j - i) mod n} x_j`.
pub fn circulant_matvec(row: &[f64], x: &[f64]) -> Vec<f64> {
    // first-row circulant C satisfies C x = reverse-shift trick:
    // y = IFFT(FFT(c_col) * FFT(x)) where c_col is the first column:
    // c_col[i] = row[(n - i) % n].
    let n = row.len();
    let mut col = vec![0.0; n];
    for i in 0..n {
        col[i] = row[(n - i) % n];
    }
    circular_convolve(&col, x)
}

/// Multiply by the Toeplitz matrix `T` with `T[i][j] = diag[j - i + (n-1)]`,
/// where `diag` has length `2n - 1` (entry `n-1` is the main diagonal,
/// entries above it the superdiagonals). Uses 2n-point circulant embedding.
pub fn toeplitz_matvec(diag: &[f64], x: &[f64]) -> Vec<f64> {
    let n = x.len();
    debug_assert_eq!(diag.len(), 2 * n - 1);
    let m = (2 * n).next_power_of_two();
    // Embed: circulant first column c with c[k] = T[k][0] = diag[n-1-k] for
    // k in 0..n, and wrap the superdiagonals at the end.
    let mut c = vec![0.0; m];
    for i in 0..n {
        c[i] = diag[n - 1 - i]; // first column, top to bottom
    }
    for j in 1..n {
        c[m - j] = diag[n - 1 + j]; // superdiagonal j wraps to position m-j
    }
    let mut xx = vec![0.0; m];
    xx[..n].copy_from_slice(x);
    let y = circular_convolve(&c, &xx);
    y[..n].to_vec()
}

/// Multiply by the Hankel matrix `Hk[i][j] = anti[i + j]` where `anti` has
/// length `2n - 1`. A Hankel matrix is a row-reversed Toeplitz: `Hk x = T xr`
/// with `xr` the reversed input.
pub fn hankel_matvec(anti: &[f64], x: &[f64]) -> Vec<f64> {
    let n = x.len();
    debug_assert_eq!(anti.len(), 2 * n - 1);
    // Hk[i][j] = anti[i+j]; with xr[j] = x[n-1-j]:
    // (T xr)_i = sum_j T[i][j] x[n-1-j]; choose T[i][j] = anti[i + n-1 - j]
    // i.e. T diag index (j - i + n - 1) -> anti[i + n - 1 - j] means
    // diag[d] = anti[2(n-1) - d].
    let mut diag = vec![0.0; 2 * n - 1];
    for d in 0..2 * n - 1 {
        diag[d] = anti[2 * (n - 1) - d];
    }
    let xr: Vec<f64> = x.iter().rev().copied().collect();
    toeplitz_matvec(&diag, &xr)
}

/// Multiply by the skew-circulant matrix with first row `row`:
/// like a circulant but entries that wrap around pick up a minus sign
/// (`S[i][j] = row[j-i]` for `j >= i`, `-row[n + j - i]` for `j < i`).
pub fn skew_circulant_matvec(row: &[f64], x: &[f64]) -> Vec<f64> {
    // A skew-circulant is the Toeplitz matrix with diag[d] = row[d - (n-1)]
    // for d >= n-1 (upper part incl. main diag) and -row[d + 1] for d < n-1.
    let n = row.len();
    let mut diag = vec![0.0; 2 * n - 1];
    for d in 0..2 * n - 1 {
        diag[d] = if d >= n - 1 {
            row[d - (n - 1)]
        } else {
            -row[d + 1]
        };
    }
    toeplitz_matvec(&diag, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::rng::Rng;

    fn naive_dft(re: &[f64], im: &[f64], inverse: bool) -> (Vec<f64>, Vec<f64>) {
        let n = re.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut or_ = vec![0.0; n];
        let mut oi = vec![0.0; n];
        for k in 0..n {
            for t in 0..n {
                let ang = sign * 2.0 * PI * (k * t) as f64 / n as f64;
                or_[k] += re[t] * ang.cos() - im[t] * ang.sin();
                oi[k] += re[t] * ang.sin() + im[t] * ang.cos();
            }
        }
        if inverse {
            for v in or_.iter_mut() {
                *v /= n as f64;
            }
            for v in oi.iter_mut() {
                *v /= n as f64;
            }
        }
        (or_, oi)
    }

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = Rng::new(11);
        for n in [1usize, 2, 4, 8, 32, 128] {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (er, ei) = naive_dft(&re, &im, false);
            let (mut gr, mut gi) = (re.clone(), im.clone());
            fft(&mut gr, &mut gi, false);
            for i in 0..n {
                assert!((gr[i] - er[i]).abs() < 1e-8 * n as f64, "n={n}");
                assert!((gi[i] - ei[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        }
    }

    #[test]
    fn fft_round_trip() {
        for_all(24, |g| {
            let n = g.pow2_in(0, 9);
            let re: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let im: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let (mut rr, mut ri) = (re.clone(), im.clone());
            fft(&mut rr, &mut ri, false);
            fft(&mut rr, &mut ri, true);
            for i in 0..n {
                assert!((rr[i] - re[i]).abs() < 1e-9);
                assert!((ri[i] - im[i]).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn parseval() {
        for_all(16, |g| {
            let n = g.pow2_in(1, 8);
            let re: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let mut im = vec![0.0; n];
            let energy: f64 = re.iter().map(|v| v * v).sum();
            let mut fr = re;
            fft(&mut fr, &mut im, false);
            let fenergy: f64 =
                fr.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / n as f64;
            assert!((energy - fenergy).abs() < 1e-8 * energy.max(1.0));
        });
    }

    fn naive_circulant(row: &[f64], x: &[f64]) -> Vec<f64> {
        let n = row.len();
        (0..n)
            .map(|i| (0..n).map(|j| row[(n + j - i) % n] * x[j]).sum())
            .collect()
    }

    #[test]
    fn circulant_matches_naive() {
        for_all(24, |g| {
            let n = g.pow2_in(0, 7);
            let row: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect = naive_circulant(&row, &x);
            let got = circulant_matvec(&row, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn toeplitz_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 70);
            let diag: Vec<f64> = (0..2 * n - 1).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| diag[j + n - 1 - i] * x[j]).sum())
                .collect();
            let got = toeplitz_matvec(&diag, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn hankel_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 60);
            let anti: Vec<f64> = (0..2 * n - 1).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| anti[i + j] * x[j]).sum())
                .collect();
            let got = hankel_matvec(&anti, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn skew_circulant_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 60);
            let row: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| {
                    (0..n)
                        .map(|j| {
                            if j >= i {
                                row[j - i] * x[j]
                            } else {
                                -row[n + j - i] * x[j]
                            }
                        })
                        .sum()
                })
                .collect();
            let got = skew_circulant_matvec(&row, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn plan_batch_matches_single_row_bitwise() {
        // The multi-row kernel must reproduce the single-row path bit for
        // bit — this is what keeps apply_into and apply_batch_serial
        // interchangeable for every FFT-backed family.
        for_all(16, |g| {
            let n = g.pow2_in(0, 8);
            let rows = g.usize_in(1, 12);
            let mut rng = Rng::new(g.u64());
            let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan = ConvPlan::new(&k);
            let batch: Vec<f64> = (0..rows * n).map(|_| rng.gaussian()).collect();
            let mut expect = Vec::with_capacity(rows * n);
            for row in batch.chunks_exact(n) {
                let mut re = row.to_vec();
                let mut im = vec![0.0; n];
                plan.apply_in_place(&mut re, &mut im);
                expect.extend_from_slice(&re);
            }
            let mut re = batch;
            let mut im = vec![0.0; rows * n];
            plan.apply_batch_in_place(&mut re, &mut im);
            assert_eq!(re, expect, "n={n} rows={rows}");
        });
    }

    #[test]
    fn plan_scratch_reuse_is_clean() {
        // dirty im scratch (and dirty padding in re from a previous call)
        // must not leak into results.
        let mut rng = Rng::new(17);
        let n = 32;
        let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = ConvPlan::new(&k);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let clean = plan.apply(&x);
        let mut re = x.clone();
        let mut im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect(); // garbage
        plan.apply_in_place(&mut re, &mut im);
        assert_eq!(re, clean);
    }

    #[test]
    fn batch_block_rows_bounds() {
        for n in [1usize, 2, 64, 1024, 1 << 14, 1 << 16] {
            let k = vec![1.0f64; n];
            let plan = ConvPlan::new(&k);
            let b = plan.batch_block_rows();
            assert!((1..=8).contains(&b), "n={n} -> block {b}");
        }
    }

    #[test]
    fn conv_plan_matches_one_shot() {
        let mut rng = Rng::new(13);
        let n = 64;
        let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = ConvPlan::new(&k);
        let a = plan.apply(&x);
        let b = circular_convolve(&k, &x);
        for i in 0..n {
            assert!((a[i] - b[i]).abs() < 1e-9);
        }
    }
}
