//! Real-input (RFFT) and complex FFT engines for the FFT-based structured
//! matvecs.
//!
//! Circulant / Toeplitz / Hankel / skew-circulant Gaussian matrices (the
//! `G_circ D2 H D1`-style TripleSpin members, Lemma 1 of the paper) multiply
//! a vector in `O(n log n)` via circular convolution. NumPy's `numpy.fft`
//! played this role in the paper's experiments; here it is self-contained.
//!
//! All transforms work on split complex (re, im) `f64` buffers — the extra
//! precision is free at these sizes and keeps the structured matvec within
//! f32 round-off of the dense reference.
//!
//! ## Two engines, one [`ConvPlan`]
//!
//! Every convolution row in the engine is purely **real**, so the default
//! engine is an **RFFT**: an `n`-point real transform computed as an
//! `n/2`-point complex FFT over the packed row `z[k] = x[2k] + i·x[2k+1]`
//! plus a conjugate-symmetric split/merge pass — half the butterflies,
//! half the spectrum, half the scratch traffic of the old complex path.
//!
//! * **Half-spectrum layout.** A real row's spectrum is Hermitian
//!   (`X[n-k] = conj(X[k])`), so only bins `0..=n/2` are stored: `n/2 + 1`
//!   `(re, im)` pairs, with bins `0` and `n/2` real. [`ConvPlan`] keeps the
//!   kernel spectrum in this layout and multiplies it with
//!   [`simd::cmul_half`], which fuses split → pointwise multiply → merge in
//!   one conjugate-pair walk so the full spectrum is never materialized.
//! * **Radix-4 levels.** The half-size FFT runs fused radix-4 butterfly
//!   levels ([`simd::fft_butterfly4`]; ~25% fewer twiddle multiplies and
//!   half the sweeps over the row), with one radix-2 cleanup level first
//!   when `log2` of the transform size is odd. Twiddle tables cover
//!   `k < 3n/4` to feed the radix-4 `w, w², w³` accesses.
//! * **Variant selection.** `TS_FFT=complex` pins the legacy full-complex
//!   radix-2 path ([`FftVariant::Complex`]) — the A/B baseline and the CI
//!   cross-check lane; anything else (default) selects
//!   [`FftVariant::Rfft`]. A plan captures the active variant at
//!   construction and stays internally consistent regardless of later
//!   [`force_variant`] calls.
//!
//! ## Bit-identity scope
//!
//! The RFFT path is **not** bit-identical to the complex path (different
//! operation order); correctness across the two is pinned by the naive-DFT
//! and naive-circulant oracles (tolerance) plus the property tests below.
//! *Within* each path the SIMD dispatch tiers remain bit-identical to
//! scalar (`tests/simd_equivalence.rs`), and the batch kernel remains
//! bit-identical to the single-row kernel.

use crate::linalg::simd;
use std::f64::consts::PI;
use std::sync::atomic::{AtomicU8, Ordering};

/// Which engine [`ConvPlan`] builds: the real-input half-spectrum RFFT
/// (default) or the legacy full-complex radix-2 path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FftVariant {
    /// Half-spectrum real-input engine (radix-4 half-size FFT + conjugate
    /// split/merge). The default.
    Rfft,
    /// Full complex radix-2 path — selected by `TS_FFT=complex`; kept
    /// compiled as the A/B baseline and CI cross-check lane.
    Complex,
}

impl FftVariant {
    pub fn name(&self) -> &'static str {
        match self {
            FftVariant::Rfft => "rfft",
            FftVariant::Complex => "complex",
        }
    }
}

const VARIANT_UNSET: u8 = u8::MAX;
static VARIANT: AtomicU8 = AtomicU8::new(VARIANT_UNSET);

fn detect_variant() -> FftVariant {
    match std::env::var("TS_FFT") {
        Ok(v) if v.eq_ignore_ascii_case("complex") => FftVariant::Complex,
        _ => FftVariant::Rfft,
    }
}

/// The engine new [`ConvPlan`]s are built on (`TS_FFT`-selected, cached;
/// see [`force_variant`]).
pub fn variant() -> FftVariant {
    // ORDERING: Relaxed — VARIANT is an idempotent cache of an env probe;
    // racing fills store the same value and nothing else is published.
    match VARIANT.load(Ordering::Relaxed) {
        0 => FftVariant::Rfft,
        1 => FftVariant::Complex,
        _ => {
            let v = detect_variant();
            // ORDERING: Relaxed — same-value cache fill (see load above).
            VARIANT.store(if v == FftVariant::Complex { 1 } else { 0 }, Ordering::Relaxed);
            v
        }
    }
}

/// Override the plan-construction variant (`None` = re-read `TS_FFT`).
/// Bench/test hook for A/B-ing both engines in one process; existing plans
/// keep the variant they were built with.
pub fn force_variant(v: Option<FftVariant>) {
    let enc = match v {
        Some(FftVariant::Rfft) => 0,
        Some(FftVariant::Complex) => 1,
        None => {
            if detect_variant() == FftVariant::Complex {
                1
            } else {
                0
            }
        }
    };
    // ORDERING: Relaxed — bench/test hook; plans capture the variant at
    // construction on the calling thread, so no release/acquire pairing.
    VARIANT.store(enc, Ordering::Relaxed);
}

/// `exp(-2πi k/n)` for `k <` the variant's read range: the complex
/// radix-2 levels read `k < n/2`; the RFFT's radix-4 levels read strided
/// `j, 2j, 3j` indices up to `< 3n/4` and its conjugate split/merge reads
/// `k < n/4`, so its tables extend to `3n/4`.
fn build_twiddles(n: usize, variant: FftVariant) -> (Vec<f64>, Vec<f64>) {
    let len = match variant {
        FftVariant::Complex => (n / 2).max(1),
        FftVariant::Rfft => (3 * n / 4).max(1),
    };
    let mut twr = Vec::with_capacity(len);
    let mut twi = Vec::with_capacity(len);
    for i in 0..len {
        let ang = -2.0 * PI * i as f64 / n as f64;
        twr.push(ang.cos());
        twi.push(ang.sin());
    }
    (twr, twi)
}

/// In-place iterative radix-2 Cooley–Tukey FFT.
/// `re.len() == im.len()` must be a power of two. `inverse` applies the
/// conjugate transform *including* the 1/n scaling.
pub fn fft(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    debug_assert_eq!(n, im.len());
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }

    bit_reverse(re, im);

    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in i..i + len / 2 {
                let (ur, ui) = (re[k], im[k]);
                let (vr, vi) = (
                    re[k + len / 2] * cr - im[k + len / 2] * ci,
                    re[k + len / 2] * ci + im[k + len / 2] * cr,
                );
                re[k] = ur + vr;
                im[k] = ui + vi;
                re[k + len / 2] = ur - vr;
                im[k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }

    if inverse {
        let s = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Circular convolution `a ⊛ b` of two real vectors of equal power-of-two
/// length. Routed through a one-shot [`ConvPlan`] so the one-shot and
/// planned paths share one kernel (and the naive-convolution oracle tests
/// exercise the active — by default RFFT — engine) instead of paying four
/// scratch `Vec`s and two full complex FFTs per call.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    ConvPlan::new(a).apply(b)
}

/// Bit-reversal permutation shared by [`fft`] and the table-driven plan
/// kernels.
#[inline]
fn bit_reverse(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    if n <= 2 {
        return;
    }
    let mut j = 0usize;
    for i in 0..n - 1 {
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
        let mut m = n >> 1;
        while m >= 1 && j & m != 0 {
            j ^= m;
            m >>= 1;
        }
        j |= m;
    }
}

/// One radix-2 butterfly level (span `len`) over one row, twiddles looked
/// up from a precomputed `exp(-2πi k/tab_n)` table (stride `tab_n/len`).
/// The table drive replaces the per-stage trig recurrence of [`fft`]: no
/// serial dependency in the inner loop, every row of a batch reuses the
/// same table entries, and each block's complex butterflies run through
/// the dispatched SIMD kernel ([`simd::fft_butterfly`] — bit-identical to
/// its scalar path, no FMA contraction). `tab_n` equals the transform
/// length for the complex path and `2×` it for the RFFT's half-size
/// transform (which shares the full-length table).
#[inline]
fn butterfly_level(
    re: &mut [f64],
    im: &mut [f64],
    len: usize,
    inverse: bool,
    twr: &[f64],
    twi: &[f64],
    tab_n: usize,
) {
    let n = re.len();
    let half = len / 2;
    let stride = tab_n / len;
    let sign = if inverse { -1.0 } else { 1.0 };
    let mut i = 0;
    while i < n {
        let (re_h, re_t) = re[i..i + len].split_at_mut(half);
        let (im_h, im_t) = im[i..i + len].split_at_mut(half);
        simd::fft_butterfly(re_h, im_h, re_t, im_t, twr, twi, stride, sign);
        i += len;
    }
}

/// One fused radix-4 butterfly level (span `len`) — each block's four
/// quarters run through [`simd::fft_butterfly4`]. Same table convention as
/// [`butterfly_level`].
#[inline]
fn butterfly4_level(
    re: &mut [f64],
    im: &mut [f64],
    len: usize,
    inverse: bool,
    twr: &[f64],
    twi: &[f64],
    tab_n: usize,
) {
    let n = re.len();
    let q = len / 4;
    let stride = tab_n / len;
    let sign = if inverse { -1.0 } else { 1.0 };
    let mut i = 0;
    while i < n {
        let (re0, rr) = re[i..i + len].split_at_mut(q);
        let (re1, rr) = rr.split_at_mut(q);
        let (re2, re3) = rr.split_at_mut(q);
        let (im0, ir) = im[i..i + len].split_at_mut(q);
        let (im1, ir) = ir.split_at_mut(q);
        let (im2, im3) = ir.split_at_mut(q);
        simd::fft_butterfly4(re0, im0, re1, im1, re2, im2, re3, im3, twr, twi, stride, sign);
        i += len;
    }
}

/// Full table-driven radix-2 FFT over one row (the legacy complex plan
/// kernel; the standalone [`fft`] keeps its table-free form for one-shot
/// callers).
#[inline]
fn fft_tabled(re: &mut [f64], im: &mut [f64], inverse: bool, twr: &[f64], twi: &[f64]) {
    let n = re.len();
    if n <= 1 {
        return;
    }
    bit_reverse(re, im);
    let mut len = 2;
    while len <= n {
        butterfly_level(re, im, len, inverse, twr, twi, n);
        len <<= 1;
    }
    if inverse {
        let s = 1.0 / n as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Table-driven FFT with fused radix-4 levels — the engine under the
/// RFFT's half-size transform. Rule: one radix-2 cleanup level first when
/// `log2(len)` is odd (it carries no twiddle multiplies), then pure
/// radix-4 levels `4L ← L`. `tab_n` is the twiddle-table granularity
/// (`2 × re.len()` when called on the RFFT's packed half-size row).
fn fft_radix4_tabled(
    re: &mut [f64],
    im: &mut [f64],
    inverse: bool,
    twr: &[f64],
    twi: &[f64],
    tab_n: usize,
) {
    let h = re.len();
    if h <= 1 {
        return;
    }
    debug_assert!(h.is_power_of_two());
    bit_reverse(re, im);
    let mut len = if h.trailing_zeros() % 2 == 1 {
        butterfly_level(re, im, 2, inverse, twr, twi, tab_n);
        8
    } else {
        4
    };
    while len <= h {
        butterfly4_level(re, im, len, inverse, twr, twi, tab_n);
        len <<= 2;
    }
    if inverse {
        let s = 1.0 / h as f64;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

/// Real-input FFT: the half spectrum (`n/2 + 1` bins, bins `0` and `n/2`
/// real) of a real power-of-two-length signal, computed as an `n/2`-point
/// radix-4 complex FFT over the packed row `z[k] = x[2k] + i·x[2k+1]` plus
/// the conjugate-symmetric split. Matches bins `0..=n/2` of [`fft`] run on
/// `(x, 0)`.
pub fn rfft(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.len();
    assert!(n.is_power_of_two(), "rfft needs power-of-two len, got {n}");
    let (twr, twi) = build_twiddles(n, FftVariant::Rfft);
    rfft_with_tables(x, &twr, &twi)
}

/// [`rfft`] on caller-provided RFFT-sized twiddle tables — the single
/// pack → half-size radix-4 FFT → split kernel shared by the standalone
/// transform and [`ConvPlan`] construction (which reuses the plan's own
/// tables instead of rebuilding them).
fn rfft_with_tables(x: &[f64], twr: &[f64], twi: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.len();
    if n <= 1 {
        return (x.to_vec(), vec![0.0; n]);
    }
    let h = n / 2;
    let mut zre: Vec<f64> = (0..h).map(|k| x[2 * k]).collect();
    let mut zim: Vec<f64> = (0..h).map(|k| x[2 * k + 1]).collect();
    fft_radix4_tabled(&mut zre, &mut zim, false, twr, twi, n);
    let mut xr = vec![0.0; h + 1];
    let mut xi = vec![0.0; h + 1];
    simd::rfft_split(&zre, &zim, &mut xr, &mut xi, twr, twi);
    (xr, xi)
}

/// Inverse of [`rfft`] (including the `1/n` scaling): the real signal
/// whose half spectrum is `(xr, xi)` (`n/2 + 1` bins for an `n`-point
/// signal).
pub fn irfft(xr: &[f64], xi: &[f64]) -> Vec<f64> {
    let bins = xr.len();
    assert_eq!(bins, xi.len());
    assert!(bins >= 1, "irfft needs at least the DC bin");
    if bins == 1 {
        return vec![xr[0]];
    }
    let h = bins - 1;
    let n = 2 * h;
    assert!(n.is_power_of_two(), "irfft needs power-of-two len, got {n}");
    let (twr, twi) = build_twiddles(n, FftVariant::Rfft);
    let mut zre = vec![0.0; h];
    let mut zim = vec![0.0; h];
    simd::rfft_merge(xr, xi, &mut zre, &mut zim, &twr, &twi);
    fft_radix4_tabled(&mut zre, &mut zim, true, &twr, &twi, n);
    let mut x = vec![0.0; n];
    for k in 0..h {
        x[2 * k] = zre[k];
        x[2 * k + 1] = zim[k];
    }
    x
}

/// Rows per block of the batch convolution kernel: bounds the f64 scratch
/// (`block * n` data doubles plus [`ConvPlan::batch_scratch_len`] of
/// spectrum scratch — one shared row under the RFFT engine, a full
/// imaginary image on the complex lane) while amortizing the twiddle
/// stream across rows. Consumers size their workspace scratch with
/// [`ConvPlan::batch_block_rows`].
const MAX_FFT_BLOCK_ROWS: usize = 8;

/// Precomputed spectrum of a circulant (or skew-/Toeplitz-embedded) kernel
/// **plus its twiddle tables**, so repeated matvecs pay only two
/// table-driven FFTs — and batches of rows share one twiddle stream
/// ([`ConvPlan::apply_batch_in_place`]) instead of re-deriving the
/// per-stage trig recurrence once per row.
///
/// The plan captures the active [`FftVariant`] at construction: the
/// default RFFT engine stores the kernel's **half spectrum** (`n/2 + 1`
/// bins) and convolves through the half-size radix-4 FFT; the
/// `TS_FFT=complex` legacy engine stores the full `n`-bin spectrum and
/// runs the radix-2 complex path.
#[derive(Clone, Debug)]
pub struct ConvPlan {
    n: usize,
    variant: FftVariant,
    /// Kernel spectrum: half (`n/2 + 1` bins) for [`FftVariant::Rfft`],
    /// full (`n` bins) for [`FftVariant::Complex`].
    kr: Vec<f64>,
    ki: Vec<f64>,
    /// `exp(-2πi k/n)` (forward; inverse conjugates), sized per variant
    /// by [`build_twiddles`]: `n/2` entries for the radix-2 complex lane,
    /// `3n/4` for the RFFT's radix-4 `w, w², w³` accesses.
    twr: Vec<f64>,
    twi: Vec<f64>,
}

impl ConvPlan {
    /// Plan for circular convolution with fixed kernel `k` (power-of-two
    /// len) on the active [`variant`].
    pub fn new(k: &[f64]) -> ConvPlan {
        ConvPlan::with_variant(k, variant())
    }

    /// Plan on an explicitly chosen engine, independent of the process
    /// default — the race-free way for tests/benches to A/B the engines
    /// without mutating global state.
    pub fn with_variant(k: &[f64], variant: FftVariant) -> ConvPlan {
        let n = k.len();
        assert!(n.is_power_of_two());
        let (twr, twi) = build_twiddles(n, variant);
        let (kr, ki) = match variant {
            FftVariant::Complex => {
                let mut kr = k.to_vec();
                let mut ki = vec![0.0; n];
                fft_tabled(&mut kr, &mut ki, false, &twr, &twi);
                (kr, ki)
            }
            // the kernel's half spectrum, on the plan's own twiddle
            // tables (a plain `rfft(k)` call would rebuild them)
            FftVariant::Rfft => rfft_with_tables(k, &twr, &twi),
        };
        ConvPlan {
            n,
            variant,
            kr,
            ki,
            twr,
            twi,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The engine this plan was built on (fixed at construction).
    pub fn variant(&self) -> FftVariant {
        self.variant
    }

    /// How many rows the batch kernel processes per block — size per-block
    /// data scratch as `batch_block_rows() * len()` (plus
    /// [`ConvPlan::batch_scratch_len`] of shared spectrum scratch).
    pub fn batch_block_rows(&self) -> usize {
        // keep a block's f64 buffers within ~256 KiB
        ((1usize << 14) / self.n.max(1)).clamp(1, MAX_FFT_BLOCK_ROWS)
    }

    /// Scratch doubles the caller must hand to
    /// [`ConvPlan::apply_batch_in_place`] alongside a `rows`-row data
    /// buffer. The complex lane needs a full imaginary image (`rows · n`);
    /// the RFFT lane needs one packed-spectrum row (`n`) shared by every
    /// row — half the checkout of the old engine for any `rows >= 2`.
    pub fn batch_scratch_len(&self, rows: usize) -> usize {
        match self.variant {
            FftVariant::Complex => rows * self.n,
            FftVariant::Rfft => self.n,
        }
    }

    /// Rough per-matvec cost in the engine's ~f32-butterfly-op units (an
    /// f64 complex butterfly counts ≈ 8): two full-length radix-2 FFT
    /// sweeps plus the spectrum multiply for the complex lane; two
    /// half-length sweeps plus the fused half-spectrum pass for the RFFT
    /// lane. Feeds `Transform::batch_work_per_row` so the pool's work gate
    /// tracks the active engine.
    pub fn matvec_work(&self) -> usize {
        let m = self.n.max(2);
        let lg = m.ilog2() as usize + 1;
        match self.variant {
            FftVariant::Complex => 8 * (2 * m * lg + m),
            FftVariant::Rfft => 8 * (m * lg + m),
        }
    }

    /// `out = kernel ⊛ x` (circular).
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.n);
        let mut re = x.to_vec();
        let mut im = vec![0.0; self.batch_scratch_len(1)];
        self.apply_in_place(&mut re, &mut im);
        re
    }

    /// `re = kernel ⊛ re` (circular), in place. `im` is caller-provided
    /// scratch of [`ConvPlan::batch_scratch_len`]`(1)` (= `len()`)
    /// doubles, overwritten — its incoming contents never reach the
    /// output. The single-row case of [`ConvPlan::apply_batch_in_place`] —
    /// the two share one code path so the per-row and batch engines stay
    /// bit-for-bit identical.
    pub fn apply_in_place(&self, re: &mut [f64], im: &mut [f64]) {
        debug_assert_eq!(re.len(), self.n);
        self.apply_batch_in_place(re, im);
    }

    /// Multi-row circular convolution: `re` holds `rows` row-major rows of
    /// `len()` each (`re = kernel ⊛ re` per row), `im` is caller scratch of
    /// [`ConvPlan::batch_scratch_len`]`(rows)` doubles. The plan's
    /// precomputed twiddle tables and the caller's scratch are shared
    /// across every row; each row runs to completion (forward FFT,
    /// spectrum multiply, inverse FFT) so it stays L1-resident — a
    /// level-major ordering across rows was tried and REVERTED:
    /// re-streaming the block once per butterfly level measured slower
    /// than per-row traversal at n >= 512 (C-mirror calibration, PR 2).
    /// This is the batch kernel under every circulant/Toeplitz/Hankel/skew
    /// family.
    ///
    /// On the RFFT lane the scratch holds the packed half-size spectrum
    /// and is **fully overwritten** before any read (dirty checkouts need
    /// no zeroing); on the complex lane it is the semantic all-zero
    /// imaginary input plane and is cleared here on every call.
    pub fn apply_batch_in_place(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(re.len() % n.max(1), 0);
        if n <= 1 {
            // 1-point FFT: pointwise scale by the kernel only.
            for v in re.iter_mut() {
                *v *= self.kr[0];
            }
            return;
        }
        match self.variant {
            FftVariant::Complex => {
                debug_assert_eq!(im.len(), re.len());
                im.fill(0.0);
                for (rr, ri) in re.chunks_exact_mut(n).zip(im.chunks_exact_mut(n)) {
                    fft_tabled(rr, ri, false, &self.twr, &self.twi);
                    simd::cmul(rr, ri, &self.kr, &self.ki);
                    fft_tabled(rr, ri, true, &self.twr, &self.twi);
                }
            }
            FftVariant::Rfft => {
                debug_assert!(im.len() >= n);
                let h = n / 2;
                let (zre, zim) = im[..n].split_at_mut(h);
                for row in re.chunks_exact_mut(n) {
                    // pack: z[k] = row[2k] + i·row[2k+1] (overwrites all
                    // scratch this pass reads)
                    for k in 0..h {
                        zre[k] = row[2 * k];
                        zim[k] = row[2 * k + 1];
                    }
                    fft_radix4_tabled(zre, zim, false, &self.twr, &self.twi, n);
                    simd::cmul_half(zre, zim, &self.kr, &self.ki, &self.twr, &self.twi);
                    fft_radix4_tabled(zre, zim, true, &self.twr, &self.twi, n);
                    for k in 0..h {
                        row[2 * k] = zre[k];
                        row[2 * k + 1] = zim[k];
                    }
                }
            }
        }
    }
}

/// Multiply by the circulant matrix whose **first row** is `row`:
/// `y_i = sum_j row_{(j - i) mod n} x_j`. One-shot [`ConvPlan`] under the
/// hood — the same kernel every planned matvec runs.
pub fn circulant_matvec(row: &[f64], x: &[f64]) -> Vec<f64> {
    // first-row circulant C satisfies C x = reverse-shift trick:
    // y = IFFT(FFT(c_col) * FFT(x)) where c_col is the first column:
    // c_col[i] = row[(n - i) % n].
    let n = row.len();
    let mut col = vec![0.0; n];
    for i in 0..n {
        col[i] = row[(n - i) % n];
    }
    ConvPlan::new(&col).apply(x)
}

/// Multiply by the Toeplitz matrix `T` with `T[i][j] = diag[j - i + (n-1)]`,
/// where `diag` has length `2n - 1` (entry `n-1` is the main diagonal,
/// entries above it the superdiagonals). Uses 2n-point circulant embedding.
pub fn toeplitz_matvec(diag: &[f64], x: &[f64]) -> Vec<f64> {
    let n = x.len();
    debug_assert_eq!(diag.len(), 2 * n - 1);
    let m = (2 * n).next_power_of_two();
    // Embed: circulant first column c with c[k] = T[k][0] = diag[n-1-k] for
    // k in 0..n, and wrap the superdiagonals at the end.
    let mut c = vec![0.0; m];
    for i in 0..n {
        c[i] = diag[n - 1 - i]; // first column, top to bottom
    }
    for j in 1..n {
        c[m - j] = diag[n - 1 + j]; // superdiagonal j wraps to position m-j
    }
    let mut xx = vec![0.0; m];
    xx[..n].copy_from_slice(x);
    let mut y = ConvPlan::new(&c).apply(&xx);
    y.truncate(n);
    y
}

/// Multiply by the Hankel matrix `Hk[i][j] = anti[i + j]` where `anti` has
/// length `2n - 1`. A Hankel matrix is a row-reversed Toeplitz: `Hk x = T xr`
/// with `xr` the reversed input.
pub fn hankel_matvec(anti: &[f64], x: &[f64]) -> Vec<f64> {
    let n = x.len();
    debug_assert_eq!(anti.len(), 2 * n - 1);
    // Hk[i][j] = anti[i+j]; with xr[j] = x[n-1-j]:
    // (T xr)_i = sum_j T[i][j] x[n-1-j]; choose T[i][j] = anti[i + n-1 - j]
    // i.e. T diag index (j - i + n - 1) -> anti[i + n - 1 - j] means
    // diag[d] = anti[2(n-1) - d].
    let mut diag = vec![0.0; 2 * n - 1];
    for d in 0..2 * n - 1 {
        diag[d] = anti[2 * (n - 1) - d];
    }
    let xr: Vec<f64> = x.iter().rev().copied().collect();
    toeplitz_matvec(&diag, &xr)
}

/// Multiply by the skew-circulant matrix with first row `row`:
/// like a circulant but entries that wrap around pick up a minus sign
/// (`S[i][j] = row[j-i]` for `j >= i`, `-row[n + j - i]` for `j < i`).
pub fn skew_circulant_matvec(row: &[f64], x: &[f64]) -> Vec<f64> {
    // A skew-circulant is the Toeplitz matrix with diag[d] = row[d - (n-1)]
    // for d >= n-1 (upper part incl. main diag) and -row[d + 1] for d < n-1.
    let n = row.len();
    let mut diag = vec![0.0; 2 * n - 1];
    for d in 0..2 * n - 1 {
        diag[d] = if d >= n - 1 {
            row[d - (n - 1)]
        } else {
            -row[d + 1]
        };
    }
    toeplitz_matvec(&diag, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::rng::Rng;

    fn naive_dft(re: &[f64], im: &[f64], inverse: bool) -> (Vec<f64>, Vec<f64>) {
        let n = re.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut or_ = vec![0.0; n];
        let mut oi = vec![0.0; n];
        for k in 0..n {
            for t in 0..n {
                let ang = sign * 2.0 * PI * (k * t) as f64 / n as f64;
                or_[k] += re[t] * ang.cos() - im[t] * ang.sin();
                oi[k] += re[t] * ang.sin() + im[t] * ang.cos();
            }
        }
        if inverse {
            for v in or_.iter_mut() {
                *v /= n as f64;
            }
            for v in oi.iter_mut() {
                *v /= n as f64;
            }
        }
        (or_, oi)
    }

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = Rng::new(11);
        for n in [1usize, 2, 4, 8, 32, 128] {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (er, ei) = naive_dft(&re, &im, false);
            let (mut gr, mut gi) = (re.clone(), im.clone());
            fft(&mut gr, &mut gi, false);
            for i in 0..n {
                assert!((gr[i] - er[i]).abs() < 1e-8 * n as f64, "n={n}");
                assert!((gi[i] - ei[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        }
    }

    #[test]
    fn fft_round_trip() {
        for_all(24, |g| {
            let n = g.pow2_in(0, 9);
            let re: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let im: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let (mut rr, mut ri) = (re.clone(), im.clone());
            fft(&mut rr, &mut ri, false);
            fft(&mut rr, &mut ri, true);
            for i in 0..n {
                assert!((rr[i] - re[i]).abs() < 1e-9);
                assert!((ri[i] - im[i]).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn parseval() {
        for_all(16, |g| {
            let n = g.pow2_in(1, 8);
            let re: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let mut im = vec![0.0; n];
            let energy: f64 = re.iter().map(|v| v * v).sum();
            let mut fr = re;
            fft(&mut fr, &mut im, false);
            let fenergy: f64 =
                fr.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / n as f64;
            assert!((energy - fenergy).abs() < 1e-8 * energy.max(1.0));
        });
    }

    fn naive_circulant(row: &[f64], x: &[f64]) -> Vec<f64> {
        let n = row.len();
        (0..n)
            .map(|i| (0..n).map(|j| row[(n + j - i) % n] * x[j]).sum())
            .collect()
    }

    #[test]
    fn circulant_matches_naive() {
        for_all(24, |g| {
            let n = g.pow2_in(0, 7);
            let row: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect = naive_circulant(&row, &x);
            let got = circulant_matvec(&row, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn toeplitz_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 70);
            let diag: Vec<f64> = (0..2 * n - 1).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| diag[j + n - 1 - i] * x[j]).sum())
                .collect();
            let got = toeplitz_matvec(&diag, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn hankel_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 60);
            let anti: Vec<f64> = (0..2 * n - 1).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| anti[i + j] * x[j]).sum())
                .collect();
            let got = hankel_matvec(&anti, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn skew_circulant_matches_naive() {
        for_all(24, |g| {
            let n = g.usize_in(1, 60);
            let row: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let expect: Vec<f64> = (0..n)
                .map(|i| {
                    (0..n)
                        .map(|j| {
                            if j >= i {
                                row[j - i] * x[j]
                            } else {
                                -row[n + j - i] * x[j]
                            }
                        })
                        .sum()
                })
                .collect();
            let got = skew_circulant_matvec(&row, &x);
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-8 * n as f64, "n={n}");
            }
        });
    }

    #[test]
    fn plan_batch_matches_single_row_bitwise() {
        // The multi-row kernel must reproduce the single-row path bit for
        // bit — this is what keeps apply_into and apply_batch_serial
        // interchangeable for every FFT-backed family.
        for_all(16, |g| {
            let n = g.pow2_in(0, 8);
            let rows = g.usize_in(1, 12);
            let mut rng = Rng::new(g.u64());
            let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan = ConvPlan::new(&k);
            let batch: Vec<f64> = (0..rows * n).map(|_| rng.gaussian()).collect();
            let mut expect = Vec::with_capacity(rows * n);
            for row in batch.chunks_exact(n) {
                let mut re = row.to_vec();
                let mut im = vec![0.0; plan.batch_scratch_len(1)];
                plan.apply_in_place(&mut re, &mut im);
                expect.extend_from_slice(&re);
            }
            let mut re = batch;
            let mut im = vec![0.0; plan.batch_scratch_len(rows)];
            plan.apply_batch_in_place(&mut re, &mut im);
            assert_eq!(re, expect, "n={n} rows={rows}");
        });
    }

    #[test]
    fn plan_scratch_reuse_is_clean() {
        // dirty im scratch (and dirty padding in re from a previous call)
        // must not leak into results — for BOTH engines (the complex lane
        // clears its imaginary plane internally; the RFFT lane fully
        // overwrites its packed-spectrum scratch before reading it).
        let mut rng = Rng::new(17);
        let n = 32;
        let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        for v in [FftVariant::Rfft, FftVariant::Complex] {
            let plan = ConvPlan::with_variant(&k, v);
            assert_eq!(plan.variant(), v);
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let clean = plan.apply(&x);
            let mut re = x.clone();
            let mut im: Vec<f64> = (0..plan.batch_scratch_len(1))
                .map(|_| rng.gaussian())
                .collect(); // garbage
            plan.apply_in_place(&mut re, &mut im);
            assert_eq!(re, clean, "variant={v:?}");
        }
    }

    #[test]
    fn rfft_matches_complex_fft_half_spectrum() {
        // forward oracle across n ∈ {1 .. 2^14}: the RFFT's half spectrum
        // must match bins 0..=n/2 of the (naive-DFT-verified) complex FFT.
        let mut rng = Rng::new(41);
        for lg in 0..=14usize {
            let n = 1usize << lg;
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (xr, xi) = rfft(&x);
            assert_eq!(xr.len(), n / 2 + 1);
            assert_eq!(xi.len(), n / 2 + 1);
            let mut fr = x.clone();
            let mut fi = vec![0.0; n];
            fft(&mut fr, &mut fi, false);
            let tol = 1e-9 * (n as f64).max(1.0);
            for k in 0..=n / 2 {
                assert!((xr[k] - fr[k]).abs() < tol, "n={n} k={k}: {} vs {}", xr[k], fr[k]);
                assert!((xi[k] - fi[k]).abs() < tol, "n={n} k={k}: {} vs {}", xi[k], fi[k]);
            }
            // bins 0 and n/2 of a real signal are exactly real
            assert_eq!(xi[0], 0.0, "n={n}");
            assert_eq!(xi[n / 2], 0.0, "n={n}");
        }
    }

    #[test]
    fn irfft_matches_complex_inverse() {
        // inverse oracle: irfft of a Hermitian half spectrum == the
        // complex inverse FFT of its full Hermitian extension.
        let mut rng = Rng::new(43);
        for lg in 1..=14usize {
            let n = 1usize << lg;
            let h = n / 2;
            let xr: Vec<f64> = (0..=h).map(|_| rng.gaussian()).collect();
            let mut xi: Vec<f64> = (0..=h).map(|_| rng.gaussian()).collect();
            xi[0] = 0.0;
            xi[h] = 0.0;
            let x = irfft(&xr, &xi);
            // full Hermitian extension -> complex inverse
            let mut fr = vec![0.0; n];
            let mut fi = vec![0.0; n];
            fr[..=h].copy_from_slice(&xr);
            fi[..=h].copy_from_slice(&xi);
            for k in h + 1..n {
                fr[k] = xr[n - k];
                fi[k] = -xi[n - k];
            }
            fft(&mut fr, &mut fi, true);
            let tol = 1e-11 * (n as f64).max(1.0);
            for t in 0..n {
                assert!((x[t] - fr[t]).abs() < tol, "n={n} t={t}");
                assert!(fi[t].abs() < tol, "n={n} t={t}: inverse not real");
            }
        }
    }

    #[test]
    fn rfft_round_trip() {
        for_all(24, |g| {
            let n = 1usize << g.usize_in(0, 12);
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let (xr, xi) = rfft(&x);
            let back = irfft(&xr, &xi);
            assert_eq!(back.len(), n);
            for t in 0..n {
                assert!((back[t] - x[t]).abs() < 1e-10, "n={n} t={t}");
            }
        });
    }

    #[test]
    fn rfft_parseval_on_half_spectrum() {
        // sum x^2 == (|X0|^2 + |X_{n/2}|^2 + 2·sum_{1..n/2} |Xk|^2) / n —
        // the Hermitian bins carry double weight.
        for_all(16, |g| {
            let n = 1usize << g.usize_in(1, 12);
            let x: Vec<f64> = (0..n).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let energy: f64 = x.iter().map(|v| v * v).sum();
            let (xr, xi) = rfft(&x);
            let h = n / 2;
            let mut fenergy = xr[0] * xr[0] + xi[0] * xi[0] + xr[h] * xr[h] + xi[h] * xi[h];
            for k in 1..h {
                fenergy += 2.0 * (xr[k] * xr[k] + xi[k] * xi[k]);
            }
            fenergy /= n as f64;
            assert!(
                (energy - fenergy).abs() < 1e-8 * energy.max(1.0),
                "n={n}: {energy} vs {fenergy}"
            );
        });
    }

    #[test]
    fn plan_variants_agree() {
        // same kernel, same input: the RFFT plan and the legacy complex
        // plan must agree to f64 round-off at every size, single-row and
        // batch.
        let mut rng = Rng::new(47);
        for lg in 0..=10usize {
            let n = 1usize << lg;
            let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan_r = ConvPlan::with_variant(&k, FftVariant::Rfft);
            let plan_c = ConvPlan::with_variant(&k, FftVariant::Complex);
            let rows = 3;
            let xs: Vec<f64> = (0..rows * n).map(|_| rng.gaussian()).collect();
            let mut got_r = xs.clone();
            let mut scratch_r = vec![0.0; plan_r.batch_scratch_len(rows)];
            plan_r.apply_batch_in_place(&mut got_r, &mut scratch_r);
            let mut got_c = xs.clone();
            let mut scratch_c = vec![0.0; plan_c.batch_scratch_len(rows)];
            plan_c.apply_batch_in_place(&mut got_c, &mut scratch_c);
            let scale: f64 = k.iter().map(|v| v.abs()).sum::<f64>().max(1.0);
            for i in 0..rows * n {
                assert!(
                    (got_r[i] - got_c[i]).abs() < 1e-9 * scale,
                    "n={n} i={i}: rfft {} vs complex {}",
                    got_r[i],
                    got_c[i]
                );
            }
        }
    }

    #[test]
    fn plan_scratch_halved_under_rfft() {
        let ones = [1.0f64; 64];
        let plan_r = ConvPlan::with_variant(&ones, FftVariant::Rfft);
        let plan_c = ConvPlan::with_variant(&ones, FftVariant::Complex);
        assert_eq!(plan_r.batch_scratch_len(8), 64); // one shared spectrum row
        assert_eq!(plan_c.batch_scratch_len(8), 8 * 64); // full imaginary image
        assert!(plan_r.matvec_work() < plan_c.matvec_work());
    }

    #[test]
    fn batch_block_rows_bounds() {
        for n in [1usize, 2, 64, 1024, 1 << 14, 1 << 16] {
            let k = vec![1.0f64; n];
            let plan = ConvPlan::new(&k);
            let b = plan.batch_block_rows();
            assert!((1..=8).contains(&b), "n={n} -> block {b}");
        }
    }

    #[test]
    fn conv_plan_matches_one_shot() {
        let mut rng = Rng::new(13);
        let n = 64;
        let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = ConvPlan::new(&k);
        let a = plan.apply(&x);
        let b = circular_convolve(&k, &x);
        for i in 0..n {
            assert!((a[i] - b[i]).abs() < 1e-9);
        }
    }
}
