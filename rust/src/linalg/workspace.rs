//! Reusable scratch-buffer arenas for the zero-allocation execution path.
//!
//! Every [`crate::transform::Transform`] draws its intermediate buffers from
//! a [`Workspace`] instead of allocating per call: after the first apply has
//! warmed the pools (or [`crate::transform::Transform::make_workspace`] has
//! pre-warmed them), the hot path performs no heap allocations at all.
//!
//! [`WorkspacePool`] holds one `Workspace` per batch worker so
//! `apply_batch_into` can shard rows across `std::thread::scope` threads
//! (gateway-batcher style), each worker reusing its own scratch across
//! batches.
//!
//! Buffers are checked out by value ([`Workspace::take_f32`] /
//! [`Workspace::take_f64`]) and returned with the matching `put_*`, which
//! makes nested use (a stacked transform borrowing a block buffer while its
//! blocks borrow FFT scratch) trivially safe. Check-outs are LIFO: as long
//! as a call site takes and returns buffers in a consistent order, the same
//! allocation is recycled every call.

/// Minimum batch rows assigned to one worker before another thread is
/// spawned — below this, thread-spawn latency dominates the kernel time.
pub const MIN_ROWS_PER_WORKER: usize = 8;

/// Grow-only pool of f32/f64 scratch buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    f32_pool: Vec<Vec<f32>>,
    f64_pool: Vec<Vec<f64>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out an f32 buffer of exactly `len` elements, all zero.
    /// Reuses a pooled allocation when one is available.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.f32_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer checked out with [`Workspace::take_f32`].
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        self.f32_pool.push(buf);
    }

    /// Check out an f64 buffer of exactly `len` elements, all zero.
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        let mut b = self.f64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer checked out with [`Workspace::take_f64`].
    pub fn put_f64(&mut self, buf: Vec<f64>) {
        self.f64_pool.push(buf);
    }
}

/// Batch-execution worker count: the `TS_WORKERS` env var when set (>= 1),
/// otherwise `available_parallelism` capped at 8.
pub fn worker_count_from_env() -> usize {
    std::env::var("TS_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|w| *w >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(8)
        })
}

/// One [`Workspace`] per batch worker, reused across `apply_batch_into`
/// calls. Slots are created lazily and never shrink.
#[derive(Debug)]
pub struct WorkspacePool {
    slots: Vec<Workspace>,
    workers: usize,
}

impl WorkspacePool {
    /// Pool targeting a fixed worker count (clamped to >= 1).
    pub fn new(workers: usize) -> WorkspacePool {
        WorkspacePool {
            slots: Vec::new(),
            workers: workers.max(1),
        }
    }

    /// Pool sized by [`worker_count_from_env`].
    pub fn from_env() -> WorkspacePool {
        WorkspacePool::new(worker_count_from_env())
    }

    /// Target worker count (the actual count per batch is additionally
    /// capped so each worker gets at least [`MIN_ROWS_PER_WORKER`] rows).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Mutable access to the first `k` per-worker workspaces.
    pub fn slots_mut(&mut self, k: usize) -> &mut [Workspace] {
        while self.slots.len() < k {
            self.slots.push(Workspace::new());
        }
        &mut self.slots[..k]
    }

    /// Mutable access to one slot (created on demand).
    pub fn slot(&mut self, i: usize) -> &mut Workspace {
        &mut self.slots_mut(i + 1)[i]
    }
}

impl Default for WorkspacePool {
    fn default() -> Self {
        WorkspacePool::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_exact_len() {
        let mut ws = Workspace::new();
        let a = ws.take_f32(7);
        assert_eq!(a.len(), 7);
        assert!(a.iter().all(|v| *v == 0.0));
        let b = ws.take_f64(3);
        assert_eq!(b.len(), 3);
        ws.put_f64(b);
        ws.put_f32(a);
    }

    #[test]
    fn put_then_take_reuses_allocation() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(16);
        a[0] = 5.0;
        let ptr = a.as_ptr();
        ws.put_f32(a);
        let b = ws.take_f32(16);
        assert_eq!(b.as_ptr(), ptr, "same allocation must be recycled");
        assert_eq!(b[0], 0.0, "recycled buffer must be re-zeroed");
        ws.put_f32(b);
    }

    #[test]
    fn pool_slots_are_distinct_and_persistent() {
        let mut pool = WorkspacePool::new(3);
        assert_eq!(pool.workers(), 3);
        pool.slot(0).put_f32(vec![1.0; 4]);
        assert_eq!(pool.slots_mut(3).len(), 3);
        // slot 0 kept its pooled buffer; slot 1 starts empty
        let a = pool.slot(0).take_f32(4);
        assert_eq!(a.len(), 4);
        pool.slot(0).put_f32(a);
    }

    #[test]
    fn worker_count_at_least_one() {
        assert!(worker_count_from_env() >= 1);
        assert_eq!(WorkspacePool::new(0).workers(), 1);
    }
}
