//! Reusable scratch-buffer arenas for the zero-allocation execution path.
//!
//! Every [`crate::transform::Transform`] draws its intermediate buffers from
//! a [`Workspace`] instead of allocating per call: after the first apply has
//! warmed the pools (or [`crate::transform::Transform::make_workspace`] has
//! pre-warmed them), the hot path performs no heap allocations at all.
//!
//! Batch execution pins one `Workspace` per worker thread inside the
//! persistent [`crate::runtime::WorkerPool`] — the worker owns its scratch
//! for its whole lifetime, so warm buffers survive across batches without
//! any hand-off.
//!
//! Buffers are checked out by value ([`Workspace::take_f32`] /
//! [`Workspace::take_f64`]) and returned with the matching `put_*`, which
//! makes nested use (a stacked transform borrowing a block buffer while its
//! blocks borrow FFT scratch) trivially safe. Check-outs are LIFO: as long
//! as a call site takes and returns buffers in a consistent order, the same
//! allocation is recycled every call.
//!
//! Two checkout flavors exist: the zeroed `take_*` (for buffers whose
//! padding/prefix semantics rely on zeros) and the **dirty**
//! `take_*_uninit` (length set, contents arbitrary — stale data from the
//! previous checkout). Call sites that fully overwrite their buffer
//! (FWHT stage rows, FFT row blocks, batch stacking scratch) use the dirty
//! variant and skip the zeroing sweep the zeroed variant pays on every
//! checkout. The FFT families' spectrum scratch is dirty too: the default
//! RFFT engine checks out **one plan-length row** per batch
//! (`ConvPlan::batch_scratch_len`) and fully overwrites it per row, while
//! the legacy complex lane's full-batch imaginary plane is re-zeroed
//! inside the plan kernel where it is semantically required.

/// Minimum batch rows assigned to one worker before another thread is
/// engaged — below this, dispatch latency dominates the kernel time.
pub const MIN_ROWS_PER_WORKER: usize = 8;

/// Grow-only pool of f32/f64 scratch buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    f32_pool: Vec<Vec<f32>>,
    f64_pool: Vec<Vec<f64>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out an f32 buffer of exactly `len` elements, all zero.
    /// Reuses a pooled allocation when one is available.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.f32_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Dirty checkout: an f32 buffer of exactly `len` elements whose
    /// contents are **arbitrary** (stale data from a previous checkout;
    /// only net growth beyond the recycled length is zero-filled). For
    /// call sites that fully overwrite the buffer — skips the full zeroing
    /// sweep [`Workspace::take_f32`] pays.
    pub fn take_f32_uninit(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.f32_pool.pop().unwrap_or_default();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer checked out with [`Workspace::take_f32`] /
    /// [`Workspace::take_f32_uninit`].
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        self.f32_pool.push(buf);
    }

    /// Check out an f64 buffer of exactly `len` elements, all zero.
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        let mut b = self.f64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Dirty checkout: an f64 buffer of exactly `len` elements, contents
    /// arbitrary (see [`Workspace::take_f32_uninit`]).
    pub fn take_f64_uninit(&mut self, len: usize) -> Vec<f64> {
        let mut b = self.f64_pool.pop().unwrap_or_default();
        b.resize(len, 0.0);
        b
    }

    /// Return a buffer checked out with [`Workspace::take_f64`] /
    /// [`Workspace::take_f64_uninit`].
    pub fn put_f64(&mut self, buf: Vec<f64>) {
        self.f64_pool.push(buf);
    }
}

/// Pure worker-count resolution from an optional `TS_WORKERS` value:
/// a parseable value `w` resolves to `max(w, 1)` — **`0` means "stay
/// single-threaded"**, not "pick a default" — while unset / unparseable
/// falls back to `available_parallelism` capped at 8.
pub fn resolve_worker_count(ts_workers: Option<&str>) -> usize {
    match ts_workers.and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(w) => w.max(1),
        None => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8),
    }
}

/// Batch-execution worker count from the environment (`TS_WORKERS`);
/// see [`resolve_worker_count`] for the rules.
pub fn worker_count_from_env() -> usize {
    resolve_worker_count(std::env::var("TS_WORKERS").ok().as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_exact_len() {
        let mut ws = Workspace::new();
        let a = ws.take_f32(7);
        assert_eq!(a.len(), 7);
        assert!(a.iter().all(|v| *v == 0.0));
        let b = ws.take_f64(3);
        assert_eq!(b.len(), 3);
        ws.put_f64(b);
        ws.put_f32(a);
    }

    #[test]
    fn put_then_take_reuses_allocation() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(16);
        a[0] = 5.0;
        let ptr = a.as_ptr();
        ws.put_f32(a);
        let b = ws.take_f32(16);
        assert_eq!(b.as_ptr(), ptr, "same allocation must be recycled");
        assert_eq!(b[0], 0.0, "recycled buffer must be re-zeroed");
        ws.put_f32(b);
    }

    #[test]
    fn uninit_take_sets_length_and_reuses_allocation() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(16);
        a.iter_mut().for_each(|v| *v = 7.0);
        let ptr = a.as_ptr();
        ws.put_f32(a);
        // dirty checkout: same allocation, same length, stale contents
        // permitted (no zeroing sweep) — callers must fully overwrite
        let b = ws.take_f32_uninit(16);
        assert_eq!(b.as_ptr(), ptr);
        assert_eq!(b.len(), 16);
        ws.put_f32(b);
        // growth beyond the recycled length is still zero-filled
        let c = ws.take_f32_uninit(32);
        assert_eq!(c.len(), 32);
        assert!(c[16..].iter().all(|v| *v == 0.0));
        ws.put_f32(c);
        // and the zeroed variant continues to clear recycled contents
        let d = ws.take_f32(32);
        assert!(d.iter().all(|v| *v == 0.0));
        ws.put_f32(d);

        let mut e = ws.take_f64_uninit(8);
        e[0] = 3.0;
        let eptr = e.as_ptr();
        ws.put_f64(e);
        let f = ws.take_f64_uninit(8);
        assert_eq!(f.as_ptr(), eptr);
        assert_eq!(f.len(), 8);
        let g = ws.take_f64(8);
        assert!(g.iter().all(|v| *v == 0.0));
        ws.put_f64(g);
        ws.put_f64(f);
    }

    #[test]
    fn worker_count_zero_degrades_to_serial() {
        // TS_WORKERS=0 must mean "single-threaded", never "use the default".
        assert_eq!(resolve_worker_count(Some("0")), 1);
        assert_eq!(resolve_worker_count(Some(" 0 ")), 1);
    }

    #[test]
    fn worker_count_explicit_values_respected() {
        assert_eq!(resolve_worker_count(Some("1")), 1);
        assert_eq!(resolve_worker_count(Some("3")), 3);
        // values larger than the machine are allowed here; the per-batch
        // cap (WorkerPool::workers_for) bounds the actual fan-out.
        assert_eq!(resolve_worker_count(Some("64")), 64);
    }

    #[test]
    fn worker_count_garbage_falls_back_to_default() {
        for v in [None, Some(""), Some("abc"), Some("-3"), Some("2.5")] {
            let w = resolve_worker_count(v);
            assert!((1..=8).contains(&w), "{v:?} -> {w}");
        }
    }

    #[test]
    fn worker_count_from_env_at_least_one() {
        assert!(worker_count_from_env() >= 1);
    }
}
