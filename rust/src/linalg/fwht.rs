//! Fast Walsh–Hadamard Transform (FWHT).
//!
//! The workhorse of every Hadamard-based TripleSpin matrix: `HD x` costs one
//! diagonal scaling plus one FWHT, `O(n log n)` instead of the `O(n^2)` dense
//! matvec. This replaces the `ffht` C library the paper's experiments used.
//!
//! Conventions: [`fwht`] applies the *unnormalized* Hadamard matrix (entries
//! ±1); the paper's `H` is the L2-normalized matrix, i.e. `fwht` output
//! scaled by `1/sqrt(n)` — use [`fwht_normalized`]. Both operate in place on
//! power-of-two lengths.
//!
//! Butterfly levels with `h >= 4` run through the runtime-dispatched SIMD
//! kernels in [`crate::linalg::simd`] (AVX2/SSE2/NEON with a `TS_NO_SIMD=1`
//! scalar path) — every dispatch level is bit-identical, so the transform's
//! output does not depend on the host CPU.

use crate::linalg::simd;

/// In-place unnormalized FWHT. `x.len()` must be a power of two.
///
/// After the call `x = H̃ x` where `H̃` has ±1 entries (Sylvester order).
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    // First two levels fused in blocks of 4 (in-register radix-4 head);
    // the remaining levels run radix-2 through the dispatched SIMD
    // butterfly with a contiguous inner loop. A full radix-4 sweep was
    // tried and REVERTED: its 4-way strided inner loop defeats
    // vectorization and measured 13% slower at n=8192 (see EXPERIMENTS.md
    // §Perf, L3 iteration 2).
    if n == 2 {
        let (a, b) = (x[0], x[1]);
        x[0] = a + b;
        x[1] = a - b;
        return;
    }
    let mut h = 1;
    if n >= 4 {
        // fused h=1 and h=2 pass over blocks of 4
        let mut i = 0;
        while i < n {
            let (a, b, c, d) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
            let (ab0, ab1) = (a + b, a - b);
            let (cd0, cd1) = (c + d, c - d);
            x[i] = ab0 + cd0;
            x[i + 1] = ab1 + cd1;
            x[i + 2] = ab0 - cd0;
            x[i + 3] = ab1 - cd1;
            i += 4;
        }
        h = 4;
    }
    while h < n {
        let mut i = 0;
        while i < n {
            let (head, tail) = x[i..i + 2 * h].split_at_mut(h);
            simd::butterfly(head, tail);
            i += h * 2;
        }
        h *= 2;
    }
}

/// In-place L2-normalized FWHT: `x = H x` with `H = H̃ / sqrt(n)` (an
/// isometry, `H H = I`).
///
/// The `1/√n` scaling is folded into the **last butterfly level** instead of
/// a separate full pass over the buffer — one fewer memory sweep per call,
/// and bit-for-bit identical to `fwht` + scale (the multiply sees the exact
/// same operand either way).
pub fn fwht_normalized(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    if n <= 1 {
        return;
    }
    let s = 1.0 / (n as f32).sqrt();
    if n == 2 {
        let (a, b) = (x[0], x[1]);
        x[0] = (a + b) * s;
        x[1] = (a - b) * s;
        return;
    }
    let mut h;
    if n >= 8 {
        // fused radix-4 head (levels h=1,2) — safe here because the last
        // level, which carries the scale, is h = n/2 >= 4
        let mut i = 0;
        while i < n {
            let (a, b, c, d) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
            let (ab0, ab1) = (a + b, a - b);
            let (cd0, cd1) = (c + d, c - d);
            x[i] = ab0 + cd0;
            x[i + 1] = ab1 + cd1;
            x[i + 2] = ab0 - cd0;
            x[i + 3] = ab1 - cd1;
            i += 4;
        }
        h = 4;
    } else {
        // n == 4: plain h=1 level; h=2 is the fused last level below
        let mut i = 0;
        while i < n {
            let (a, b) = (x[i], x[i + 1]);
            x[i] = a + b;
            x[i + 1] = a - b;
            i += 2;
        }
        h = 2;
    }
    while h < n / 2 {
        let mut i = 0;
        while i < n {
            let (head, tail) = x[i..i + 2 * h].split_at_mut(h);
            simd::butterfly(head, tail);
            i += h * 2;
        }
        h *= 2;
    }
    // last level (h = n/2, one block spanning the whole buffer) with the
    // 1/√n normalization fused into the butterfly outputs
    debug_assert_eq!(h, n / 2);
    let (head, tail) = x.split_at_mut(n / 2);
    simd::butterfly_scaled(head, tail, s);
}

/// Unnormalized FWHT over every row of a row-major `rows x n` batch,
/// bit-for-bit identical to calling [`fwht`] on each row.
///
/// Internally this IS a per-row traversal: each row runs all butterfly
/// levels while it is L1-resident. A level-major organization (every level
/// swept across a block of rows before the next level) was shipped in PR 1
/// and REVERTED here: calibration against a C mirror of both kernels
/// measured level-major 5–35% slower across n = 32..4096 — re-streaming
/// the block once per level trades L1 hits for L2 traffic, and the per-row
/// butterfly schedule is too cheap to be worth amortizing (PR 2,
/// tools/bench_mirror.c).
pub fn fwht_batch(data: &mut [f32], n: usize) {
    if n <= 1 || data.is_empty() {
        return;
    }
    debug_assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    debug_assert_eq!(data.len() % n, 0);
    for row in data.chunks_exact_mut(n) {
        fwht(row);
    }
}

/// Apply the normalized FWHT to every row of a row-major `rows x n` batch
/// (per-row [`fwht_normalized`], so the `1/√n` scale stays fused into each
/// row's last butterfly level — no separate scale sweep).
pub fn fwht_batch_normalized(data: &mut [f32], n: usize) {
    if n == 0 {
        return;
    }
    debug_assert_eq!(data.len() % n, 0);
    for row in data.chunks_exact_mut(n) {
        fwht_normalized(row);
    }
}

/// Dense Sylvester-order Hadamard matrix with ±1 entries (for tests and the
/// Pallas kernel's small in-VMEM factor). Row-major `n x n`.
pub fn hadamard_dense(n: usize) -> Vec<f32> {
    assert!(n.is_power_of_two());
    let mut m = vec![0.0f32; n * n];
    m[0] = 1.0;
    let mut size = 1;
    while size < n {
        for i in 0..size {
            for j in 0..size {
                let v = m[i * n + j];
                m[i * n + (j + size)] = v;
                m[(i + size) * n + j] = v;
                m[(i + size) * n + (j + size)] = -v;
            }
        }
        size *= 2;
    }
    m
}

/// Smallest power of two >= n (data is zero-padded to this size before any
/// Hadamard-based transform; matches the paper's treatment of USPST n=258).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::rng::Rng;

    fn dense_apply(h: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| (0..n).map(|j| h[i * n + j] * x[j]).sum())
            .collect()
    }

    #[test]
    fn matches_dense_hadamard() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 4, 8, 16, 64, 256] {
            let h = hadamard_dense(n);
            let x = rng.gaussian_vec(n);
            let expect = dense_apply(&h, &x, n);
            let mut got = x.clone();
            fwht(&mut got);
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-3 * n as f32, "n={n}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn normalized_is_involution() {
        // H H = I for the normalized transform.
        for_all(32, |g| {
            let n = g.pow2_in(0, 9);
            let x = g.gaussian_vec(n);
            let mut y = x.clone();
            fwht_normalized(&mut y);
            fwht_normalized(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-4, "n={n}");
            }
        });
    }

    #[test]
    fn normalized_preserves_norm() {
        for_all(32, |g| {
            let n = g.pow2_in(1, 10);
            let x = g.gaussian_vec(n);
            let before: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            let mut y = x;
            fwht_normalized(&mut y);
            let after: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
            assert!(
                (before - after).abs() < 1e-3 * before.max(1.0),
                "n={n} before={before} after={after}"
            );
        });
    }

    #[test]
    fn linearity() {
        for_all(16, |g| {
            let n = g.pow2_in(1, 8);
            let x = g.gaussian_vec(n);
            let y = g.gaussian_vec(n);
            let a = g.f32_in(-2.0, 2.0);
            let mut lhs: Vec<f32> = x.iter().zip(&y).map(|(u, v)| a * u + v).collect();
            fwht(&mut lhs);
            let mut fx = x.clone();
            fwht(&mut fx);
            let mut fy = y.clone();
            fwht(&mut fy);
            for i in 0..n {
                let rhs = a * fx[i] + fy[i];
                assert!((lhs[i] - rhs).abs() < 1e-2 * (1.0 + rhs.abs()), "n={n}");
            }
        });
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(2);
        let n = 32;
        let rows = 5;
        let mut batch: Vec<f32> = rng.gaussian_vec(n * rows);
        let singles: Vec<Vec<f32>> = batch
            .chunks_exact(n)
            .map(|r| {
                let mut v = r.to_vec();
                fwht_normalized(&mut v);
                v
            })
            .collect();
        fwht_batch_normalized(&mut batch, n);
        for (i, s) in singles.iter().enumerate() {
            assert_eq!(&batch[i * n..(i + 1) * n], &s[..]);
        }
    }

    #[test]
    fn unnormalized_batch_matches_rowwise_bitwise() {
        for_all(16, |g| {
            let n = g.pow2_in(1, 9);
            let rows = g.usize_in(1, 12);
            let mut batch = g.gaussian_vec(n * rows);
            let expect: Vec<f32> = batch
                .chunks_exact(n)
                .flat_map(|r| {
                    let mut v = r.to_vec();
                    fwht(&mut v);
                    v
                })
                .collect();
            fwht_batch(&mut batch, n);
            assert_eq!(batch, expect, "n={n} rows={rows}");
        });
    }

    #[test]
    fn batch_matches_rowwise_at_large_n() {
        // large-n regression shape (8192-float rows, 20 of them): the batch
        // entry point must stay bit-identical to per-row fwht far beyond
        // any cache-resident size.
        // Miri: the interpreter can't afford 160k floats of butterflies;
        // 512×4 still crosses several recursion levels and the pool gate.
        let (n, rows) = if cfg!(miri) { (512, 4) } else { (8192, 20) };
        let mut rng = Rng::new(77);
        let mut batch = rng.gaussian_vec(n * rows);
        let expect: Vec<f32> = batch
            .chunks_exact(n)
            .flat_map(|r| {
                let mut v = r.to_vec();
                fwht(&mut v);
                v
            })
            .collect();
        fwht_batch(&mut batch, n);
        assert_eq!(batch, expect);
    }

    #[test]
    fn normalized_fused_scale_matches_separate_pass() {
        // fwht_normalized folds 1/√n into the last butterfly level; the
        // result must be bit-for-bit what fwht + a scale pass produces.
        for_all(24, |g| {
            let n = g.pow2_in(0, 10);
            let x = g.gaussian_vec(n);
            let mut fused = x.clone();
            fwht_normalized(&mut fused);
            let mut two_pass = x;
            fwht(&mut two_pass);
            let s = 1.0 / (n as f32).sqrt();
            for v in two_pass.iter_mut() {
                *v *= s;
            }
            assert_eq!(fused, two_pass, "n={n}");
        });
    }

    #[test]
    fn hadamard_dense_is_orthogonal() {
        let n = 16;
        let h = hadamard_dense(n);
        for i in 0..n {
            for j in 0..n {
                let dot: f32 = (0..n).map(|k| h[i * n + k] * h[j * n + k]).sum();
                let expect = if i == j { n as f32 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(50), 64);
        assert_eq!(next_pow2(258), 512);
        assert_eq!(next_pow2(256), 256);
    }
}
