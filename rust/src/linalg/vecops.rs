//! Small vector helpers shared across the library.

/// Dot product (f64 accumulation for stability).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// L2 norm.
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Normalize to unit L2 norm in place (no-op on the zero vector).
pub fn normalize(a: &mut [f32]) {
    let n = norm2(a);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        for v in a.iter_mut() {
            *v *= inv;
        }
    }
}

/// Elementwise multiply in place: `a[i] *= d[i]` — the `D` of every `HD`
/// that still stores dense (float) entries. Routes through the dispatched
/// SIMD kernel; packed ±1 diagonals use
/// [`crate::transform::hd::SignDiag::apply`] instead.
#[inline]
pub fn scale_by(a: &mut [f32], d: &[f32]) {
    debug_assert_eq!(a.len(), d.len());
    crate::linalg::simd::scale(a, d);
}

/// Zero-pad `x` to length `n` (returns a new vector).
pub fn pad_to(x: &[f32], n: usize) -> Vec<f32> {
    debug_assert!(n >= x.len());
    let mut out = vec![0.0f32; n];
    out[..x.len()].copy_from_slice(x);
    out
}

/// Euclidean distance between two vectors.
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| ((*x - *y) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Angle (radians) between two vectors.
pub fn angle(a: &[f32], b: &[f32]) -> f64 {
    let c = dot(a, b) / (norm2(a) * norm2(b)).max(1e-30);
    c.clamp(-1.0, 1.0).acos()
}

/// Index of the entry with the largest absolute value, with its sign:
/// the cross-polytope `η(y)` returns `±e_i` — we encode it as
/// `i` if positive, `i + n` if negative.
pub fn argmax_abs_signed(y: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_abs = f32::NEG_INFINITY;
    for (i, v) in y.iter().enumerate() {
        let a = v.abs();
        if a > best_abs {
            best_abs = a;
            best = i;
        }
    }
    if y[best] >= 0.0 {
        best
    } else {
        best + y.len()
    }
}

/// Mean of a slice of f64.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit() {
        for_all(16, |g| {
            let n = g.usize_in(1, 32);
            let mut v = g.gaussian_vec(n);
            if norm2(&v) == 0.0 {
                return;
            }
            normalize(&mut v);
            assert!((norm2(&v) - 1.0).abs() < 1e-5);
        });
    }

    #[test]
    fn normalize_zero_is_noop() {
        let mut z = vec![0.0f32; 4];
        normalize(&mut z);
        assert_eq!(z, vec![0.0f32; 4]);
    }

    #[test]
    fn pad_preserves_prefix() {
        let p = pad_to(&[1.0, 2.0], 5);
        assert_eq!(p, vec![1.0, 2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn angle_orthogonal_and_parallel() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((angle(&a, &b) - std::f64::consts::FRAC_PI_2).abs() < 1e-9);
        assert!(angle(&a, &a).abs() < 1e-6);
    }

    #[test]
    fn argmax_abs_signed_encoding() {
        assert_eq!(argmax_abs_signed(&[0.1, -3.0, 2.0]), 1 + 3); // -e_1
        assert_eq!(argmax_abs_signed(&[0.1, 3.0, 2.0]), 1); // +e_1
        assert_eq!(argmax_abs_signed(&[5.0]), 0);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn euclidean_distance() {
        assert!((euclidean(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
