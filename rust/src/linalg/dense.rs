//! Dense row-major matrices: the unstructured baseline and small solvers.
//!
//! This is the `G` side of every paper comparison — a plain dense Gaussian
//! matvec/matmul stands in for the MKL GEMV the authors benchmarked against
//! (speedup *ratios* are what Table 1 reports; both sides share a toolchain
//! here, which is the fair version of the comparison).
//!
//! Also hosts the small dense factorizations the Newton-sketch pipeline
//! needs: Cholesky solve for the `d x d` sketched-Hessian system.

use crate::util::rng::Rng;

/// Flatten a point set into one row-major `(points.len(), n)` buffer,
/// zero-padding each point (dims `<= n`) — the shared staging step every
/// batch-projection consumer (Gram feature matrices, binary code
/// matrices, LSH index builds) runs before handing rows to the pool.
pub fn flatten_padded(points: &[Vec<f32>], n: usize) -> Vec<f32> {
    let mut xs = vec![0.0f32; points.len() * n];
    for (p, row) in points.iter().zip(xs.chunks_exact_mut(n)) {
        assert!(p.len() <= n, "point dim {} exceeds batch dim {n}", p.len());
        row[..p.len()].copy_from_slice(p);
    }
    xs
}

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// i.i.d. N(0,1) entries (the paper's unstructured `G`).
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat {
            rows,
            cols,
            data: rng.gaussian_vec(rows * cols),
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `y = A x`. Inner loop is written to auto-vectorize (contiguous fma
    /// over the row), with 4-way outer unroll to cut loop overhead.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = A x` into a caller-provided buffer (hot-path variant, no alloc).
    ///
    /// Each row accumulates into 8 independent lanes so LLVM can vectorize
    /// the reduction without `-ffast-math` (scalar accumulation pins the FP
    /// addition order and blocks SIMD — measured 4.5x slower; §Perf L3
    /// iteration 3). This keeps the Table-1 dense baseline honest.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        let n = self.cols;
        let chunks = n / 8;

        #[inline(always)]
        fn row_dot(row: &[f32], x: &[f32], chunks: usize, acc: &mut [f32; 8]) {
            for c in 0..chunks {
                let r = &row[c * 8..c * 8 + 8];
                let xx = &x[c * 8..c * 8 + 8];
                for l in 0..8 {
                    acc[l] += r[l] * xx[l];
                }
            }
        }
        #[inline(always)]
        fn finish(acc: &[f32; 8], row: &[f32], x: &[f32], chunks: usize) -> f32 {
            let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5])
                + (acc[2] + acc[6])
                + (acc[3] + acc[7]);
            for j in chunks * 8..x.len() {
                s += row[j] * x[j];
            }
            s
        }

        // Two row streams at once keep the HW prefetchers busy on
        // bandwidth-bound sizes (n >= 2^12, matrix >> LLC) while the 8-lane
        // accumulators vectorize on compute-bound sizes.
        let data: &[f32] = &self.data;
        let rows = self.rows;
        let mut i = 0;
        while i + 2 <= rows {
            let r0 = &data[i * n..(i + 1) * n];
            let r1 = &data[(i + 1) * n..(i + 2) * n];
            let mut a0 = [0.0f32; 8];
            let mut a1 = [0.0f32; 8];
            // chunks_exact elides the per-chunk bounds checks the indexed
            // form keeps in generic (non-const-n) code — 2.2x on this loop
            for ((xx, p0), p1) in x
                .chunks_exact(8)
                .zip(r0.chunks_exact(8))
                .zip(r1.chunks_exact(8))
            {
                for l in 0..8 {
                    a0[l] += p0[l] * xx[l];
                    a1[l] += p1[l] * xx[l];
                }
            }
            y[i] = finish(&a0, r0, x, chunks);
            y[i + 1] = finish(&a1, r1, x, chunks);
            i += 2;
        }
        while i < rows {
            let row = &data[i * n..(i + 1) * n];
            let mut acc = [0.0f32; 8];
            row_dot(row, x, chunks, &mut acc);
            y[i] = finish(&acc, row, x, chunks);
            i += 1;
        }
    }

    /// `C = A B` (naive blocked; used off the hot path: Gram matrices, tests).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let crow = &mut c.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += a * brow[j];
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt()
    }

    /// `||A - B||_F / ||B||_F` — the Gram reconstruction metric of Figure 2.
    pub fn rel_frob_err(&self, reference: &Mat) -> f64 {
        assert_eq!(self.rows, reference.rows);
        assert_eq!(self.cols, reference.cols);
        let num: f64 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| ((*a - *b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / reference.frob().max(1e-30)
    }
}

/// Cholesky factorization of an SPD matrix (f64 for stability), returning
/// the lower factor L with `A = L L^T`, or `None` if not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky. Returns `None` if `A` is not
/// positive definite.
pub fn solve_spd(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky(a, n)?;
    // forward: L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // backward: L^T x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn matvec_matches_naive() {
        for_all(24, |g| {
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 20);
            let a = Mat::from_vec(m, n, g.vec_f32(m * n, -1.0, 1.0));
            let x = g.vec_f32(n, -1.0, 1.0);
            let y = a.matvec(&x);
            for i in 0..m {
                let expect: f32 = (0..n).map(|j| a.at(i, j) * x[j]).sum();
                assert!((y[i] - expect).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn matmul_identity() {
        for_all(16, |g| {
            let n = g.usize_in(1, 12);
            let a = Mat::from_vec(n, n, g.vec_f32(n * n, -1.0, 1.0));
            let i = Mat::identity(n);
            assert_eq!(a.matmul(&i), a);
        });
    }

    #[test]
    fn matmul_matches_matvec_columns() {
        for_all(16, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 10);
            let n = g.usize_in(1, 10);
            let a = Mat::from_vec(m, k, g.vec_f32(m * k, -1.0, 1.0));
            let b = Mat::from_vec(k, n, g.vec_f32(k * n, -1.0, 1.0));
            let c = a.matmul(&b);
            // column j of C == A * (column j of B)
            for j in 0..n {
                let col: Vec<f32> = (0..k).map(|p| b.at(p, j)).collect();
                let y = a.matvec(&col);
                for i in 0..m {
                    assert!((c.at(i, j) - y[i]).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn transpose_involution() {
        for_all(16, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 10);
            let a = Mat::from_vec(m, n, g.vec_f32(m * n, -1.0, 1.0));
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn frob_err_zero_on_self() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.rel_frob_err(&m) < 1e-12);
    }

    #[test]
    fn cholesky_solve_recovers() {
        for_all(24, |g| {
            let n = g.usize_in(1, 12);
            // A = B B^T + n*I is SPD
            let b = Mat::from_vec(n, n, g.vec_f32(n * n, -1.0, 1.0));
            let mut a = vec![0.0f64; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for k in 0..n {
                        s += b.at(i, k) as f64 * b.at(j, k) as f64;
                    }
                    a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let x_true: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0) / n as f64).collect();
            let rhs: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            let x = solve_spd(&a, &rhs, n).expect("SPD");
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-8, "n={n}");
            }
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // [[0, 1], [1, 0]] is indefinite
        assert!(cholesky(&[0.0, 1.0, 1.0, 0.0], 2).is_none());
    }

    #[test]
    fn gaussian_matrix_moments() {
        let mut rng = Rng::new(21);
        let m = Mat::gaussian(64, 64, &mut rng);
        let mean: f64 = m.data.iter().map(|v| *v as f64).sum::<f64>() / m.data.len() as f64;
        let var: f64 =
            m.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / m.data.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.1);
    }
}
