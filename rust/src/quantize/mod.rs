//! Vector quantization with random projection trees (paper §1, Remark 4).
//!
//! An RP-tree recursively splits a dataset at the median of its projection
//! onto a random direction [Dasgupta & Freund]. The paper's Remark 4 notes
//! the whole tree is one function `f` of a Gaussian matrix `G` (one row per
//! level), with `d = d_intrinsic` — so any TripleSpin member can supply the
//! directions. [`RpTree`] builds the tree with either a dense Gaussian or a
//! structured transform; [`RpTree::quantize`] maps a vector to its leaf
//! centroid, and [`distortion`] measures the quantization error the
//! experiments compare.

pub mod tree;

pub use tree::{distortion, RpTree};
