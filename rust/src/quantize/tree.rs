//! Random projection tree quantizer.
//!
//! Internal nodes hold a projection-row index and a median threshold; the
//! projections for *all* levels come from one `k x n` transform (one row
//! per tree level), so a TripleSpin transform supplies every split
//! direction at `O(n log n)` per query instead of `O(kn)`.

use crate::linalg::vecops::{euclidean, pad_to};
use crate::transform::{make, Family, Transform};
use crate::util::rng::Rng;

/// A node of the RP-tree, indexed into [`RpTree::nodes`].
#[derive(Clone, Debug)]
enum Node {
    Internal {
        /// Which projection row splits this node (== node depth).
        level: usize,
        /// Median threshold on the projected value.
        threshold: f32,
        left: usize,
        right: usize,
    },
    Leaf {
        /// Mean of the training points that landed here.
        centroid: Vec<f32>,
        /// Number of training points.
        count: usize,
    },
}

/// Random-projection-tree vector quantizer.
pub struct RpTree {
    transform: Box<dyn Transform>,
    nodes: Vec<Node>,
    root: usize,
    dim: usize,
    depth: usize,
}

impl RpTree {
    /// Build a depth-`depth` RP-tree over `points`, drawing split
    /// directions from `family`. Leaves store centroids.
    pub fn build(
        points: &[Vec<f32>],
        family: Family,
        depth: usize,
        seed: u64,
    ) -> RpTree {
        assert!(!points.is_empty());
        let dim = points[0].len();
        let n_pad = dim.next_power_of_two();
        let mut rng = Rng::new(seed);
        // one projection row per level
        let transform = make(family, depth.max(1), n_pad, n_pad, &mut rng);
        // project every training point once
        let projections: Vec<Vec<f32>> = points
            .iter()
            .map(|p| transform.apply(&pad_to(p, n_pad)))
            .collect();
        let mut nodes = Vec::new();
        let ids: Vec<usize> = (0..points.len()).collect();
        let root = build_rec(points, &projections, &ids, 0, depth, &mut nodes);
        RpTree {
            transform,
            nodes,
            root,
            dim,
            depth,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Total stored parameters in bits (tree thresholds + centroids +
    /// projection rows).
    pub fn param_bits(&self) -> usize {
        let node_bits: usize = self
            .nodes
            .iter()
            .map(|n| match n {
                Node::Internal { .. } => 32 + 2 * 64,
                Node::Leaf { centroid, .. } => 32 * centroid.len(),
            })
            .sum();
        node_bits + self.transform.param_bits()
    }

    /// The leaf centroid for `x` (the quantized representative).
    pub fn quantize(&self, x: &[f32]) -> &[f32] {
        let n_pad = self.transform.dim_in();
        let proj = self.transform.apply(&pad_to(x, n_pad));
        let mut cur = self.root;
        loop {
            match &self.nodes[cur] {
                Node::Internal {
                    level,
                    threshold,
                    left,
                    right,
                } => {
                    cur = if proj[*level] <= *threshold { *left } else { *right };
                }
                Node::Leaf { centroid, .. } => return centroid,
            }
        }
    }

    /// Leaf id for `x` (a compact code in `0..num_leaves`-ish space —
    /// node index, stable for a built tree).
    pub fn code(&self, x: &[f32]) -> usize {
        let n_pad = self.transform.dim_in();
        let proj = self.transform.apply(&pad_to(x, n_pad));
        let mut cur = self.root;
        loop {
            match &self.nodes[cur] {
                Node::Internal {
                    level,
                    threshold,
                    left,
                    right,
                } => {
                    cur = if proj[*level] <= *threshold { *left } else { *right };
                }
                Node::Leaf { .. } => return cur,
            }
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

fn build_rec(
    points: &[Vec<f32>],
    projections: &[Vec<f32>],
    ids: &[usize],
    level: usize,
    max_depth: usize,
    nodes: &mut Vec<Node>,
) -> usize {
    if level >= max_depth || ids.len() <= 1 {
        let dim = points[0].len();
        let mut centroid = vec![0.0f32; dim];
        for &i in ids {
            for (c, v) in centroid.iter_mut().zip(&points[i]) {
                *c += v;
            }
        }
        let cnt = ids.len().max(1);
        for c in centroid.iter_mut() {
            *c /= cnt as f32;
        }
        nodes.push(Node::Leaf {
            centroid,
            count: ids.len(),
        });
        return nodes.len() - 1;
    }
    // median split on this level's projection
    let mut vals: Vec<f32> = ids.iter().map(|&i| projections[i][level]).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = vals[vals.len() / 2];
    let (mut l, mut r) = (Vec::new(), Vec::new());
    for &i in ids {
        if projections[i][level] <= threshold {
            l.push(i);
        } else {
            r.push(i);
        }
    }
    // degenerate split (ties): stop here
    if l.is_empty() || r.is_empty() {
        return build_rec(points, projections, ids, max_depth, max_depth, nodes);
    }
    let left = build_rec(points, projections, &l, level + 1, max_depth, nodes);
    let right = build_rec(points, projections, &r, level + 1, max_depth, nodes);
    nodes.push(Node::Internal {
        level,
        threshold,
        left,
        right,
    });
    nodes.len() - 1
}

/// Mean squared quantization distortion `E ||x - q(x)||²` over a set.
pub fn distortion(tree: &RpTree, points: &[Vec<f32>]) -> f64 {
    let total: f64 = points
        .iter()
        .map(|p| euclidean(p, tree.quantize(p)).powi(2))
        .sum();
    total / points.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uspst;

    fn dataset() -> Vec<Vec<f32>> {
        uspst::dataset_n(300, 7)
    }

    #[test]
    fn tree_builds_and_quantizes() {
        let pts = dataset();
        let tree = RpTree::build(&pts, Family::Hd3, 5, 1);
        assert!(tree.num_leaves() > 1);
        assert!(tree.num_leaves() <= 32);
        for p in pts.iter().take(10) {
            let q = tree.quantize(p);
            assert_eq!(q.len(), p.len());
        }
    }

    #[test]
    fn deeper_trees_reduce_distortion() {
        let pts = dataset();
        let d2 = distortion(&RpTree::build(&pts, Family::Hd3, 2, 3), &pts);
        let d6 = distortion(&RpTree::build(&pts, Family::Hd3, 6, 3), &pts);
        let d8 = distortion(&RpTree::build(&pts, Family::Hd3, 8, 3), &pts);
        assert!(d6 < d2, "depth 6 ({d6}) should beat depth 2 ({d2})");
        assert!(d8 <= d6 * 1.05, "depth 8 ({d8}) should not regress vs 6 ({d6})");
    }

    #[test]
    fn structured_matches_dense_distortion() {
        // the paper's claim specialized to quantization: TripleSpin split
        // directions quantize as well as Gaussian ones.
        let pts = dataset();
        let avg = |fam: Family| -> f64 {
            (0..4)
                .map(|s| distortion(&RpTree::build(&pts, fam, 6, 10 + s), &pts))
                .sum::<f64>()
                / 4.0
        };
        let dense = avg(Family::Dense);
        let hd3 = avg(Family::Hd3);
        assert!(
            (hd3 - dense).abs() < 0.25 * dense,
            "hd3 distortion {hd3} vs dense {dense}"
        );
    }

    #[test]
    fn code_is_consistent_with_quantize() {
        let pts = dataset();
        let tree = RpTree::build(&pts, Family::Hdg, 5, 2);
        for p in pts.iter().take(20) {
            let c1 = tree.code(p);
            let c2 = tree.code(p);
            assert_eq!(c1, c2);
        }
    }

    #[test]
    fn identical_points_share_a_leaf() {
        let pts = dataset();
        let tree = RpTree::build(&pts, Family::Hd3, 6, 4);
        let p = &pts[0];
        assert_eq!(tree.code(p), tree.code(&p.clone()));
    }

    #[test]
    fn single_point_dataset() {
        let pts = vec![vec![1.0f32; 16]];
        let tree = RpTree::build(&pts, Family::Hd3, 4, 5);
        assert_eq!(tree.num_leaves(), 1);
        let q = tree.quantize(&pts[0]);
        assert_eq!(q, &pts[0][..]);
        assert_eq!(distortion(&tree, &pts), 0.0);
    }

    #[test]
    fn param_bits_positive_and_ordered() {
        let pts = dataset();
        let hd3 = RpTree::build(&pts, Family::Hd3, 5, 6).param_bits();
        let dense = RpTree::build(&pts, Family::Dense, 5, 6).param_bits();
        assert!(hd3 > 0);
        assert!(hd3 < dense, "structured tree must store fewer bits");
    }
}
