//! Serving ingress: in-flight dedup and a bounded response cache in
//! front of the coordinator's per-lane micro-batchers.
//!
//! The engine is batch-first — pooled multi-row batches are where the
//! TripleSpin structured-matrix work amortizes — but a TCP front that
//! forwards each request line straight to [`super::Coordinator`] turns
//! every concurrent client into a batch of one. This module is the real
//! ingress between [`super::server::LineService`] and the coordinator:
//!
//! * **Coalescing** happens in the lane itself (requests from many
//!   connections land on one lane queue and flush together on
//!   `max_batch` / `max_wait` / the cost-model `flush_work` cap, with
//!   the earliest queued deadline bounding the window). The ingress
//!   keeps that path hot by stripping duplicate work *before* it
//!   reaches the queue. The batch class is the lane key `(op, n)`:
//!   requests coalesce exactly when they share an op and a transform
//!   dimension, because that is what one backend call can execute.
//! * **In-flight dedup**: byte-identical concurrent requests
//!   (fingerprint = FNV-1a over op name + exact input bits, via
//!   [`crate::router::topology::request_key`]) elect one leader that
//!   computes; followers subscribe to the same response slot. Compute
//!   is a deterministic pure function of `(op, input bits)`, so fanning
//!   the leader's *successful* output to followers is exact — and only
//!   successes fan out: any leader failure (refusal, typed error,
//!   timeout, lane death) orphans the slot, and each waiter retries
//!   individually (one promotes itself to leader), so failures stay
//!   per-request and a dead leader cannot strand its followers.
//! * **Response cache**: a bounded per-lane LRU keyed by the same
//!   fingerprint answers repeat requests without backend time. Requests
//!   can opt out per line with the `no_cache` wire field (neither read
//!   nor stored); hit / miss / eviction counts and occupancy ride
//!   [`super::LaneMetrics`].
//!
//! **Every** request — leader, follower, cache hit — pays the full
//! admission chain ([`super::Coordinator::admit`]) first: each client is
//! charged its own work units, and a shed / throttle refusal for one
//! follower never evicts the leader's computation (the refusal happens
//! before the slot is joined). Refusal order therefore matches the
//! uncoalesced path exactly.

use super::codec;
use super::server::CODE_TIMEOUT;
use super::{
    Coordinator, LaneMetrics, SubmitError, SubmitOptions, DEFAULT_CALL_TIMEOUT, RESPONSE_GRACE,
};
use crate::router::topology;
use crate::runtime::{Op, Output};
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Ingress tuning for [`super::CoordinatorService::with_ingress`].
#[derive(Clone, Copy, Debug)]
pub struct IngressOptions {
    /// Response-cache entries per lane (`0` disables the cache).
    pub cache_cap: usize,
    /// In-flight dedup of identical requests (leader / follower slots).
    pub dedup: bool,
}

impl Default for IngressOptions {
    fn default() -> Self {
        IngressOptions {
            cache_cap: 256,
            dedup: true,
        }
    }
}

/// Terminal state of one dedup slot. `Pending` while the leader
/// computes; exactly one transition out of it, under the slot mutex.
enum SlotState {
    Pending,
    /// The leader's successful output — safe to fan out because compute
    /// is deterministic in `(op, input bits)`.
    Done(Output),
    /// The leader failed (refusal, typed error, timeout, lane death).
    /// Waiters retry individually; one becomes the next leader.
    Orphaned,
}

/// One in-flight computation identical requests subscribe to.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// What a follower's bounded wait on a slot resolved to.
enum Waited {
    Done(Output),
    Orphaned,
    TimedOut,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        }
    }

    /// Publish the terminal state and wake every follower.
    fn resolve(&self, terminal: SlotState) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        *st = terminal;
        self.cv.notify_all();
    }

    /// Follower-side bounded wait for the leader's terminal state.
    fn wait_until(&self, deadline: Instant) -> Waited {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            match &*st {
                SlotState::Done(out) => return Waited::Done(out.clone()),
                SlotState::Orphaned => return Waited::Orphaned,
                SlotState::Pending => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return Waited::TimedOut;
            }
            // spurious wakes and timeouts both fall through to the
            // state/deadline re-check at the top of the loop
            let (guard, _) = self
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }
}

/// Bounded true-LRU response cache (stamp-based recency; eviction scans
/// for the oldest stamp — O(cap), fine for the small per-lane caps the
/// ingress runs with).
struct LruCache {
    cap: usize,
    stamp: u64,
    map: HashMap<u64, (Output, u64)>,
}

impl LruCache {
    fn new(cap: usize) -> LruCache {
        LruCache {
            cap,
            stamp: 0,
            map: HashMap::new(),
        }
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    /// Hit refreshes recency (true LRU, not FIFO).
    fn get(&mut self, key: u64) -> Option<Output> {
        self.stamp += 1;
        let stamp = self.stamp;
        self.map.get_mut(&key).map(|(out, s)| {
            *s = stamp;
            out.clone()
        })
    }

    /// Insert (or refresh) `key`; returns how many entries were evicted
    /// to stay under capacity (0 or 1).
    fn insert(&mut self, key: u64, out: Output) -> u64 {
        if self.cap == 0 {
            return 0;
        }
        self.stamp += 1;
        let stamp = self.stamp;
        if let Some(slot) = self.map.get_mut(&key) {
            *slot = (out, stamp);
            return 0;
        }
        let mut evicted = 0;
        if self.map.len() >= self.cap {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, s))| *s)
                .map(|(k, _)| *k)
            {
                self.map.remove(&oldest);
                evicted = 1;
            }
        }
        self.map.insert(key, (out, stamp));
        evicted
    }
}

/// Per-lane ingress state: the dedup slot table and the response cache,
/// plus the lane's metrics handle (shared with the coordinator, so
/// ingress counters land in the same per-lane document).
struct LaneIngress {
    metrics: Arc<LaneMetrics>,
    inflight: Mutex<HashMap<u64, Arc<Slot>>>,
    cache: Mutex<LruCache>,
}

/// The ingress front: one [`LaneIngress`] per coordinator lane.
pub struct Batcher {
    coordinator: Arc<Coordinator>,
    opts: IngressOptions,
    lanes: HashMap<(Op, usize), LaneIngress>,
}

impl Batcher {
    /// Build an ingress over every lane `coordinator` serves.
    pub fn new(coordinator: Arc<Coordinator>, opts: IngressOptions) -> Batcher {
        let lanes = coordinator
            .metrics()
            .into_iter()
            .map(|(key, metrics)| {
                (
                    key,
                    LaneIngress {
                        metrics,
                        inflight: Mutex::new(HashMap::new()),
                        cache: Mutex::new(LruCache::new(opts.cache_cap)),
                    },
                )
            })
            .collect();
        Batcher {
            coordinator,
            opts,
            lanes,
        }
    }

    /// Answer one validated compute request through the ingress:
    /// admission → cache → dedup → lane. The rendered response is
    /// byte-identical to the uncoalesced path's for the same outcome.
    pub fn respond(&self, req: codec::Request, peer: &str) -> Json {
        let codec::Request {
            id,
            op,
            timeout,
            client_id,
            priority,
            no_cache,
            vector,
        } = req;
        let started = Instant::now();
        let opts = SubmitOptions {
            deadline: timeout,
            client: Some(client_id.as_deref().unwrap_or(peer)),
            priority,
        };
        // 1. full admission chain, for every caller — leaders, followers
        // and cache hits alike pay their own work units, and refusals
        // happen before any slot is joined (so they cannot evict an
        // in-flight leader)
        if let Err(e) = self.coordinator.admit(op, vector.len(), opts) {
            return codec::err_response_with_hint(id, &e.to_string(), e.code(), e.retry_after_ms());
        }
        let Some(lane) = self.lanes.get(&(op, vector.len())) else {
            // admitted lanes always have ingress state (same key set by
            // construction); degrade to a plain compute if not
            return match self.compute(&id, op, vector, timeout) {
                Ok(out) => codec::ok_response(id, out),
                Err(reply) => reply,
            };
        };
        let key = topology::request_key(op.name(), &vector);
        // 2. response cache (skipped entirely on no_cache: not a miss)
        if !no_cache && self.opts.cache_cap > 0 {
            let hit = {
                let mut cache = lane.cache.lock().unwrap_or_else(|p| p.into_inner());
                cache.get(key)
            };
            if let Some(out) = hit {
                lane.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                record_completion(&lane.metrics, &out, started);
                return codec::ok_response(id, out);
            }
            lane.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        if !self.opts.dedup {
            return self.lead(lane, None, &id, op, key, no_cache, vector, timeout);
        }
        // 3. dedup: join the in-flight slot as a follower, or claim
        // leadership. An orphaned slot (failed leader) loops back here —
        // the retrying waiter that finds the table empty promotes itself
        // to leader, so every waiter reaches a terminal coded response.
        let wait_deadline =
            started + timeout.unwrap_or(DEFAULT_CALL_TIMEOUT).saturating_add(RESPONSE_GRACE);
        loop {
            let claimed = {
                let mut inflight = lane.inflight.lock().unwrap_or_else(|p| p.into_inner());
                match inflight.get(&key) {
                    Some(slot) => Err(Arc::clone(slot)),
                    None => {
                        let slot = Arc::new(Slot::new());
                        inflight.insert(key, Arc::clone(&slot));
                        Ok(slot)
                    }
                }
            };
            match claimed {
                Ok(slot) => {
                    return self.lead(lane, Some(slot), &id, op, key, no_cache, vector, timeout);
                }
                Err(slot) => {
                    lane.metrics.dedup_followers.fetch_add(1, Ordering::Relaxed);
                    match slot.wait_until(wait_deadline) {
                        Waited::Done(out) => {
                            record_completion(&lane.metrics, &out, started);
                            return codec::ok_response(id, out);
                        }
                        // leader failed — retry; failures never fan out
                        Waited::Orphaned => continue,
                        Waited::TimedOut => {
                            return codec::err_response(id, "response timed out", CODE_TIMEOUT)
                        }
                    }
                }
            }
        }
    }

    /// Leader path: compute through the lane, publish the slot's
    /// terminal state, feed the cache on success. The slot entry is
    /// removed from the table *before* resolving so late arrivals start
    /// a fresh computation instead of joining a finished one.
    #[allow(clippy::too_many_arguments)]
    fn lead(
        &self,
        lane: &LaneIngress,
        slot: Option<Arc<Slot>>,
        id: &Json,
        op: Op,
        key: u64,
        no_cache: bool,
        vector: Vec<f32>,
        timeout: Option<Duration>,
    ) -> Json {
        let outcome = self.compute(id, op, vector, timeout);
        if slot.is_some() {
            let mut inflight = lane.inflight.lock().unwrap_or_else(|p| p.into_inner());
            inflight.remove(&key);
        }
        match outcome {
            Ok(out) => {
                if let Some(slot) = slot {
                    slot.resolve(SlotState::Done(out.clone()));
                }
                if !no_cache && self.opts.cache_cap > 0 {
                    let (evicted, len) = {
                        let mut cache = lane.cache.lock().unwrap_or_else(|p| p.into_inner());
                        let evicted = cache.insert(key, out.clone());
                        (evicted, cache.len() as u64)
                    };
                    if evicted > 0 {
                        lane.metrics.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
                    }
                    lane.metrics.cache_entries.store(len, Ordering::Relaxed);
                }
                codec::ok_response(id.clone(), out)
            }
            Err(reply) => {
                // failures never fan out: waiters retry individually
                if let Some(slot) = slot {
                    slot.resolve(SlotState::Orphaned);
                }
                reply
            }
        }
    }

    /// One enqueue + bounded response wait — the exact uncoalesced
    /// `respond_compute` behavior, minus admission (already paid).
    /// Errors come back as ready-to-send wire replies.
    fn compute(
        &self,
        id: &Json,
        op: Op,
        vector: Vec<f32>,
        timeout: Option<Duration>,
    ) -> Result<Output, Json> {
        match self.coordinator.enqueue(op, vector, timeout) {
            Ok((_, rx)) => {
                let wait = timeout
                    .unwrap_or(DEFAULT_CALL_TIMEOUT)
                    .saturating_add(RESPONSE_GRACE);
                match rx.recv_timeout(wait) {
                    Ok(resp) => match resp.result {
                        Ok(out) => Ok(out),
                        Err(e) => Err(codec::err_response(id.clone(), &e.to_string(), e.code())),
                    },
                    Err(mpsc::RecvTimeoutError::Timeout) => Err(codec::err_response(
                        id.clone(),
                        "response timed out",
                        CODE_TIMEOUT,
                    )),
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        Err(codec::err_response_with_hint(
                            id.clone(),
                            "lane dropped response (restarted mid-request)",
                            "lane_down",
                            SubmitError::LaneDown.retry_after_ms(),
                        ))
                    }
                }
            }
            Err(e) => Err(codec::err_response_with_hint(
                id.clone(),
                &e.to_string(),
                e.code(),
                e.retry_after_ms(),
            )),
        }
    }
}

/// Count a request answered off the lane path (cache hit / dedup
/// follower) into the same completion ledger the lane feeds: completed,
/// output footprint, and end-to-end latency.
fn record_completion(metrics: &LaneMetrics, out: &Output, started: Instant) {
    let bits = match out {
        Output::Bits(v) => v.len() * 64,
        Output::F32(v) => v.len() * 32,
        Output::I32(v) => v.len() * 32,
    };
    metrics.output_bits.fetch_add(bits as u64, Ordering::Relaxed);
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics
        .latency
        .record_us(started.elapsed().as_micros() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Config, NativeBackend};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn lru_cache_evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        assert_eq!(c.insert(1, Output::I32(vec![1])), 0);
        assert_eq!(c.insert(2, Output::I32(vec![2])), 0);
        // touch 1 so 2 becomes the eviction victim
        assert!(c.get(1).is_some());
        assert_eq!(c.insert(3, Output::I32(vec![3])), 1);
        assert!(c.get(2).is_none(), "LRU victim must be the stale entry");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.len(), 2);
        // refreshing an existing key never evicts
        assert_eq!(c.insert(1, Output::I32(vec![9])), 0);
        assert_eq!(c.len(), 2);
        // cap 0 disables storage entirely
        let mut off = LruCache::new(0);
        assert_eq!(off.insert(1, Output::I32(vec![1])), 0);
        assert_eq!(off.len(), 0);
    }

    #[test]
    fn fingerprint_separates_ops_and_inputs() {
        let v = vec![1.0f32; 8];
        let a = topology::request_key(Op::Transform.name(), &v);
        assert_eq!(a, topology::request_key(Op::Transform.name(), &v));
        assert_ne!(a, topology::request_key(Op::Rff.name(), &v));
        let mut w = v.clone();
        w[0] = 1.0 + f32::EPSILON;
        assert_ne!(a, topology::request_key(Op::Transform.name(), &w));
    }

    #[test]
    fn orphaned_slot_wakes_followers_to_retry() {
        let slot = Arc::new(Slot::new());
        let s2 = Arc::clone(&slot);
        let waiter = std::thread::spawn(move || {
            matches!(
                s2.wait_until(Instant::now() + Duration::from_secs(5)),
                Waited::Orphaned
            )
        });
        std::thread::sleep(Duration::from_millis(20));
        slot.resolve(SlotState::Orphaned);
        assert!(waiter.join().unwrap(), "orphan must wake the follower");
        // a pre-resolved slot answers without blocking
        let done = Slot::new();
        done.resolve(SlotState::Done(Output::I32(vec![7])));
        assert!(matches!(
            done.wait_until(Instant::now() + Duration::from_millis(1)),
            Waited::Done(_)
        ));
    }

    /// Backend that counts calls — proves cache hits skip it entirely.
    struct CountingBackend {
        inner: NativeBackend,
        calls: AtomicU64,
    }

    impl Backend for CountingBackend {
        fn run_batch(
            &self,
            op: Op,
            n: usize,
            rows: usize,
            xs: &[f32],
        ) -> Result<Output, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.run_batch(op, n, rows, xs)
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    fn request(vector: Vec<f32>, no_cache: bool) -> codec::Request {
        codec::Request {
            id: Json::Num(1.0),
            op: Op::Transform,
            timeout: None,
            client_id: None,
            priority: crate::coordinator::admission::PRIORITY_NORMAL,
            no_cache,
            vector,
        }
    }

    #[test]
    fn cache_hits_answer_without_backend_and_no_cache_opts_out() {
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            queue_cap: 64,
            sigma: 1.0,
            seed: 5,
            ..Config::default()
        };
        let be = Arc::new(CountingBackend {
            inner: NativeBackend::new(&[64], 1.0, 5),
            calls: AtomicU64::new(0),
        });
        let c = Arc::new(crate::coordinator::Coordinator::start(
            config,
            Arc::clone(&be) as Arc<dyn Backend>,
        ));
        let b = Batcher::new(Arc::clone(&c), IngressOptions::default());
        let v: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
        let first = b.respond(request(v.clone(), false), "t");
        assert_eq!(first.get("ok"), Some(&Json::Bool(true)));
        let calls_after_first = be.calls.load(Ordering::Relaxed);
        assert!(calls_after_first >= 1);
        // identical request: answered from cache, byte-identical, no
        // further backend calls
        let second = b.respond(request(v.clone(), false), "t");
        assert_eq!(second.to_string(), first.to_string());
        assert_eq!(be.calls.load(Ordering::Relaxed), calls_after_first);
        // no_cache recomputes (and never stores)
        let third = b.respond(request(v.clone(), true), "t");
        assert_eq!(third.to_string(), first.to_string());
        assert!(be.calls.load(Ordering::Relaxed) > calls_after_first);
        let m = c.lane_metrics(Op::Transform, 64).unwrap();
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1, "no_cache is not a miss");
        assert_eq!(m.cache_entries.load(Ordering::Relaxed), 1);
        // the full ledger stays balanced: 3 submits, 3 completions
        assert_eq!(m.submitted.load(Ordering::Relaxed), 3);
        assert_eq!(m.completed.load(Ordering::Relaxed), 3);
        drop(b);
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }
}
