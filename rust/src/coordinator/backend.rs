//! Execution backends for the coordinator.
//!
//! Both backends compute the same model — the `√n·HD3 HD2 HD1` chain and
//! its derived ops — from the same seeded [`ModelParams`], so they are
//! interchangeable and cross-checkable:
//!
//! * [`NativeBackend`] — pure-Rust hot path (FWHT chain), no artifacts
//!   needed. The fallback and the perf baseline.
//! * [`PjrtBackend`] — executes the AOT-compiled JAX/Pallas artifacts via
//!   the runtime service (the paper-faithful "three-layer" path).

use crate::linalg::fwht::fwht;
use crate::runtime::pool::{shard_rows as pool_shard_rows, WorkerPool};
use crate::runtime::{Op, Output, RuntimeHandle};
use crate::transform::SignDiag;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-dimension model parameters shared by both backends: the three
/// Rademacher diagonals of the chain plus the RFF bandwidth.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub n: usize,
    pub d1: Vec<f32>,
    pub d2: Vec<f32>,
    pub d3: Vec<f32>,
    /// `1/σ` for the Gaussian-kernel RFF op.
    pub inv_sigma: f32,
}

impl ModelParams {
    /// Deterministic in (seed, n): both backends derive identical params.
    pub fn generate(n: usize, sigma: f64, seed: u64) -> ModelParams {
        assert!(n.is_power_of_two());
        let mut rng = Rng::new(seed ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        ModelParams {
            n,
            d1: rng.rademacher_vec(n),
            d2: rng.rademacher_vec(n),
            d3: rng.rademacher_vec(n),
            inv_sigma: (1.0 / sigma) as f32,
        }
    }
}

/// A batch-execution backend. `xs` is a row-major `(rows, n)` buffer.
///
/// Failure contract with the coordinator lane: an `Err` fails the batch's
/// requests but costs nothing else; a **panic** out of [`Backend::run_batch`]
/// is caught per call (the batch is retried as singletons to isolate the
/// poisoned row); but a **malformed output shape** — anything other than
/// `rows * out_elems(op, n)` elements — is lane-fatal by design (the lane
/// thread dies and is restarted by its supervisor), because slicing a
/// wrong-shape buffer into per-request responses would hand clients
/// silently corrupt data.
pub trait Backend: Send + Sync + 'static {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String>;
    /// Output elements **per request row** for (op, n). For
    /// [`Op::BinaryEmbed`] an "element" is one packed `u64` word
    /// (`⌈n/64⌉` of them — 64 sign bits each).
    fn out_elems(&self, op: Op, n: usize) -> usize {
        match op {
            Op::Transform => n,
            Op::Rff => 2 * n,
            Op::CrossPolytope => 1,
            Op::BinaryEmbed => n.div_ceil(64),
        }
    }
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend: the L3-native hot path. Batches run through the
/// chain kernel (all three spins per L1-resident row)
/// with rows sharded over the **process-wide** [`WorkerPool::global`]
/// (`TS_WORKERS`-tunable) — worker threads are spawned once on the first
/// large-enough batch and shared with every other pool consumer
/// (transform trait path, feature maps, LSH, sketches), so steady-state
/// serving keeps exactly one set of warm workers no matter which
/// subsystem a request hits. Tests/benches that need a pinned worker
/// count get a private pool via [`NativeBackend::with_workers`].
pub struct NativeBackend {
    params: HashMap<usize, NativeParams>,
    /// `None` = run on [`WorkerPool::global`]; `Some` = privately owned
    /// pinned-count pool (the `with_workers` constructor).
    pool: Option<WorkerPool>,
}

/// [`ModelParams`] packed for the hot loop: the three Rademacher diagonals
/// as [`SignDiag`] bitmasks (applied as SIMD sign XORs — bit-identical to
/// the f32 multiply for ±1 entries), plus the chain's global `1/n`
/// normalization riding as the uniform post-scale of the last sign pass
/// (it commutes with the linear FWHT; one fewer pass per request, §Perf L3
/// iter 1 — and `1/n` is a power of two, so `±1/n` folds exactly). The
/// dense [`ModelParams`] vectors are dropped after packing — only the RFF
/// bandwidth survives — so the backend really holds ~3n bits per dim.
struct NativeParams {
    d1: SignDiag,
    d2: SignDiag,
    d3: SignDiag,
    d3_scale: f32,
    inv_sigma: f32,
}

impl NativeBackend {
    pub fn new(dims: &[usize], sigma: f64, seed: u64) -> NativeBackend {
        NativeBackend {
            params: dims
                .iter()
                .map(|&n| {
                    let base = ModelParams::generate(n, sigma, seed);
                    let packed = NativeParams {
                        d1: SignDiag::from_f32(&base.d1),
                        d2: SignDiag::from_f32(&base.d2),
                        d3: SignDiag::from_f32(&base.d3),
                        d3_scale: 1.0 / n as f32,
                        inv_sigma: base.inv_sigma,
                    };
                    (n, packed)
                })
                .collect(),
            pool: None, // execute on the shared WorkerPool::global()
        }
    }

    /// Like [`NativeBackend::new`] with a pinned worker count (`new` reads
    /// the `TS_WORKERS` env var / machine parallelism). Pinning also
    /// disables the pool's work gate: "use exactly this many workers
    /// wherever the row count allows" — the test/bench constructor.
    pub fn with_workers(dims: &[usize], sigma: f64, seed: u64, workers: usize) -> NativeBackend {
        let mut be = NativeBackend::new(dims, sigma, seed);
        be.pool = Some(WorkerPool::with_min_work(workers, 0));
        be
    }

    /// The pool batches execute on: the private pinned-count pool when one
    /// was requested, otherwise the process-wide shared pool.
    fn pool(&self) -> &WorkerPool {
        self.pool.as_ref().unwrap_or_else(WorkerPool::global)
    }

    fn params(&self, n: usize) -> Result<&NativeParams, String> {
        self.params
            .get(&n)
            .ok_or_else(|| format!("native backend: no params for n={n}"))
    }

    /// In-place chain over a row-major sub-batch: `√n · H D3 H D2 H D1 x`
    /// per row (normalized H). Three unnormalized FWHTs contribute n^{3/2};
    /// the remaining `√n/n^{3/2} = 1/n` factor rides the last sign pass as
    /// `d3_scale`. Each row runs all three stages while L1-resident —
    /// stage-major full-batch sweeps were reverted with the other
    /// level-major kernels (see [`crate::linalg::fwht::fwht_batch`]).
    fn chain_batch(p: &NativeParams, data: &mut [f32], n: usize) {
        for row in data.chunks_exact_mut(n) {
            p.d1.apply(row);
            fwht(row);
            p.d2.apply(row);
            fwht(row);
            p.d3.apply_scaled(row, p.d3_scale);
            fwht(row);
        }
    }

    /// Per-row work estimate of the three-spin chain, in the pool's
    /// ~butterfly-op units (see `Transform::batch_work_per_row`).
    fn chain_work(n: usize) -> usize {
        let n = n.max(2);
        3 * n * (n.ilog2() as usize + 1)
    }
}

/// Shard the rows of the `proj` chain buffer (width `n`) and the output
/// buffer (width `w_out`) across the backend's persistent pool; batches too
/// small for a second worker run serially on the caller thread.
#[allow(clippy::too_many_arguments)]
fn shard_proj_out<T, F>(
    pool: &WorkerPool,
    proj: &mut [f32],
    out: &mut [T],
    rows: usize,
    n: usize,
    w_out: usize,
    work_per_row: usize,
    f: F,
) where
    T: Send,
    F: Fn(&mut [f32], &mut [T]) + Sync,
{
    let proj_ptr = proj.as_mut_ptr() as usize;
    let out_ptr = out.as_mut_ptr() as usize;
    pool_shard_rows(pool, rows, work_per_row, &|lo, hi, _slot, _ws| {
        // SAFETY: shard_rows hands out disjoint, covering row ranges and
        // blocks until every worker finished, so the raw-slice views below
        // never alias and never outlive the borrow of proj/out.
        let pc = unsafe {
            std::slice::from_raw_parts_mut((proj_ptr as *mut f32).add(lo * n), (hi - lo) * n)
        };
        let oc = unsafe {
            std::slice::from_raw_parts_mut((out_ptr as *mut T).add(lo * w_out), (hi - lo) * w_out)
        };
        f(pc, oc);
    });
}

impl Backend for NativeBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        debug_assert_eq!(xs.len(), rows * n);
        let p = self.params(n)?;
        if rows == 0 {
            return Ok(match op {
                Op::CrossPolytope => Output::I32(Vec::new()),
                Op::BinaryEmbed => Output::Bits(Vec::new()),
                _ => Output::F32(Vec::new()),
            });
        }
        match op {
            Op::Transform => {
                let mut out = xs.to_vec();
                {
                    let out_ptr = out.as_mut_ptr() as usize;
                    let work = Self::chain_work(n);
                    pool_shard_rows(self.pool(), rows, work, &|lo, hi, _slot, _ws| {
                        // SAFETY: disjoint covering row ranges; the pool
                        // blocks until every worker acked.
                        let chunk = unsafe {
                            std::slice::from_raw_parts_mut(
                                (out_ptr as *mut f32).add(lo * n),
                                (hi - lo) * n,
                            )
                        };
                        Self::chain_batch(p, chunk, n);
                    });
                }
                Ok(Output::F32(out))
            }
            Op::Rff => {
                let mut proj = xs.to_vec();
                let mut out = vec![0.0f32; rows * 2 * n];
                let inv_sigma = p.inv_sigma;
                let feat_scale = (1.0 / (n as f64).sqrt()) as f32;
                // chain + ~8 units per cos/sin output
                let work = Self::chain_work(n) + 16 * n;
                shard_proj_out(self.pool(), &mut proj, &mut out, rows, n, 2 * n, work, |pc, oc| {
                    Self::chain_batch(p, pc, n);
                    for (prow, orow) in pc.chunks_exact(n).zip(oc.chunks_exact_mut(2 * n)) {
                        let (cos_half, sin_half) = orow.split_at_mut(n);
                        for (o, v) in cos_half.iter_mut().zip(prow.iter()) {
                            *o = (v * inv_sigma).cos() * feat_scale;
                        }
                        for (o, v) in sin_half.iter_mut().zip(prow.iter()) {
                            *o = (v * inv_sigma).sin() * feat_scale;
                        }
                    }
                });
                Ok(Output::F32(out))
            }
            Op::CrossPolytope => {
                let mut proj = xs.to_vec();
                let mut out = vec![0i32; rows];
                let work = Self::chain_work(n) + n;
                shard_proj_out(self.pool(), &mut proj, &mut out, rows, n, 1, work, |pc, oc| {
                    Self::chain_batch(p, pc, n);
                    for (prow, o) in pc.chunks_exact(n).zip(oc.iter_mut()) {
                        *o = crate::linalg::vecops::argmax_abs_signed(prow) as i32;
                    }
                });
                Ok(Output::I32(out))
            }
            Op::BinaryEmbed => {
                // chain then sign-quantize in place per shard: each worker
                // packs its own projection rows, so the response payload is
                // bits end to end (⌈n/64⌉ words per row — 32x below the
                // f32 transform lane)
                let mut proj = xs.to_vec();
                let words = n.div_ceil(64);
                let mut out = vec![0u64; rows * words];
                // pack cost ~n/32 of the chain's — chain_work dominates
                let work = Self::chain_work(n) + n;
                shard_proj_out(
                    self.pool(),
                    &mut proj,
                    &mut out,
                    rows,
                    n,
                    words,
                    work,
                    |pc, oc| {
                        Self::chain_batch(p, pc, n);
                        for (prow, orow) in pc.chunks_exact(n).zip(oc.chunks_exact_mut(words)) {
                            crate::linalg::simd::pack_signs(prow, orow);
                        }
                    },
                );
                Ok(Output::Bits(out))
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Per-dimension parameters cached **once** in shared buffers: each
/// `run_padded` call passes `Arc` clones (refcount bumps) instead of
/// deep-copying the three sign vectors per call — the same allocator-churn
/// fix the native path got from pre-folding `d3`.
struct SharedParams {
    d1: Arc<Vec<f32>>,
    d2: Arc<Vec<f32>>,
    d3: Arc<Vec<f32>>,
    /// `[1/σ]` as a ready-made scalar input buffer for the RFF op.
    inv_sigma: Arc<Vec<f32>>,
}

impl SharedParams {
    fn from_model(p: ModelParams) -> SharedParams {
        SharedParams {
            inv_sigma: Arc::new(vec![p.inv_sigma]),
            d1: Arc::new(p.d1),
            d2: Arc::new(p.d2),
            d3: Arc::new(p.d3),
        }
    }
}

/// PJRT backend: routes batches to the AOT artifacts via the runtime thread.
pub struct PjrtBackend {
    handle: RuntimeHandle,
    params: HashMap<usize, SharedParams>,
    /// available (op, n) -> sorted batch sizes, derived from artifact names.
    batches: HashMap<(Op, usize), Vec<usize>>,
}

impl PjrtBackend {
    /// `dims`, `sigma`, `seed` must match the NativeBackend's for parity.
    pub fn new(
        handle: RuntimeHandle,
        dims: &[usize],
        sigma: f64,
        seed: u64,
    ) -> Result<PjrtBackend, String> {
        let names = handle.names().map_err(|e| e.to_string())?;
        let mut batches: HashMap<(Op, usize), Vec<usize>> = HashMap::new();
        for name in &names {
            // artifact names are "<op>_n<k>_b<B>"
            if let Some((op, n, b)) = parse_artifact_name(name) {
                batches.entry((op, n)).or_default().push(b);
            }
        }
        for v in batches.values_mut() {
            v.sort_unstable();
            v.dedup();
        }
        Ok(PjrtBackend {
            handle,
            params: dims
                .iter()
                .map(|&n| {
                    (n, SharedParams::from_model(ModelParams::generate(n, sigma, seed)))
                })
                .collect(),
            batches,
        })
    }

    /// Smallest compiled batch >= rows, or the largest available (batches
    /// larger than it are split by the caller via multiple run calls).
    pub fn pick_batch(&self, op: Op, n: usize, rows: usize) -> Option<usize> {
        let avail = self.batches.get(&(op, n))?;
        avail
            .iter()
            .copied()
            .find(|b| *b >= rows)
            .or_else(|| avail.last().copied())
    }

    fn run_padded(
        &self,
        op: Op,
        n: usize,
        rows: usize,
        xs: &[f32],
    ) -> Result<Output, String> {
        let p = self
            .params
            .get(&n)
            .ok_or_else(|| format!("pjrt backend: no params for n={n}"))?;
        let b = self
            .pick_batch(op, n, rows)
            .ok_or_else(|| format!("no artifact for op={op} n={n}"))?;
        if rows > b {
            // split into chunks of <= b rows, concatenate
            let mut f32_out: Vec<f32> = Vec::new();
            let mut i32_out: Vec<i32> = Vec::new();
            let mut bits_out: Vec<u64> = Vec::new();
            let mut kind = 'f';
            for chunk in xs.chunks(b * n) {
                let r = chunk.len() / n;
                match self.run_padded(op, n, r, chunk)? {
                    Output::F32(v) => f32_out.extend_from_slice(&v),
                    Output::I32(v) => {
                        kind = 'i';
                        i32_out.extend_from_slice(&v);
                    }
                    Output::Bits(v) => {
                        kind = 'b';
                        bits_out.extend_from_slice(&v);
                    }
                }
            }
            return Ok(match kind {
                'i' => Output::I32(i32_out),
                'b' => Output::Bits(bits_out),
                _ => Output::F32(f32_out),
            });
        }
        // pad to exactly b rows
        let mut x = vec![0.0f32; b * n];
        x[..rows * n].copy_from_slice(xs);
        let name = format!("{op}_n{n}_b{b}");
        // only the request buffer is fresh; d1/d2/d3 (and the RFF scalar)
        // are Arc clones of the backend's cached buffers — no per-call copy
        let mut inputs = vec![
            Arc::new(x),
            Arc::clone(&p.d1),
            Arc::clone(&p.d2),
            Arc::clone(&p.d3),
        ];
        if op == Op::Rff {
            inputs.push(Arc::clone(&p.inv_sigma));
        }
        let out = self
            .handle
            .run_shared(&name, inputs)
            .map_err(|e| e.to_string())?;
        // strip padding rows
        let per = self.out_elems(op, n);
        Ok(match out {
            Output::F32(v) => Output::F32(v[..rows * per].to_vec()),
            Output::I32(v) => Output::I32(v[..rows * per].to_vec()),
            Output::Bits(v) => Output::Bits(v[..rows * per].to_vec()),
        })
    }
}

/// Parse "<op>_n<k>_b<B>" artifact names.
pub fn parse_artifact_name(name: &str) -> Option<(Op, usize, usize)> {
    let (op_s, rest) = name.split_once("_n")?;
    let (n_s, b_s) = rest.split_once("_b")?;
    Some((
        Op::parse(op_s)?,
        n_s.parse().ok()?,
        b_s.parse().ok()?,
    ))
}

impl Backend for PjrtBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        debug_assert_eq!(xs.len(), rows * n);
        self.run_padded(op, n, rows, xs)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_params_deterministic() {
        let a = ModelParams::generate(64, 2.0, 7);
        let b = ModelParams::generate(64, 2.0, 7);
        assert_eq!(a.d1, b.d1);
        assert_eq!(a.d3, b.d3);
        let c = ModelParams::generate(64, 2.0, 8);
        assert_ne!(a.d1, c.d1);
    }

    #[test]
    fn native_transform_matches_hdchain_scaling() {
        // the chain output on a unit vector has norm √n
        let n = 64;
        let be = NativeBackend::new(&[n], 1.0, 3);
        let x = Rng::new(5).unit_vec(n);
        let out = be.run_batch(Op::Transform, n, 1, &x).unwrap();
        let y = out.as_f32().unwrap();
        let norm: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - (n as f64).sqrt()).abs() < 1e-3 * (n as f64).sqrt());
    }

    #[test]
    fn native_rff_unit_features() {
        let n = 32;
        let be = NativeBackend::new(&[n], 2.0, 4);
        let x = Rng::new(6).unit_vec(n);
        let out = be.run_batch(Op::Rff, n, 1, &x).unwrap();
        let phi = out.as_f32().unwrap();
        assert_eq!(phi.len(), 2 * n);
        let ss: f64 = phi.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((ss - 1.0).abs() < 1e-5, "cos²+sin² sums to 1, got {ss}");
    }

    #[test]
    fn native_crosspolytope_range_and_scale_invariance() {
        let n = 64;
        let be = NativeBackend::new(&[n], 1.0, 5);
        let x = Rng::new(7).unit_vec(n);
        let id1 = be.run_batch(Op::CrossPolytope, n, 1, &x).unwrap();
        let scaled: Vec<f32> = x.iter().map(|v| v * 3.0).collect();
        let id2 = be.run_batch(Op::CrossPolytope, n, 1, &scaled).unwrap();
        assert_eq!(id1, id2);
        let v = id1.as_i32().unwrap()[0];
        assert!((0..2 * n as i32).contains(&v));
    }

    #[test]
    fn native_batch_equals_rowwise() {
        let n = 32;
        let be = NativeBackend::new(&[n], 1.0, 6);
        let mut rng = Rng::new(8);
        let rows = 5;
        let xs: Vec<f32> = rng.gaussian_vec(rows * n);
        let batch = be.run_batch(Op::Transform, n, rows, &xs).unwrap();
        let batch = batch.as_f32().unwrap();
        for r in 0..rows {
            let single = be
                .run_batch(Op::Transform, n, 1, &xs[r * n..(r + 1) * n])
                .unwrap();
            assert_eq!(single.as_f32().unwrap(), &batch[r * n..(r + 1) * n]);
        }
    }

    #[test]
    fn worker_counts_agree_bitwise_for_all_ops() {
        let n = 64;
        let rows = 41; // deliberately not a multiple of any worker count
        let xs = Rng::new(9).gaussian_vec(rows * n);
        let serial = NativeBackend::with_workers(&[n], 1.5, 2, 1);
        for op in [Op::Transform, Op::Rff, Op::CrossPolytope] {
            let want = serial.run_batch(op, n, rows, &xs).unwrap();
            for workers in [2usize, 4] {
                let be = NativeBackend::with_workers(&[n], 1.5, 2, workers);
                let got = be.run_batch(op, n, rows, &xs).unwrap();
                assert_eq!(got, want, "op={op} workers={workers}");
            }
        }
    }

    #[test]
    fn empty_batch_is_ok() {
        let be = NativeBackend::new(&[32], 1.0, 1);
        let out = be.run_batch(Op::Transform, 32, 0, &[]).unwrap();
        assert_eq!(out.as_f32().unwrap().len(), 0);
        let out = be.run_batch(Op::CrossPolytope, 32, 0, &[]).unwrap();
        assert_eq!(out.as_i32().unwrap().len(), 0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(
            parse_artifact_name("transform_n256_b16"),
            Some((Op::Transform, 256, 16))
        );
        assert_eq!(
            parse_artifact_name("crosspolytope_n64_b1"),
            Some((Op::CrossPolytope, 64, 1))
        );
        assert_eq!(parse_artifact_name("junk"), None);
        assert_eq!(parse_artifact_name("transform_nX_b1"), None);
    }

    #[test]
    fn unknown_dim_is_error() {
        let be = NativeBackend::new(&[64], 1.0, 1);
        assert!(be.run_batch(Op::Transform, 128, 1, &vec![0.0; 128]).is_err());
    }
}
