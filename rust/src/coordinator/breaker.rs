//! Per-lane health state: liveness phase + a consecutive-failure circuit
//! breaker.
//!
//! Every lane owns one [`LaneState`] shared between three parties:
//!
//! * the **lane thread** records per-backend-call outcomes
//!   ([`LaneState::record_success`] / [`LaneState::record_failure`]);
//! * the **supervisor** flips the phase to `Dead` while the lane is down
//!   and back to `Open` after a restart ([`LaneState::set_dead`] /
//!   [`LaneState::restart`]);
//! * **submitters** consult [`LaneState::phase`] and [`LaneState::admit`]
//!   to fail fast instead of queueing doomed work.
//!
//! The breaker is the classic three-state machine collapsed onto the lane
//! phase: `Open` (healthy) → `Degraded` (breaker open: shed with
//! `Unavailable`) after `threshold` *consecutive* failures → half-open
//! probing once `cooldown` elapses (admit() starts returning true again) →
//! back to `Open` on the first success, or re-armed for another cooldown
//! window by any failure while degraded. `threshold == 0` disables the
//! breaker entirely (failures are still counted for health reporting).
//!
//! Everything is atomics — no locks on the submit path — and time is
//! measured as microseconds since a per-state [`Instant`] epoch so the
//! cooldown comparison is a single `u64` load.

// Atomics come through the loom façade so the `--cfg loom` lane can model
// every interleaving of this file's lock-free protocol (see
// `crate::loom_models::breaker_*`); a normal build gets std atomics.
use crate::util::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::time::{Duration, Instant};

const PHASE_OPEN: u8 = 0;
const PHASE_DEGRADED: u8 = 1;
const PHASE_DEAD: u8 = 2;

/// Lane liveness phase, reported verbatim by the `health` wire op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Healthy: accepting and serving traffic.
    Open,
    /// Circuit breaker open: the lane thread is alive but the backend has
    /// failed `threshold` consecutive calls; submits shed until cooldown.
    Degraded,
    /// The lane thread died (lane-fatal panic) and the supervisor is in
    /// its restart backoff.
    Dead,
}

impl Phase {
    /// Wire name, as shipped by the `health` op.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Open => "open",
            Phase::Degraded => "degraded",
            Phase::Dead => "dead-restarting",
        }
    }
}

/// Shared lane health state (see module docs).
pub struct LaneState {
    epoch: Instant,
    phase: AtomicU8,
    consecutive_failures: AtomicU32,
    /// µs-since-epoch until which an open breaker sheds; only meaningful
    /// while the phase is `Degraded`.
    open_until_us: AtomicU64,
    threshold: u32,
    cooldown: Duration,
}

impl LaneState {
    /// `threshold` consecutive backend failures open the breaker for
    /// `cooldown`; `threshold == 0` disables the breaker.
    pub fn new(threshold: u32, cooldown: Duration) -> LaneState {
        LaneState {
            epoch: Instant::now(),
            phase: AtomicU8::new(PHASE_OPEN),
            consecutive_failures: AtomicU32::new(0),
            open_until_us: AtomicU64::new(0),
            threshold,
            cooldown,
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn phase(&self) -> Phase {
        // ORDERING: Relaxed — an advisory snapshot for admission/health; a
        // momentarily stale phase only means one extra queued request or a
        // slightly dated health report, never a safety violation.
        match self.phase.load(Ordering::Relaxed) {
            PHASE_DEGRADED => Phase::Degraded,
            PHASE_DEAD => Phase::Dead,
            _ => Phase::Open,
        }
    }

    /// Current consecutive-failure count (health reporting).
    pub fn consecutive_failures(&self) -> u32 {
        // ORDERING: Relaxed — reporting-only read of a monotonic-ish gauge.
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    /// Supervisor: the lane thread died; shed everything until restart.
    pub fn set_dead(&self) {
        // ORDERING: Relaxed — the phase byte is self-contained: no other
        // memory is published through it, submitters re-read it per call.
        self.phase.store(PHASE_DEAD, Ordering::Relaxed);
    }

    /// Supervisor: the lane thread was restarted — clean slate (the
    /// restarted lane gets a fresh breaker window rather than inheriting
    /// the failure streak that killed its predecessor).
    pub fn restart(&self) {
        // ORDERING: Relaxed — the three fields are independently meaningful
        // (each is re-read per decision); a submitter racing this reset can
        // at worst shed one request against the dying configuration, which
        // the Dead phase was already doing.
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.open_until_us.store(0, Ordering::Relaxed);
        self.phase.store(PHASE_OPEN, Ordering::Relaxed);
    }

    /// Lane thread: a backend call succeeded. Resets the failure streak
    /// and closes an open breaker (the half-open probe worked).
    pub fn record_success(&self) {
        // ORDERING: Relaxed — outcomes are recorded only by the single lane
        // thread, so these fields have one writer here; concurrent readers
        // (submitters) tolerate staleness as documented on `phase()`.
        self.consecutive_failures.store(0, Ordering::Relaxed);
        if self.phase.load(Ordering::Relaxed) == PHASE_DEGRADED {
            // ORDERING: Relaxed — closing the breaker: clearing the window
            // before the phase flip means a racing submitter sees either a
            // shed (old phase) or a clean open breaker, never a stale shed
            // window attached to an open phase.
            self.open_until_us.store(0, Ordering::Relaxed);
            self.phase.store(PHASE_OPEN, Ordering::Relaxed);
        }
    }

    /// Lane thread: a backend call failed (error or caught panic).
    /// Returns `true` when this failure *newly* opened the breaker (the
    /// caller counts `breaker_opens` on that edge); a failure while
    /// already degraded re-arms the cooldown window instead.
    pub fn record_failure(&self) -> bool {
        if self.threshold == 0 {
            // ORDERING: Relaxed — pure health counter when disabled.
            self.consecutive_failures.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // ORDERING: Relaxed — fetch_add is atomic RMW, so every failure gets
        // a distinct streak value even if outcomes ever raced; no other
        // memory hangs off the counter.
        let streak = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= self.threshold {
            let until = self.now_us() + self.cooldown.as_micros() as u64;
            // ORDERING: Relaxed — window written before the phase flip; a
            // submitter that sees DEGRADED with the *old* window admits one
            // extra half-open probe, which the protocol already tolerates
            // (probes are safe by design). The swap's RMW atomicity — not
            // its ordering — is what guarantees exactly one caller observes
            // the open edge and counts `breaker_opens`.
            self.open_until_us.store(until, Ordering::Relaxed);
            let was = self.phase.swap(PHASE_DEGRADED, Ordering::Relaxed);
            return was != PHASE_DEGRADED;
        }
        false
    }

    /// Submitter: may this request be queued? `Open` always admits;
    /// `Degraded` admits only once the cooldown has elapsed (half-open
    /// probes); `Dead` never admits (the caller maps that to `LaneDown`
    /// rather than `Unavailable`).
    pub fn admit(&self) -> bool {
        match self.phase() {
            Phase::Open => true,
            Phase::Dead => false,
            // ORDERING: Relaxed — admission is advisory (see `phase()`): a
            // stale window admits at most one early half-open probe.
            Phase::Degraded => self.now_us() >= self.open_until_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opens_after_threshold_consecutive_failures() {
        let s = LaneState::new(3, Duration::from_millis(50));
        assert_eq!(s.phase(), Phase::Open);
        assert!(!s.record_failure());
        assert!(!s.record_failure());
        assert!(s.admit(), "below threshold: still admitting");
        assert!(s.record_failure(), "third failure newly opens the breaker");
        assert_eq!(s.phase(), Phase::Degraded);
        assert!(!s.admit(), "open breaker sheds during cooldown");
        assert!(!s.record_failure(), "already open: no second open edge");
    }

    #[test]
    fn success_resets_the_streak() {
        let s = LaneState::new(3, Duration::from_millis(50));
        s.record_failure();
        s.record_failure();
        s.record_success();
        assert_eq!(s.consecutive_failures(), 0);
        s.record_failure();
        s.record_failure();
        assert_eq!(s.phase(), Phase::Open, "streak restarted after success");
    }

    #[test]
    fn half_open_after_cooldown_then_closes_on_success() {
        let s = LaneState::new(1, Duration::from_millis(10));
        assert!(s.record_failure());
        assert!(!s.admit());
        std::thread::sleep(Duration::from_millis(15));
        assert!(s.admit(), "cooldown elapsed: half-open probe admitted");
        assert_eq!(s.phase(), Phase::Degraded, "still degraded until a success");
        s.record_success();
        assert_eq!(s.phase(), Phase::Open);
        assert!(s.admit());
    }

    #[test]
    fn failure_while_degraded_rearms_the_window() {
        let s = LaneState::new(1, Duration::from_millis(20));
        assert!(s.record_failure());
        std::thread::sleep(Duration::from_millis(25));
        assert!(s.admit(), "first window elapsed");
        // the probe fails -> a fresh cooldown window opens
        assert!(!s.record_failure());
        assert!(!s.admit(), "failed probe re-arms the cooldown");
    }

    #[test]
    fn zero_threshold_disables_the_breaker() {
        let s = LaneState::new(0, Duration::from_millis(10));
        for _ in 0..100 {
            assert!(!s.record_failure());
        }
        assert_eq!(s.phase(), Phase::Open);
        assert!(s.admit());
        assert_eq!(s.consecutive_failures(), 100, "failures still counted");
    }

    #[test]
    fn dead_never_admits_and_restart_resets() {
        let s = LaneState::new(2, Duration::from_millis(10));
        s.record_failure();
        s.record_failure();
        s.set_dead();
        assert_eq!(s.phase(), Phase::Dead);
        assert!(!s.admit());
        assert_eq!(s.phase().name(), "dead-restarting");
        s.restart();
        assert_eq!(s.phase(), Phase::Open);
        assert_eq!(s.consecutive_failures(), 0);
        assert!(s.admit());
    }
}
