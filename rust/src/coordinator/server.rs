//! TCP serving front: newline-delimited JSON over `std::net`.
//!
//! Protocol (one JSON document per line):
//!
//! ```text
//! -> {"id": 7, "op": "transform", "vector": [0.1, -0.3, ...]}
//! <- {"id": 7, "ok": true, "result": [ ... ]}
//! <- {"id": 7, "ok": false, "error": "lane queue full"}
//! ```
//!
//! Each connection gets a handler thread; requests within a connection are
//! pipelined (responses come back in submit order, matching the lane's
//! FIFO guarantee). Backpressure surfaces as `ok: false / "lane queue
//! full"` so clients can retry with jitter. Below the lanes, batch compute
//! runs on the backend's persistent [`crate::runtime::WorkerPool`]: the
//! steady-state thread census is `1 accept + 1/connection + 1/lane +
//! TS_WORKERS pool workers`, fixed for the life of the server.

use super::{Coordinator, SubmitError};
use crate::runtime::{Op, Output};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Handle to a running TCP server.
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `coordinator`.
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_join = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let c = Arc::clone(&coordinator);
                            let _ = std::thread::Builder::new()
                                .name("tcp-conn".into())
                                .spawn(move || handle_connection(stream, c));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(TcpServer {
            addr: local,
            stop,
            accept_join: Some(accept_join),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept thread. Existing
    /// connection handlers finish their in-flight lines and exit on EOF.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // unblock accept() with a no-op connection
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

fn handle_connection(stream: TcpStream, coordinator: Arc<Coordinator>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = process_line(&line, &coordinator);
        if writer
            .write_all(format!("{reply}\n").as_bytes())
            .is_err()
        {
            break;
        }
    }
    let _ = peer; // connection closed
}

/// Parse one request line, execute, format the response (pure function —
/// unit-testable without sockets).
pub fn process_line(line: &str, coordinator: &Coordinator) -> Json {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return err_response(Json::Null, &format!("bad json: {e}")),
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    let Some(op) = doc.get("op").and_then(|o| o.as_str()).and_then(Op::parse) else {
        return err_response(id, "missing or unknown 'op'");
    };
    let Some(vec_json) = doc.get("vector").and_then(|v| v.as_arr()) else {
        return err_response(id, "missing 'vector' array");
    };
    let mut vector = Vec::with_capacity(vec_json.len());
    for v in vec_json {
        match v.as_f64() {
            Some(f) => vector.push(f as f32),
            None => return err_response(id, "'vector' must contain numbers"),
        }
    }
    match coordinator.submit(op, vector) {
        Ok((_, rx)) => match rx.recv() {
            Ok(resp) => match resp.result {
                Ok(out) => ok_response(id, out),
                Err(e) => err_response(id, &e),
            },
            Err(_) => err_response(id, "coordinator dropped response"),
        },
        Err(SubmitError::Busy) => err_response(id, "lane queue full"),
        Err(e) => err_response(id, &e.to_string()),
    }
}

fn ok_response(id: Json, out: Output) -> Json {
    let result = match out {
        Output::F32(v) => Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect()),
        Output::I32(v) => Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect()),
    };
    Json::obj(vec![("id", id), ("ok", Json::Bool(true)), ("result", result)])
}

fn err_response(id: Json, msg: &str) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, NativeBackend};
    use std::time::Duration;

    fn coordinator() -> Arc<Coordinator> {
        let config = Config {
            lanes: vec![(Op::Transform, 64), (Op::CrossPolytope, 64)],
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            sigma: 1.0,
            seed: 3,
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 3));
        Arc::new(Coordinator::start(config, backend))
    }

    #[test]
    fn process_line_happy_path() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 / 64.0)).collect();
        let line = format!(
            r#"{{"id": 1, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        let resp = process_line(&line, &c);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("result").unwrap().as_arr().unwrap().len(), 64);
    }

    #[test]
    fn process_line_errors() {
        let c = coordinator();
        // bad json
        let r = process_line("{nope", &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // unknown op
        let r = process_line(r#"{"id":2,"op":"nope","vector":[1]}"#, &c);
        assert!(r.get("error").unwrap().as_str().unwrap().contains("op"));
        // missing vector
        let r = process_line(r#"{"id":3,"op":"transform"}"#, &c);
        assert!(r.get("error").unwrap().as_str().unwrap().contains("vector"));
        // wrong dim -> unknown lane
        let r = process_line(r#"{"id":4,"op":"transform","vector":[1,2]}"#, &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn tcp_round_trip() {
        let c = coordinator();
        let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
        let addr = server.addr();

        let mut stream = TcpStream::connect(addr).unwrap();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", (i % 5) as f32)).collect();
        // pipeline three requests
        for id in 1..=3 {
            let line = format!(
                "{{\"id\": {id}, \"op\": \"crosspolytope\", \"vector\": [{}]}}\n",
                vec_str.join(",")
            );
            stream.write_all(line.as_bytes()).unwrap();
        }
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for id in 1..=3 {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let doc = Json::parse(resp.trim()).unwrap();
            assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{resp}");
            assert_eq!(doc.get("id").unwrap().as_f64(), Some(id as f64));
            let ids = doc.get("result").unwrap().as_arr().unwrap();
            assert_eq!(ids.len(), 1);
            // all three identical requests -> identical hash ids
        }
        drop(reader);
        server.shutdown();
    }

    #[test]
    fn tcp_multiple_clients() {
        let c = coordinator();
        let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let mut joins = Vec::new();
        for t in 0..3 {
            joins.push(std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let vec_str: Vec<String> =
                    (0..64).map(|i| format!("{}", ((i + t) % 7) as f32)).collect();
                let line = format!(
                    "{{\"id\": {t}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                    vec_str.join(",")
                );
                stream.write_all(line.as_bytes()).unwrap();
                let mut reader = BufReader::new(stream);
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                let doc = Json::parse(resp.trim()).unwrap();
                assert_eq!(doc.get("ok"), Some(&Json::Bool(true)));
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        server.shutdown();
    }
}
