//! TCP serving front: newline-delimited JSON over `std::net`.
//!
//! Protocol (one JSON document per line):
//!
//! ```text
//! -> {"id": 7, "op": "transform", "vector": [0.1, -0.3, ...]}
//! <- {"id": 7, "ok": true, "result": [ ... ]}
//! -> {"id": 8, "op": "binary_embed", "vector": [0.1, -0.3, ...], "timeout_ms": 50}
//! <- {"id": 8, "ok": true, "result": ["a3ff00125e9c7b01", ...]}
//! <- {"id": 8, "ok": false, "error": "lane queue full", "code": "busy"}
//! -> {"id": 9, "op": "metrics"}            (also: "health", "metrics_text")
//! <- {"id": 9, "ok": true, "result": { per-lane counters / states }}
//! ```
//!
//! `transform`/`rff` results are f32 arrays, `crosspolytope` a one-element
//! id array, and `binary_embed` ships each packed `u64` sign word as a
//! fixed-width 16-digit lowercase hex string (bit `i % 64` of word
//! `i / 64` = projection coordinate `i` negative) — exact, and ~5× fewer
//! response bytes than the float lane on the wire (32× in decoded form).
//!
//! Failure responses carry a stable machine-readable `code` alongside the
//! human-readable `error`: admission codes (`busy`, `unavailable`,
//! `lane_down`, ...), terminal request codes (`deadline`, `panic`,
//! `backend`), `timeout` (response-side wait exceeded), and `bad_request`
//! for malformed lines. An optional `timeout_ms` field sets the request's
//! deadline: expired-in-queue requests are answered `code: "deadline"`
//! without spending backend time.
//!
//! ## Codec / connection-core split
//!
//! Everything about the wire *format* — request parsing + validation,
//! response rendering, hex word packing, the server-side wire codes —
//! lives in [`super::codec`] (re-exported here for compatibility). This
//! module is the connection core: sockets, handler threads, shutdown,
//! drain, and transport-fault injection. The core serves any
//! [`LineService`], not just a [`Coordinator`] — [`serve`] binds one to a
//! listener, and [`crate::router::ShardRouter`] (the fleet tier) plugs in
//! the same way, which is how one connection core fronts both a single
//! shard and a whole fleet without a protocol fork.
//!
//! Each connection gets a handler thread; requests within a connection are
//! pipelined (responses come back in submit order, matching the lane's
//! FIFO guarantee). Backpressure surfaces as `ok: false / "lane queue
//! full"` so clients can retry with jitter. Below the lanes, batch compute
//! runs on the backend's persistent [`crate::runtime::WorkerPool`]: the
//! steady-state thread census is `1 accept + 1/connection + 1/lane +
//! TS_WORKERS pool workers`, fixed for the life of the server.
//!
//! Handler threads are **tracked and joined** on [`TcpServer::shutdown`]:
//! connection sockets carry a read timeout so a blocked reader notices the
//! stop flag within [`READ_POLL`], finishes any in-flight response line,
//! and exits — shutdown cannot race a half-written response, and no
//! detached handler outlives the server.
//!
//! ## Overload protection and drain
//!
//! Requests may carry `client_id` (admission-control key; the peer
//! address is the fallback) and `priority` (0–2; the shedder drops low
//! first). Refusals the taxonomy marks retryable additionally carry a
//! `retry_after_ms` hint. [`ServerOptions::max_conns`] bounds concurrent
//! handler threads — excess connections get a one-line `overloaded`
//! refusal instead of a thread. [`TcpServer::begin_drain`] flips the
//! server into drain: new connections get a one-line `draining` refusal,
//! existing connections' new requests get `draining` from the
//! coordinator, and [`TcpServer::shutdown_graceful`] then waits out
//! in-flight work under [`ServerOptions::drain_deadline`] before
//! joining. Transport-level fault injection
//! ([`ServerOptions::net_faults`]: `conn_drop` / `slow_read_ms` /
//! `partial_write`, plus the `down_after_ms`/`down_for_ms` shard-kill
//! window that makes the whole server play dead) lives here too, so the
//! chaos suite can prove the retry client and the shard router converge
//! under real network misbehavior.

use super::codec::{self, ParsedLine};
use super::prom;
use super::{
    Coordinator, SubmitError, SubmitOptions, DEFAULT_CALL_TIMEOUT, DRAINING_RETRY_MS,
    RESPONSE_GRACE,
};
use crate::coordinator::FaultPlan;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

// Compatibility re-exports: these names predate the codec split and are
// part of this module's public surface (used by the client, main, tests).
pub use super::codec::{hex_to_word, word_to_hex, CODE_BAD_REQUEST, CODE_TIMEOUT};

/// How often a blocked connection reader re-checks the stop flag.
const READ_POLL: Duration = Duration::from_millis(100);

/// Per-syscall write stall limit. Without it a client that stops reading
/// (full kernel send buffer) would block a handler in `write_all`
/// forever — and since shutdown now *joins* handlers, that would hang
/// shutdown itself. A stalled write errors out instead, tearing the
/// connection down; a draining-but-slow client is unaffected (the limit
/// is per write syscall, and `write_all` keeps going as long as each
/// write makes progress).
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(5);

/// Retry hint attached to accept-loop `overloaded` refusals (connection
/// cap hit). Connection slots churn fast, so the hint is short.
const MAX_CONNS_RETRY_MS: u64 = 50;

/// What the connection core serves: one request line in, one response
/// document out. [`CoordinatorService`] wires a single coordinator's
/// lanes behind it; [`crate::router::ShardRouter`] wires a whole fleet.
/// Implementations must be cheap to call concurrently — the core invokes
/// `handle_line` from one thread per connection.
pub trait LineService: Send + Sync + 'static {
    /// Answer one request line. `peer` is the fallback admission key for
    /// requests that carry no `client_id`.
    fn handle_line(&self, line: &str, peer: &str) -> Json;

    /// Enter drain: refuse new work with a typed `draining` answer while
    /// in-flight work keeps running. Default: nothing to drain.
    fn begin_drain(&self) {}

    /// Wait out in-flight work under `deadline`; `true` when everything
    /// completed in time. Default: nothing to wait for.
    fn drain(&self, _deadline: Duration) -> bool {
        true
    }
}

/// The single-node [`LineService`]: a [`Coordinator`]'s lanes behind the
/// wire codec (plus the `metrics`/`health`/`metrics_text` introspection
/// ops). [`CoordinatorService::new`] is a passthrough front (one request
/// line = one lane submit); [`CoordinatorService::with_ingress`] puts
/// the coalescing ingress ([`super::Batcher`]: in-flight dedup + a
/// bounded response cache) in front of the same lanes.
pub struct CoordinatorService {
    coordinator: Arc<Coordinator>,
    ingress: Option<super::Batcher>,
}

impl CoordinatorService {
    pub fn new(coordinator: Arc<Coordinator>) -> Self {
        CoordinatorService {
            coordinator,
            ingress: None,
        }
    }

    /// Serve through the coalescing ingress: every compute line passes
    /// admission, then the response cache, then in-flight dedup, before
    /// reaching a lane. Introspection ops and refusal rendering are
    /// identical to the passthrough front.
    pub fn with_ingress(coordinator: Arc<Coordinator>, opts: super::IngressOptions) -> Self {
        let ingress = super::Batcher::new(Arc::clone(&coordinator), opts);
        CoordinatorService {
            coordinator,
            ingress: Some(ingress),
        }
    }
}

impl LineService for CoordinatorService {
    fn handle_line(&self, line: &str, peer: &str) -> Json {
        match &self.ingress {
            None => process_line_from(line, &self.coordinator, peer),
            Some(batcher) => match codec::parse_line(line) {
                ParsedLine::Malformed(reply) => reply,
                ParsedLine::Compute(req) => batcher.respond(req, peer),
                ParsedLine::Other { id, op, .. } => {
                    respond_other(id, op.as_deref(), &self.coordinator)
                }
            },
        }
    }

    fn begin_drain(&self) {
        self.coordinator.begin_drain();
    }

    fn drain(&self, deadline: Duration) -> bool {
        self.coordinator.drain(deadline)
    }
}

/// Tuning for [`TcpServer::start_with`].
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Maximum concurrent connection-handler threads; further connections
    /// get a one-line `overloaded` refusal. `0` = unlimited.
    pub max_conns: usize,
    /// How long [`TcpServer::shutdown_graceful`] waits for in-flight work
    /// before cutting queued jobs over to typed `deadline` answers.
    pub drain_deadline: Duration,
    /// Transport-level fault injection (`conn_drop` / `slow_read_ms` /
    /// `partial_write` / `down_after_ms` / `down_for_ms` keys of the
    /// `TS_FAULT` grammar); backend-fault keys in the plan are ignored
    /// here.
    pub net_faults: FaultPlan,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            max_conns: 256,
            drain_deadline: Duration::from_secs(5),
            net_faults: FaultPlan::default(),
        }
    }
}

/// Transport fault state shared by connection handlers: one RNG so drop /
/// truncation decisions are a single deterministic stream per server, and
/// one start-of-life instant anchoring the shard-kill window.
struct NetFaults {
    plan: FaultPlan,
    rng: Mutex<Rng>,
    started: Instant,
}

impl NetFaults {
    /// Draw (drop this reply & close, truncate this reply & close).
    fn decide(&self) -> (bool, bool) {
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        (
            self.plan.conn_drop_p > 0.0 && rng.uniform() < self.plan.conn_drop_p,
            self.plan.partial_write_p > 0.0 && rng.uniform() < self.plan.partial_write_p,
        )
    }

    /// Inside the injected shard-kill window? While true the server plays
    /// dead: new connections are dropped without a byte and existing
    /// handlers exit without replying — exactly what a killed shard
    /// process looks like from the router's side. `down_for` zero means
    /// the shard never comes back.
    fn down_now(&self) -> bool {
        let Some(after) = self.plan.down_after else {
            return false;
        };
        let t = self.started.elapsed();
        t >= after && (self.plan.down_for.is_zero() || t < after + self.plan.down_for)
    }
}

/// Handle to a running TCP server.
pub struct TcpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    /// Drain latch: accept loop refuses new connections with `draining`
    /// while existing handlers keep serving until shutdown.
    draining: Arc<AtomicBool>,
    service: Arc<dyn LineService>,
    drain_deadline: Duration,
    accept_join: Option<std::thread::JoinHandle<()>>,
    /// Live connection-handler threads, joined on shutdown (finished
    /// handlers are pruned opportunistically as new connections arrive).
    conn_joins: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `coordinator`
    /// with default [`ServerOptions`].
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<TcpServer> {
        Self::start_with(coordinator, addr, ServerOptions::default())
    }

    /// Bind `addr` and serve `coordinator` with explicit options.
    pub fn start_with(
        coordinator: Arc<Coordinator>,
        addr: &str,
        opts: ServerOptions,
    ) -> std::io::Result<TcpServer> {
        serve(Arc::new(CoordinatorService::new(coordinator)), addr, opts)
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Enter drain: the accept loop starts refusing new connections with a
    /// one-line `draining` answer, and the service refuses new work the
    /// same way, while in-flight work keeps running. Idempotent.
    pub fn begin_drain(&self) {
        // ORDERING: Relaxed — one-way latch polled by the accept loop;
        // refusal behavior needs no cross-thread data ordering.
        self.draining.store(true, Ordering::Relaxed);
        self.service.begin_drain();
    }

    /// Graceful stop: [`begin_drain`](Self::begin_drain), wait for
    /// in-flight work under the configured drain deadline (queued jobs
    /// past it get typed `deadline` answers — never silence), then
    /// [`shutdown`](Self::shutdown). Returns `true` if every queued job
    /// completed before the deadline.
    pub fn shutdown_graceful(self) -> bool {
        self.begin_drain();
        let drained = self.service.drain(self.drain_deadline);
        self.shutdown();
        drained
    }

    /// Stop accepting connections, then join the accept thread **and every
    /// connection handler**. Handlers notice the stop flag within
    /// [`READ_POLL`], complete any response line they were writing, and
    /// exit — so shutdown returns only after the last byte of the last
    /// in-flight response has been flushed (the pre-fix detached handlers
    /// could race a half-written line against process teardown).
    pub fn shutdown(mut self) {
        // ORDERING: Relaxed — one-way latch; handlers poll it (within
        // READ_POLL) and this thread then blocks on their joins, which
        // provide the actual happens-before for everything they wrote.
        self.stop.store(true, Ordering::Relaxed);
        // unblock accept() with a no-op connection
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        let handlers = std::mem::take(&mut *self.conn_joins.lock().unwrap());
        for j in handlers {
            let _ = j.join();
        }
    }
}

/// Bind `addr` and serve an arbitrary [`LineService`] — the
/// transport-agnostic entry point the coordinator path
/// ([`TcpServer::start_with`]) and the fleet tier
/// ([`crate::router::ShardRouter`]) share.
pub fn serve(
    service: Arc<dyn LineService>,
    addr: &str,
    opts: ServerOptions,
) -> std::io::Result<TcpServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let draining = Arc::new(AtomicBool::new(false));
    let draining2 = Arc::clone(&draining);
    let conn_joins = Arc::new(Mutex::new(Vec::new()));
    let joins2 = Arc::clone(&conn_joins);
    let svc_accept = Arc::clone(&service);
    let net: Option<Arc<NetFaults>> = opts.net_faults.has_net_faults().then(|| {
        Arc::new(NetFaults {
            plan: opts.net_faults,
            rng: Mutex::new(Rng::new(opts.net_faults.seed)),
            started: Instant::now(),
        })
    });
    let max_conns = opts.max_conns;
    let accept_join = std::thread::Builder::new()
        .name("tcp-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                // ORDERING: Relaxed — the stop flag is a one-way latch
                // polled in a loop; no memory is published through it
                // (shutdown correctness comes from join(), below).
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        // injected shard-kill window: a dead process
                        // accepts nothing — drop the connection without a
                        // single byte so peers see it as unreachable
                        if net.as_ref().map_or(false, |nf| nf.down_now()) {
                            drop(stream);
                            continue;
                        }
                        // ORDERING: Relaxed — drain latch is one-way;
                        // refusing a connection needs no ordering with
                        // other memory.
                        if draining2.load(Ordering::Relaxed) {
                            refuse_connection(
                                stream,
                                &SubmitError::Draining {
                                    retry_after_ms: DRAINING_RETRY_MS,
                                },
                            );
                            continue;
                        }
                        let mut joins = joins2.lock().unwrap();
                        // prune handlers whose connections already
                        // closed so the vec tracks live threads only
                        joins.retain(|j: &std::thread::JoinHandle<()>| !j.is_finished());
                        if max_conns > 0 && joins.len() >= max_conns {
                            drop(joins);
                            refuse_connection(
                                stream,
                                &SubmitError::Overloaded {
                                    retry_after_ms: MAX_CONNS_RETRY_MS,
                                },
                            );
                            continue;
                        }
                        let svc = Arc::clone(&svc_accept);
                        let flag = Arc::clone(&stop2);
                        let nf = net.clone();
                        let spawned = std::thread::Builder::new()
                            .name("tcp-conn".into())
                            .spawn(move || handle_connection(stream, svc, flag, nf));
                        if let Ok(handle) = spawned {
                            joins.push(handle);
                        }
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok(TcpServer {
        addr: local,
        stop,
        draining,
        service,
        drain_deadline: opts.drain_deadline,
        accept_join: Some(accept_join),
        conn_joins,
    })
}

/// Write a single coded refusal line (id `null`, with `retry_after_ms`)
/// to a connection the accept loop will not service, then close it.
fn refuse_connection(stream: TcpStream, err: &SubmitError) {
    let _ = stream.set_write_timeout(Some(WRITE_STALL_LIMIT));
    let mut stream = stream;
    let reply = codec::err_response_with_hint(
        Json::Null,
        &err.to_string(),
        err.code(),
        err.retry_after_ms(),
    );
    let _ = stream.write_all(format!("{reply}\n").as_bytes());
}

fn handle_connection(
    stream: TcpStream,
    service: Arc<dyn LineService>,
    stop: Arc<AtomicBool>,
    net: Option<Arc<NetFaults>>,
) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "unknown".into());
    // bounded read: a quiet connection re-checks the stop flag every
    // READ_POLL instead of blocking shutdown forever; bounded write: a
    // client that stops draining cannot pin the (joined-on-shutdown)
    // handler in write_all
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_STALL_LIMIT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // bytes, not String: read_line's UTF-8 guard would DROP buffered bytes
    // when a read timeout lands mid-multi-byte character — read_until
    // keeps every consumed byte across timeouts
    let mut line: Vec<u8> = Vec::new();
    loop {
        // injected shard-kill window: a dead process answers nothing —
        // the handler dies mid-conversation, exactly like a kill -9
        if net.as_ref().map_or(false, |nf| nf.down_now()) {
            return;
        }
        match reader.read_until(b'\n', &mut line) {
            Ok(0) => {
                // EOF — but a read timeout may have left a complete-but-
                // unterminated final request buffered; serve it before
                // closing (the protocol promise for newline-less tails)
                let text = String::from_utf8_lossy(&line);
                if !text.trim().is_empty() {
                    let reply = service.handle_line(text.trim_end(), &peer);
                    let _ = writer.write_all(format!("{reply}\n").as_bytes());
                }
                break;
            }
            Ok(_) => {
                let text = String::from_utf8_lossy(&line);
                if !text.trim().is_empty() {
                    if let Some(nf) = &net {
                        // injected read-path latency: the request sits
                        // "on the wire" before the server acts on it
                        if !nf.plan.slow_read.is_zero() {
                            std::thread::sleep(nf.plan.slow_read);
                        }
                        if nf.down_now() {
                            return;
                        }
                    }
                    let reply = service.handle_line(text.trim_end(), &peer);
                    let payload = format!("{reply}\n");
                    let (drop_conn, partial) =
                        net.as_ref().map(|nf| nf.decide()).unwrap_or((false, false));
                    if drop_conn {
                        // injected fault: connection dies instead of
                        // replying — the client saw the request accepted
                        // at the TCP level but gets no answer
                        return;
                    }
                    if partial {
                        // injected fault: half a reply, then the
                        // connection dies mid-line
                        let half = payload.len() / 2;
                        let _ = writer.write_all(&payload.as_bytes()[..half]);
                        let _ = writer.flush();
                        return;
                    }
                    if writer.write_all(payload.as_bytes()).is_err() {
                        break;
                    }
                }
                if line.last() != Some(&b'\n') {
                    break; // EOF without trailing newline: final line served
                }
                line.clear();
                // a continuously-pipelining client never hits the read
                // timeout, so the stop flag must also gate here or one
                // busy connection could hang the joining shutdown forever
                // ORDERING: Relaxed — one-way latch poll (see shutdown()).
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // timeout — any partial line stays buffered in `line` and
                // the next read continues appending to it
                // ORDERING: Relaxed — one-way latch poll (see shutdown()).
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Parse one request line, execute, format the response (pure function —
/// unit-testable without sockets). Admission falls back to the `"local"`
/// client key; the TCP path uses [`process_line_from`] with the peer
/// address instead.
pub fn process_line(line: &str, coordinator: &Coordinator) -> Json {
    process_line_from(line, coordinator, "local")
}

/// [`process_line`] with an explicit fallback admission key (`peer`),
/// used when the request carries no `client_id` field.
pub fn process_line_from(line: &str, coordinator: &Coordinator, peer: &str) -> Json {
    match codec::parse_line(line) {
        ParsedLine::Malformed(reply) => reply,
        ParsedLine::Compute(req) => respond_compute(req, coordinator, peer),
        ParsedLine::Other { id, op, .. } => respond_other(id, op.as_deref(), coordinator),
    }
}

/// Answer an introspection op (`metrics` / `health` / `metrics_text`)
/// from shared coordinator state, or refuse an unknown op — shared by
/// the passthrough and ingress fronts so both render identical bytes.
pub(crate) fn respond_other(id: Json, op: Option<&str>, coordinator: &Coordinator) -> Json {
    match op {
        Some("metrics") => codec::ok_response_json(id, coordinator.metrics_json()),
        Some("health") => codec::ok_response_json(id, coordinator.health_json()),
        Some("metrics_text") => codec::ok_response_json(
            id,
            Json::Str(prom::render(&prom::coordinator_families(
                &coordinator.metrics_json(),
            ))),
        ),
        _ => codec::err_response(id, "missing or unknown 'op'", CODE_BAD_REQUEST),
    }
}

/// Execute a validated compute request against a coordinator and render
/// the wire response (the lane-bound half of [`process_line_from`]).
pub(crate) fn respond_compute(req: codec::Request, coordinator: &Coordinator, peer: &str) -> Json {
    let codec::Request {
        id,
        op,
        timeout,
        client_id,
        priority,
        // cache participation is an ingress concern; the passthrough
        // front never caches, so the opt-out is trivially honored
        no_cache: _,
        vector,
    } = req;
    let opts = SubmitOptions {
        deadline: timeout,
        client: Some(client_id.as_deref().unwrap_or(peer)),
        priority,
    };
    match coordinator.submit_with_opts(op, vector, opts) {
        Ok((_, rx)) => {
            // bounded wait: the lane's own typed Deadline answer should win
            // the race (RESPONSE_GRACE), but a dead or wedged lane must
            // surface an error here, never hang the connection handler
            let wait = timeout
                .unwrap_or(DEFAULT_CALL_TIMEOUT)
                .saturating_add(RESPONSE_GRACE);
            match rx.recv_timeout(wait) {
                Ok(resp) => match resp.result {
                    Ok(out) => codec::ok_response(id, out),
                    Err(e) => codec::err_response(id, &e.to_string(), e.code()),
                },
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    codec::err_response(id, "response timed out", CODE_TIMEOUT)
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => codec::err_response_with_hint(
                    id,
                    "lane dropped response (restarted mid-request)",
                    "lane_down",
                    SubmitError::LaneDown.retry_after_ms(),
                ),
            }
        }
        // every taxonomy-retryable refusal carries its retry_after_ms hint
        // so clients can back off without guessing
        Err(e) => codec::err_response_with_hint(id, &e.to_string(), e.code(), e.retry_after_ms()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Config, NativeBackend};
    use crate::runtime::Op;
    use std::time::Duration;

    fn coordinator() -> Arc<Coordinator> {
        let config = Config {
            lanes: vec![
                (Op::Transform, 64),
                (Op::CrossPolytope, 64),
                (Op::BinaryEmbed, 64),
            ],
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            sigma: 1.0,
            seed: 3,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 3));
        Arc::new(Coordinator::start(config, backend))
    }

    #[test]
    fn process_line_happy_path() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 / 64.0)).collect();
        let line = format!(
            r#"{{"id": 1, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        let resp = process_line(&line, &c);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("result").unwrap().as_arr().unwrap().len(), 64);
    }

    #[test]
    fn process_line_errors() {
        let c = coordinator();
        // bad json
        let r = process_line("{nope", &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // unknown op
        let r = process_line(r#"{"id":2,"op":"nope","vector":[1]}"#, &c);
        assert!(r.get("error").unwrap().as_str().unwrap().contains("op"));
        // missing vector
        let r = process_line(r#"{"id":3,"op":"transform"}"#, &c);
        assert!(r.get("error").unwrap().as_str().unwrap().contains("vector"));
        // wrong dim -> unknown lane
        let r = process_line(r#"{"id":4,"op":"transform","vector":[1,2]}"#, &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn process_line_binary_embed_ships_hex_words() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 - 31.5)).collect();
        let line = format!(
            r#"{{"id": 9, "op": "binary_embed", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        let resp = process_line(&line, &c);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let words = resp.get("result").unwrap().as_arr().unwrap();
        assert_eq!(words.len(), 1, "64-bit code = one packed word");
        let word = hex_to_word(words[0].as_str().unwrap()).expect("16 hex digits");
        // cross-check against the float lane: hex bits == sign pattern
        let tline = format!(
            r#"{{"id": 10, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        let tresp = process_line(&tline, &c);
        let dense = tresp.get("result").unwrap().as_arr().unwrap();
        for (i, y) in dense.iter().enumerate() {
            let neg = y.as_f64().unwrap().is_sign_negative();
            assert_eq!((word >> i) & 1 == 1, neg, "bit {i}");
        }
        // wire footprint: 18 bytes ("...") per packed word vs ~12 per f32
        // number × 64 — the response line is ~20x shorter
        assert!(resp.to_string().len() * 10 < tresp.to_string().len() * 2);
    }

    #[test]
    fn process_line_error_responses_carry_codes() {
        let c = coordinator();
        let r = process_line("{nope", &c);
        assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
        let r = process_line(r#"{"id":4,"op":"transform","vector":[1,2]}"#, &c);
        assert_eq!(r.get("code").unwrap().as_str(), Some("unknown_lane"));
    }

    #[test]
    fn process_line_metrics_and_health_ops() {
        let c = coordinator();
        // serve one real request so the counters are non-trivial
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 / 64.0)).collect();
        let line = format!(
            r#"{{"id": 1, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        assert_eq!(process_line(&line, &c).get("ok"), Some(&Json::Bool(true)));
        // metrics op: per-lane counters, consistent with metrics_json()
        let m = process_line(r#"{"id": 2, "op": "metrics"}"#, &c);
        assert_eq!(m.get("ok"), Some(&Json::Bool(true)));
        let lane = m.get("result").unwrap().get("transform_n64").unwrap();
        assert_eq!(lane.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(lane.get("lane_failures").unwrap().as_f64(), Some(0.0));
        // health op: lane states
        let h = process_line(r#"{"id": 3, "op": "health"}"#, &c);
        assert_eq!(h.get("ok"), Some(&Json::Bool(true)));
        let lane = h.get("result").unwrap().get("transform_n64").unwrap();
        assert_eq!(lane.get("state").unwrap().as_str(), Some("open"));
        // both responses are valid JSON on the wire
        assert!(Json::parse(&m.to_string()).is_ok());
        assert!(Json::parse(&h.to_string()).is_ok());
    }

    #[test]
    fn metrics_text_op_renders_prometheus_exposition() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 / 64.0)).collect();
        let line = format!(
            r#"{{"id": 1, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        assert_eq!(process_line(&line, &c).get("ok"), Some(&Json::Bool(true)));
        let r = process_line(r#"{"id": 2, "op": "metrics_text"}"#, &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let text = r.get("result").unwrap().as_str().unwrap().to_string();
        // exposition format: TYPE headers + labeled samples
        assert!(text.contains("# TYPE ts_lane_completed counter"), "{text}");
        assert!(
            text.contains(r#"ts_lane_completed{lane="transform_n64"} 1"#),
            "{text}"
        );
        // and it parses back (the format round trip lives in prom.rs)
        let families = prom::parse(&text).expect("rendered exposition must parse");
        assert!(families.iter().any(|f| f.name == "ts_lane_completed"));
        // the multi-line payload survives the JSON wire encoding
        let reparsed = Json::parse(&r.to_string()).unwrap();
        assert_eq!(
            reparsed.get("result").unwrap().as_str(),
            Some(text.as_str())
        );
    }

    #[test]
    fn process_line_rejects_bad_timeout() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32)).collect();
        let line = format!(
            r#"{{"id": 5, "op": "transform", "vector": [{}], "timeout_ms": -3}}"#,
            vec_str.join(",")
        );
        let r = process_line(&line, &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("timeout_ms"));
        // a generous explicit timeout passes through and succeeds
        let line = format!(
            r#"{{"id": 6, "op": "transform", "vector": [{}], "timeout_ms": 5000}}"#,
            vec_str.join(",")
        );
        assert_eq!(process_line(&line, &c).get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn process_line_rejects_bad_client_id_and_priority() {
        let c = coordinator();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32)).collect();
        let line = format!(
            r#"{{"id": 7, "op": "transform", "vector": [{}], "client_id": 9}}"#,
            vec_str.join(",")
        );
        let r = process_line(&line, &c);
        assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("client_id"));
        let line = format!(
            r#"{{"id": 8, "op": "transform", "vector": [{}], "priority": 1.5}}"#,
            vec_str.join(",")
        );
        let r = process_line(&line, &c);
        assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("priority"));
        // a valid priority passes through and succeeds
        let line = format!(
            r#"{{"id": 9, "op": "transform", "vector": [{}], "priority": 2, "client_id": "t"}}"#,
            vec_str.join(",")
        );
        assert_eq!(process_line(&line, &c).get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn draining_coordinator_refusal_carries_retry_hint_on_the_wire() {
        let c = coordinator();
        c.begin_drain();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32)).collect();
        let line = format!(
            r#"{{"id": 10, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        let r = process_line(&line, &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("code").unwrap().as_str(), Some("draining"));
        assert_eq!(
            r.get("retry_after_ms").unwrap().as_f64(),
            Some(super::DRAINING_RETRY_MS as f64)
        );
        // non-retryable refusals must NOT carry a hint
        let r = process_line(r#"{"id":11,"op":"transform","vector":[1,2]}"#, &c);
        assert_eq!(r.get("code").unwrap().as_str(), Some("unknown_lane"));
        assert!(r.get("retry_after_ms").is_none());
    }

    #[test]
    fn ingress_service_matches_passthrough_bytes() {
        let c = coordinator();
        let plain = CoordinatorService::new(Arc::clone(&c));
        let svc = CoordinatorService::with_ingress(
            Arc::clone(&c),
            crate::coordinator::IngressOptions::default(),
        );
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", i as f32 / 64.0)).collect();
        let line = format!(
            r#"{{"id": 1, "op": "transform", "vector": [{}]}}"#,
            vec_str.join(",")
        );
        // zero cross-request corruption: ingress replies are
        // byte-identical to the uncoalesced path's
        let plain_reply = plain.handle_line(&line, "p");
        let first = svc.handle_line(&line, "p");
        assert_eq!(first.to_string(), plain_reply.to_string());
        // the cached repeat still renders the same bytes
        let second = svc.handle_line(&line, "p");
        assert_eq!(second.to_string(), first.to_string());
        let m = c.lane_metrics(Op::Transform, 64).unwrap();
        assert_eq!(m.cache_hits.load(std::sync::atomic::Ordering::Relaxed), 1);
        // introspection and refusals flow through the ingress front too
        let metrics = svc.handle_line(r#"{"id":2,"op":"metrics"}"#, "p");
        assert_eq!(metrics.get("ok"), Some(&Json::Bool(true)));
        let lane = metrics.get("result").unwrap().get("transform_n64").unwrap();
        assert_eq!(lane.get("cache_hits").unwrap().as_f64(), Some(1.0));
        let bad = svc.handle_line("{nope", "p");
        assert_eq!(bad.get("code").unwrap().as_str(), Some("bad_request"));
        let refusal = svc.handle_line(r#"{"id":3,"op":"transform","vector":[1,2]}"#, "p");
        assert_eq!(refusal.get("code").unwrap().as_str(), Some("unknown_lane"));
    }

    /// A trivial non-coordinator service: proves the connection core is
    /// genuinely transport-agnostic after the codec split.
    struct Shout;

    impl LineService for Shout {
        fn handle_line(&self, line: &str, peer: &str) -> Json {
            Json::obj(vec![
                ("echo", Json::Str(line.to_uppercase())),
                ("peer_seen", Json::Bool(!peer.is_empty())),
            ])
        }
    }

    #[test]
    fn serve_runs_any_line_service() {
        let server = serve(Arc::new(Shout), "127.0.0.1:0", ServerOptions::default()).unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"hello fleet\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let doc = Json::parse(resp.trim()).unwrap();
        assert_eq!(doc.get("echo").unwrap().as_str(), Some("HELLO FLEET"));
        assert_eq!(doc.get("peer_seen"), Some(&Json::Bool(true)));
        drop(reader);
        server.shutdown();
    }

    #[test]
    fn down_window_makes_the_server_play_dead_then_recover() {
        let c = coordinator();
        let plan = FaultPlan::parse("down_after_ms:0,down_for_ms:300").unwrap();
        let server = TcpServer::start_with(
            Arc::clone(&c),
            "127.0.0.1:0",
            ServerOptions {
                net_faults: plan,
                ..ServerOptions::default()
            },
        )
        .unwrap();
        let addr = server.addr();
        // inside the window: connection is accepted then dropped byteless
        let stream = TcpStream::connect(addr).unwrap();
        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
        let mut reader = BufReader::new(stream);
        let mut resp = String::new();
        let n = reader.read_line(&mut resp).unwrap_or(0);
        assert_eq!(n, 0, "a down shard must not answer, got: {resp}");
        // after the window: normal service resumes
        std::thread::sleep(Duration::from_millis(400));
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", (i % 5) as f32)).collect();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(
                format!(
                    "{{\"id\": 1, \"op\": \"transform\", \"vector\": [{}]}}\n",
                    vec_str.join(",")
                )
                .as_bytes(),
            )
            .unwrap();
        let mut reader = BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let doc = Json::parse(resp.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{resp}");
        drop(reader);
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_connection_handlers() {
        let c = coordinator();
        let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        // open connections and leave them idle — the pre-fix server leaked
        // these handler threads; shutdown must now stop and join them
        // within the read-poll interval instead of hanging or detaching
        let idle1 = TcpStream::connect(addr).unwrap();
        let mut busy = TcpStream::connect(addr).unwrap();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", (i % 3) as f32)).collect();
        busy.write_all(
            format!(
                "{{\"id\": 1, \"op\": \"transform\", \"vector\": [{}]}}\n",
                vec_str.join(",")
            )
            .as_bytes(),
        )
        .unwrap();
        let mut reader = BufReader::new(busy.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(
            Json::parse(resp.trim()).unwrap().get("ok"),
            Some(&Json::Bool(true))
        );
        let t0 = std::time::Instant::now();
        server.shutdown(); // joins accept + both handlers
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown must not hang on idle connections"
        );
        drop(idle1);
    }

    #[test]
    fn tcp_round_trip() {
        let c = coordinator();
        let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
        let addr = server.addr();

        let mut stream = TcpStream::connect(addr).unwrap();
        let vec_str: Vec<String> = (0..64).map(|i| format!("{}", (i % 5) as f32)).collect();
        // pipeline three requests
        for id in 1..=3 {
            let line = format!(
                "{{\"id\": {id}, \"op\": \"crosspolytope\", \"vector\": [{}]}}\n",
                vec_str.join(",")
            );
            stream.write_all(line.as_bytes()).unwrap();
        }
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for id in 1..=3 {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let doc = Json::parse(resp.trim()).unwrap();
            assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{resp}");
            assert_eq!(doc.get("id").unwrap().as_f64(), Some(id as f64));
            let ids = doc.get("result").unwrap().as_arr().unwrap();
            assert_eq!(ids.len(), 1);
            // all three identical requests -> identical hash ids
        }
        drop(reader);
        server.shutdown();
    }

    #[test]
    fn tcp_multiple_clients() {
        let c = coordinator();
        let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let mut joins = Vec::new();
        for t in 0..3 {
            joins.push(std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let vec_str: Vec<String> =
                    (0..64).map(|i| format!("{}", ((i + t) % 7) as f32)).collect();
                let line = format!(
                    "{{\"id\": {t}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                    vec_str.join(",")
                );
                stream.write_all(line.as_bytes()).unwrap();
                let mut reader = BufReader::new(stream);
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                let doc = Json::parse(resp.trim()).unwrap();
                assert_eq!(doc.get("ok"), Some(&Json::Bool(true)));
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        server.shutdown();
    }
}
