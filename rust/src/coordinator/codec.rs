//! Transport-agnostic request/response codec for the serving protocol.
//!
//! Everything about the newline-delimited JSON wire format that does not
//! require a socket or a live [`super::Coordinator`] lives here: request
//! parsing and validation ([`parse_line`]), response rendering
//! ([`ok_response`] / [`err_response`] / [`err_response_with_hint`] /
//! [`partial_response`]), the packed-word hex encoding
//! ([`word_to_hex`] / [`hex_to_word`]), the LSH result pair encoding
//! ([`lsh_ok_response`] / [`lsh_pairs`]), and the server-side wire codes.
//! [`super::server`] (the connection core) and [`crate::router`] (the
//! fleet tier) are both thin shells over this module, which is what lets
//! the shard router relay and synthesize responses that are
//! byte-compatible with a single server's.
//!
//! The split is covered by round-trip tests below that pin the rendered
//! bytes of every op, every error code, hex `Bits` words, and
//! `retry_after_ms` hints against golden pre-split strings — the carve-out
//! is invisible on the wire.

use super::admission;
use crate::runtime::{Op, Output};
use crate::util::json::Json;
use std::time::Duration;

/// Codec-level wire codes: failure modes born before a request reaches a
/// coordinator (unparseable line, bad shape), after its typed answer was
/// lost (response-channel timeout), or in the fleet tier (a required
/// shard with every replica down, a scatter-gather answer missing some
/// shards' contributions). Declared as named consts so `cargo xtask lint`
/// (R4) and the wire-taxonomy round-trip test can enumerate them
/// mechanically against ROADMAP's failure-model table, alongside the
/// `RequestError`/`SubmitError` `code()` sets.
pub const CODE_BAD_REQUEST: &str = "bad_request";
pub const CODE_TIMEOUT: &str = "timeout";
/// Router refusal: every replica of a shard the query needs is
/// unreachable or refusing. Retryable — replicas restart and probes
/// reopen the route — so it always ships with `retry_after_ms`.
pub const CODE_SHARD_DOWN: &str = "shard_down";
/// Success-with-flag marker on scatter-gather responses that are missing
/// at least one shard's contribution: `ok` stays `true`, `code` is set to
/// this, and a `degraded` array names the missing shards. Never retried
/// by [`super::client::RetryClient`] (it is not a refusal).
pub const CODE_PARTIAL: &str = "partial";

/// Retry hint attached to `shard_down` refusals: shard restarts plus a
/// probe round-trip are sub-second, so point clients a beat out.
pub const SHARD_DOWN_RETRY_MS: u64 = 250;

/// A validated compute request (the wire fields of a lane-bound line).
#[derive(Clone, Debug)]
pub struct Request {
    /// Echoed verbatim in the response (`null` when absent).
    pub id: Json,
    pub op: Op,
    /// Parsed `timeout_ms` (`None` when absent).
    pub timeout: Option<Duration>,
    /// Explicit `client_id` admission key (`None` = fall back to peer).
    pub client_id: Option<String>,
    pub priority: u8,
    /// Opt-out of the ingress response cache for this request: neither
    /// answered from it nor stored into it (dedup still applies — it is
    /// an in-flight concern, not a staleness one). Ignored when the
    /// server runs without an ingress.
    pub no_cache: bool,
    pub vector: Vec<f32>,
}

/// What one request line parsed to.
pub enum ParsedLine {
    /// A well-formed compute request bound for a lane.
    Compute(Request),
    /// Valid JSON whose `op` is not a lane op — introspection
    /// (`metrics` / `health` / `metrics_text`), fleet ops (`lsh_query`),
    /// or an unknown op the serving layer must refuse. `op` is `None`
    /// when the field is absent or not a string.
    Other {
        id: Json,
        op: Option<String>,
        doc: Json,
    },
    /// Malformed line; carries the ready-to-send `bad_request` refusal.
    Malformed(Json),
}

/// Parse + validate one request line (pure function, no I/O). Validation
/// order and error strings are part of the wire contract (pinned by the
/// round-trip tests): bad JSON, then per-field checks in `timeout_ms`,
/// `client_id`, `priority`, `no_cache`, `vector` order.
pub fn parse_line(line: &str) -> ParsedLine {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => {
            return ParsedLine::Malformed(err_response(
                Json::Null,
                &format!("bad json: {e}"),
                CODE_BAD_REQUEST,
            ))
        }
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    let op_str = doc.get("op").and_then(|o| o.as_str());
    let Some(op) = op_str.and_then(Op::parse) else {
        let op = op_str.map(str::to_string);
        return ParsedLine::Other { id, op, doc };
    };
    let timeout = match doc.get("timeout_ms") {
        None => None,
        Some(t) => match t.as_f64() {
            Some(ms) if ms.is_finite() && ms >= 0.0 => Some(Duration::from_millis(ms as u64)),
            _ => {
                return ParsedLine::Malformed(err_response(
                    id,
                    "'timeout_ms' must be a non-negative number",
                    CODE_BAD_REQUEST,
                ))
            }
        },
    };
    // admission key: explicit client_id wins, else the caller's peer; a
    // present-but-non-string client_id is a malformed request, not a
    // silent fallback (same strictness as timeout_ms)
    let client_id = match doc.get("client_id") {
        None => None,
        Some(c) => match c.as_str() {
            Some(s) => Some(s.to_string()),
            None => {
                return ParsedLine::Malformed(err_response(
                    id,
                    "'client_id' must be a string",
                    CODE_BAD_REQUEST,
                ))
            }
        },
    };
    let priority = match doc.get("priority") {
        None => admission::PRIORITY_NORMAL,
        Some(p) => match p.as_f64() {
            Some(v) if v.is_finite() && v >= 0.0 && v <= 255.0 && v.fract() == 0.0 => v as u8,
            _ => {
                return ParsedLine::Malformed(err_response(
                    id,
                    "'priority' must be an integer 0-255",
                    CODE_BAD_REQUEST,
                ))
            }
        },
    };
    // cache opt-out: strict like every other optional field — a
    // present-but-non-bool value is a malformed request
    let no_cache = match doc.get("no_cache") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => {
            return ParsedLine::Malformed(err_response(
                id,
                "'no_cache' must be a boolean",
                CODE_BAD_REQUEST,
            ))
        }
    };
    let Some(vec_json) = doc.get("vector").and_then(|v| v.as_arr()) else {
        return ParsedLine::Malformed(err_response(id, "missing 'vector' array", CODE_BAD_REQUEST));
    };
    let mut vector = Vec::with_capacity(vec_json.len());
    for v in vec_json {
        match v.as_f64() {
            Some(f) => vector.push(f as f32),
            None => {
                return ParsedLine::Malformed(err_response(
                    id,
                    "'vector' must contain numbers",
                    CODE_BAD_REQUEST,
                ))
            }
        }
    }
    ParsedLine::Compute(Request {
        id,
        op,
        timeout,
        client_id,
        priority,
        no_cache,
        vector,
    })
}

/// Render a success response for a lane output. `transform`/`rff` results
/// are f32 arrays, `crosspolytope` a one-element id array, and
/// `binary_embed` ships each packed `u64` sign word as a fixed-width
/// 16-digit lowercase hex string.
pub fn ok_response(id: Json, out: Output) -> Json {
    let result = match out {
        Output::F32(v) => Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect()),
        Output::I32(v) => Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect()),
        // packed sign words as fixed-width hex: exact (a u64 does not
        // round-trip through a JSON f64) and compact on the wire
        Output::Bits(v) => Json::Arr(v.into_iter().map(|w| Json::Str(word_to_hex(w))).collect()),
    };
    ok_response_json(id, result)
}

/// Success response around an already-rendered `result` value.
pub fn ok_response_json(id: Json, result: Json) -> Json {
    Json::obj(vec![("id", id), ("ok", Json::Bool(true)), ("result", result)])
}

/// Partial-success response: `ok` stays `true` (there *is* a result), but
/// `code` is [`CODE_PARTIAL`] and `degraded` names the shards whose
/// contribution is missing — degradation is always marked, never silent.
pub fn partial_response(id: Json, result: Json, degraded: Vec<String>) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(true)),
        ("result", result),
        ("code", Json::Str(CODE_PARTIAL.to_string())),
        (
            "degraded",
            Json::Arr(degraded.into_iter().map(Json::Str).collect()),
        ),
    ])
}

/// One packed word as 16 lowercase hex digits (most significant first).
pub fn word_to_hex(w: u64) -> String {
    format!("{w:016x}")
}

/// Parse a response-side hex word (the client-side decoder; also used by
/// the serving smoke test). Strict: exactly 16 hex digits — no sign
/// prefix (`from_str_radix` alone would accept `+` + 15 digits).
pub fn hex_to_word(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Render `lsh_query` result pairs as a flat interleaved number array
/// `[id0, dist0, id1, dist1, ...]` — ids are global point ids, distances
/// Hamming distances (both exact in a JSON f64: ids are u32, distances at
/// most the code width).
pub fn lsh_ok_response(id: Json, pairs: &[(u32, u64)]) -> Json {
    ok_response_json(id, lsh_result(pairs))
}

/// Just the flat pair array (the router's partial-result path wraps it in
/// a [`partial_response`] instead of a plain success).
pub fn lsh_result(pairs: &[(u32, u64)]) -> Json {
    let mut flat = Vec::with_capacity(pairs.len() * 2);
    for (pid, d) in pairs {
        flat.push(Json::Num(*pid as f64));
        flat.push(Json::Num(*d as f64));
    }
    Json::Arr(flat)
}

/// Decode an `lsh_query` result array back to `(id, distance)` pairs —
/// the router's scatter-gather merge and any client-side consumer share
/// this. `None` when the value is not a well-formed flat pair array.
pub fn lsh_pairs(result: &Json) -> Option<Vec<(u32, u64)>> {
    let flat = result.as_arr()?;
    if flat.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(flat.len() / 2);
    for pair in flat.chunks(2) {
        let id = pair[0].as_f64()?;
        let d = pair[1].as_f64()?;
        if id < 0.0 || id.fract() != 0.0 || d < 0.0 || d.fract() != 0.0 {
            return None;
        }
        out.push((id as u32, d as u64));
    }
    Some(out)
}

/// Error response without a retry hint.
pub fn err_response(id: Json, msg: &str, code: &str) -> Json {
    err_response_with_hint(id, msg, code, None)
}

/// Error response that attaches `retry_after_ms` when the taxonomy marks
/// the code retryable — the server-side half of the retry-client
/// contract (clients treat a missing hint as "do not bother retrying").
pub fn err_response_with_hint(id: Json, msg: &str, code: &str, retry_after_ms: Option<u64>) -> Json {
    let mut fields = vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
        ("code", Json::Str(code.to_string())),
    ];
    if let Some(ms) = retry_after_ms {
        fields.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RequestError, SubmitError};

    // ---- byte-identical round trips against the pre-split wire format ----
    //
    // The golden strings below are the exact lines the pre-split
    // `server.rs` emitted (Json::Obj is a BTreeMap, so key order is
    // stable alphabetical). If the codec carve-out changed a single byte
    // of the protocol, these pins would catch it.

    #[test]
    fn ok_responses_render_byte_identically_per_output_kind() {
        let f = ok_response(Json::Num(7.0), Output::F32(vec![1.0, -0.5]));
        assert_eq!(f.to_string(), r#"{"id":7,"ok":true,"result":[1,-0.5]}"#);
        let i = ok_response(Json::Num(8.0), Output::I32(vec![42]));
        assert_eq!(i.to_string(), r#"{"id":8,"ok":true,"result":[42]}"#);
        let b = ok_response(
            Json::Num(9.0),
            Output::Bits(vec![0xdead_beef_0123_4567, 1, u64::MAX]),
        );
        assert_eq!(
            b.to_string(),
            r#"{"id":9,"ok":true,"result":["deadbeef01234567","0000000000000001","ffffffffffffffff"]}"#
        );
        // id is echoed verbatim, whatever JSON value the client sent
        let s = ok_response(Json::Str("abc".into()), Output::I32(vec![0]));
        assert_eq!(s.to_string(), r#"{"id":"abc","ok":true,"result":[0]}"#);
    }

    #[test]
    fn every_error_code_renders_byte_identically() {
        // refusals: every SubmitError, with its hint exactly when the
        // taxonomy marks it retryable (the pre-split behavior of
        // err_response_with_hint(e.to_string(), e.code(), e.retry_after_ms()))
        let submit = [
            SubmitError::Busy,
            SubmitError::UnknownLane,
            SubmitError::BadDim,
            SubmitError::Closed,
            SubmitError::LaneDown,
            SubmitError::Unavailable,
            SubmitError::Throttled { retry_after_ms: 7 },
            SubmitError::Overloaded { retry_after_ms: 9 },
            SubmitError::Draining { retry_after_ms: 500 },
        ];
        let golden = [
            r#"{"code":"busy","error":"lane queue full","id":1,"ok":false,"retry_after_ms":25}"#,
            r#"{"code":"unknown_lane","error":"no lane for (op, dim)","id":1,"ok":false}"#,
            r#"{"code":"bad_dim","error":"input dim mismatch","id":1,"ok":false}"#,
            r#"{"code":"closed","error":"coordinator closed","id":1,"ok":false}"#,
            r#"{"code":"lane_down","error":"lane down (restarting)","id":1,"ok":false,"retry_after_ms":100}"#,
            r#"{"code":"unavailable","error":"lane unavailable (circuit open)","id":1,"ok":false,"retry_after_ms":100}"#,
            r#"{"code":"throttled","error":"client work budget exhausted","id":1,"ok":false,"retry_after_ms":7}"#,
            r#"{"code":"overloaded","error":"lane overloaded (shedding)","id":1,"ok":false,"retry_after_ms":9}"#,
            r#"{"code":"draining","error":"server draining for shutdown","id":1,"ok":false,"retry_after_ms":500}"#,
        ];
        for (e, want) in submit.iter().zip(golden) {
            let r = err_response_with_hint(
                Json::Num(1.0),
                &e.to_string(),
                e.code(),
                e.retry_after_ms(),
            );
            assert_eq!(r.to_string(), want, "{e:?}");
        }
        // terminal request errors: no hint, ever
        let request = [
            RequestError::Deadline,
            RequestError::Panic("boom".into()),
            RequestError::Backend("injected failure".into()),
        ];
        let golden = [
            r#"{"code":"deadline","error":"deadline exceeded","id":2,"ok":false}"#,
            r#"{"code":"panic","error":"backend panicked: boom","id":2,"ok":false}"#,
            r#"{"code":"backend","error":"injected failure","id":2,"ok":false}"#,
        ];
        for (e, want) in request.iter().zip(golden) {
            let r = err_response(Json::Num(2.0), &e.to_string(), e.code());
            assert_eq!(r.to_string(), want, "{e:?}");
        }
        // server/codec-side codes
        let r = err_response(Json::Null, "bad json: oops", CODE_BAD_REQUEST);
        assert_eq!(
            r.to_string(),
            r#"{"code":"bad_request","error":"bad json: oops","id":null,"ok":false}"#
        );
        let r = err_response(Json::Num(3.0), "response timed out", CODE_TIMEOUT);
        assert_eq!(
            r.to_string(),
            r#"{"code":"timeout","error":"response timed out","id":3,"ok":false}"#
        );
        let r = err_response_with_hint(
            Json::Num(4.0),
            "all replicas of shard s1 unreachable",
            CODE_SHARD_DOWN,
            Some(SHARD_DOWN_RETRY_MS),
        );
        assert_eq!(
            r.to_string(),
            r#"{"code":"shard_down","error":"all replicas of shard s1 unreachable","id":4,"ok":false,"retry_after_ms":250}"#
        );
    }

    #[test]
    fn partial_responses_are_marked_never_silent() {
        let r = partial_response(
            Json::Num(5.0),
            Json::Arr(vec![Json::Num(3.0), Json::Num(1.0)]),
            vec!["s2".into()],
        );
        assert_eq!(
            r.to_string(),
            r#"{"code":"partial","degraded":["s2"],"id":5,"ok":true,"result":[3,1]}"#
        );
        // a partial is a success on the wire: ok stays true
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("code").unwrap().as_str(), Some(CODE_PARTIAL));
    }

    #[test]
    fn hex_word_round_trip() {
        for w in [0u64, 1, 0xdead_beef_0123_4567, u64::MAX] {
            assert_eq!(hex_to_word(&word_to_hex(w)), Some(w));
        }
        assert_eq!(hex_to_word("xyz"), None);
        assert_eq!(hex_to_word("00"), None);
        // sign prefixes are 16 chars but not 16 hex digits
        assert_eq!(hex_to_word("+00000000000000f"), None);
        assert_eq!(hex_to_word("-00000000000000f"), None);
    }

    #[test]
    fn lsh_pairs_round_trip() {
        let pairs = vec![(0u32, 0u64), (917, 3), (u32::MAX, 4096)];
        let resp = lsh_ok_response(Json::Num(6.0), &pairs);
        assert_eq!(
            resp.to_string(),
            r#"{"id":6,"ok":true,"result":[0,0,917,3,4294967295,4096]}"#
        );
        assert_eq!(lsh_pairs(resp.get("result").unwrap()), Some(pairs));
        // malformed shapes are rejected, not mis-decoded
        assert_eq!(lsh_pairs(&Json::Arr(vec![Json::Num(1.0)])), None, "odd length");
        assert_eq!(
            lsh_pairs(&Json::Arr(vec![Json::Num(-1.0), Json::Num(0.0)])),
            None,
            "negative id"
        );
        assert_eq!(
            lsh_pairs(&Json::Arr(vec![Json::Num(1.5), Json::Num(0.0)])),
            None,
            "fractional id"
        );
        assert_eq!(lsh_pairs(&Json::Str("nope".into())), None);
    }

    #[test]
    fn parse_line_validates_every_op_and_every_field() {
        // every lane op parses to a Compute with the right fields
        for (op_str, op) in [
            ("transform", Op::Transform),
            ("rff", Op::Rff),
            ("crosspolytope", Op::CrossPolytope),
            ("binary_embed", Op::BinaryEmbed),
        ] {
            let line = format!(
                r#"{{"id":1,"op":"{op_str}","vector":[0.5,-1],"timeout_ms":50,"client_id":"c","priority":2}}"#
            );
            match parse_line(&line) {
                ParsedLine::Compute(req) => {
                    assert_eq!(req.op, op);
                    assert_eq!(req.vector, vec![0.5, -1.0]);
                    assert_eq!(req.timeout, Some(Duration::from_millis(50)));
                    assert_eq!(req.client_id.as_deref(), Some("c"));
                    assert_eq!(req.priority, 2);
                }
                _ => panic!("'{op_str}' must parse as a compute request"),
            }
        }
        // defaults: no timeout, peer-fallback client, normal priority,
        // cache participation on
        match parse_line(r#"{"op":"transform","vector":[1]}"#) {
            ParsedLine::Compute(req) => {
                assert_eq!(req.id, Json::Null);
                assert_eq!(req.timeout, None);
                assert_eq!(req.client_id, None);
                assert_eq!(req.priority, admission::PRIORITY_NORMAL);
                assert!(!req.no_cache);
            }
            _ => panic!("minimal request must parse"),
        }
        // explicit cache opt-out parses through
        match parse_line(r#"{"op":"transform","vector":[1],"no_cache":true}"#) {
            ParsedLine::Compute(req) => assert!(req.no_cache),
            _ => panic!("no_cache request must parse"),
        }
        // non-lane ops fall through to Other with the id preserved
        match parse_line(r#"{"id":9,"op":"metrics"}"#) {
            ParsedLine::Other { id, op, .. } => {
                assert_eq!(id.as_f64(), Some(9.0));
                assert_eq!(op.as_deref(), Some("metrics"));
            }
            _ => panic!("introspection ops are Other"),
        }
        // missing / non-string op: Other with op None
        match parse_line(r#"{"id":10,"vector":[1]}"#) {
            ParsedLine::Other { op, .. } => assert_eq!(op, None),
            _ => panic!("missing op is Other"),
        }
        // field validation refusals, byte-identical with the pre-split
        // server's messages
        let cases = [
            (
                r#"{"id":5,"op":"transform","vector":[1],"timeout_ms":-3}"#,
                r#"{"code":"bad_request","error":"'timeout_ms' must be a non-negative number","id":5,"ok":false}"#,
            ),
            (
                r#"{"id":7,"op":"transform","vector":[1],"client_id":9}"#,
                r#"{"code":"bad_request","error":"'client_id' must be a string","id":7,"ok":false}"#,
            ),
            (
                r#"{"id":8,"op":"transform","vector":[1],"priority":1.5}"#,
                r#"{"code":"bad_request","error":"'priority' must be an integer 0-255","id":8,"ok":false}"#,
            ),
            (
                r#"{"id":9,"op":"transform","vector":[1],"no_cache":"yes"}"#,
                r#"{"code":"bad_request","error":"'no_cache' must be a boolean","id":9,"ok":false}"#,
            ),
            (
                r#"{"id":3,"op":"transform"}"#,
                r#"{"code":"bad_request","error":"missing 'vector' array","id":3,"ok":false}"#,
            ),
            (
                r#"{"id":4,"op":"transform","vector":["x"]}"#,
                r#"{"code":"bad_request","error":"'vector' must contain numbers","id":4,"ok":false}"#,
            ),
        ];
        for (line, want) in cases {
            match parse_line(line) {
                ParsedLine::Malformed(reply) => assert_eq!(reply.to_string(), want, "{line}"),
                _ => panic!("{line} must be Malformed"),
            }
        }
        // unparseable JSON: id null refusal
        match parse_line("{nope") {
            ParsedLine::Malformed(reply) => {
                assert_eq!(reply.get("code").unwrap().as_str(), Some(CODE_BAD_REQUEST));
                assert_eq!(reply.get("id"), Some(&Json::Null));
            }
            _ => panic!("bad json must be Malformed"),
        }
    }
}
