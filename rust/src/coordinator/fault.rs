//! Deterministic fault injection for the serving stack.
//!
//! [`FaultInjectingBackend`] wraps any [`Backend`] and, per batch call,
//! draws from the repo's seeded [`Rng`] whether to delay, panic, or return
//! an error before delegating to the inner backend. The chaos suite and
//! the `serving_fault` bench sweep interpose it directly; the `serve` CLI
//! interposes it from the environment so a running server can be
//! chaos-tested without a rebuild:
//!
//! ```text
//! TS_FAULT=panic:0.1,err:0.05,delay_ms:3,seed:9 triplespin serve --tcp ...
//! ```
//!
//! Grammar: comma-separated `key:value` pairs, any subset, any order —
//! `panic:p` / `err:p` are probabilities in `[0, 1]`, `delay_ms:d` a
//! per-call sleep in milliseconds, `seed:s` the RNG seed (default
//! `0x5EED`). Unknown keys are rejected loudly (a typo'd fault plan that
//! silently injects nothing would invalidate a whole chaos run).
//!
//! The same plan also carries **transport** faults, applied not by this
//! wrapper but by [`super::TcpServer`] at the socket layer:
//! `conn_drop:p` (drop the connection instead of writing a reply),
//! `slow_read_ms:d` (stall before processing each request line),
//! `partial_write:p` (truncate a reply mid-line and drop the
//! connection), and the deterministic shard-kill window
//! `down_after_ms:t` / `down_for_ms:d` (from `t` after server start the
//! whole server plays dead — new connections dropped byteless, open ones
//! killed without a reply — for `d` ms; `down_for_ms` absent or `0`
//! means it never comes back). The window is what lets the chaos suite
//! kill one shard of a fleet mid-load and watch the router degrade and
//! recover on schedule. [`FaultPlan::has_backend_faults`] /
//! [`FaultPlan::has_net_faults`] split the two halves.
//!
//! Determinism: the decision stream is a pure function of the plan — one
//! `Mutex<Rng>` serializes draws, and all decisions for a call are drawn
//! *before* acting (so an injected panic can never poison the lock
//! mid-draw). Two backends built from equal plans inject the identical
//! fault sequence, which is what lets chaos tests assert exact recovery
//! scenarios instead of probabilistic ones.

use super::backend::Backend;
use crate::runtime::{Op, Output};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Parsed `TS_FAULT` plan. See the module docs for the grammar.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Probability a call panics (after any delay).
    pub panic_p: f64,
    /// Probability a call returns an injected backend error.
    pub err_p: f64,
    /// Sleep applied to every call (models a slow dependency).
    pub delay: Duration,
    /// Probability the server drops a connection instead of replying
    /// (transport fault, applied by `TcpServer`).
    pub conn_drop_p: f64,
    /// Server-side stall before processing each request line (transport
    /// fault, models a slow/congested network).
    pub slow_read: Duration,
    /// Probability a reply is truncated mid-line and the connection
    /// dropped (transport fault).
    pub partial_write_p: f64,
    /// Shard-kill window start: this long after server start, the server
    /// plays dead (transport fault; `None` = never).
    pub down_after: Option<Duration>,
    /// Shard-kill window length; `ZERO` = down forever once it starts.
    pub down_for: Duration,
    /// Seed for the decision stream.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            panic_p: 0.0,
            err_p: 0.0,
            delay: Duration::ZERO,
            conn_drop_p: 0.0,
            slow_read: Duration::ZERO,
            partial_write_p: 0.0,
            down_after: None,
            down_for: Duration::ZERO,
            seed: 0x5EED,
        }
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f64, String> {
    let p: f64 = v
        .trim()
        .parse()
        .map_err(|_| format!("TS_FAULT: '{key}:{v}' is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("TS_FAULT: '{key}:{v}' must be in [0, 1]"));
    }
    Ok(p)
}

impl FaultPlan {
    /// Parse a plan string like `"panic:0.1,err:0.05,delay_ms:3,seed:9"`.
    /// Empty string (or only separators) parses to the no-op plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once(':')
                .ok_or_else(|| format!("TS_FAULT: '{part}' is not key:value"))?;
            match k.trim() {
                "panic" => plan.panic_p = parse_prob("panic", v)?,
                "err" => plan.err_p = parse_prob("err", v)?,
                "delay_ms" => {
                    let ms: u64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("TS_FAULT: 'delay_ms:{v}' is not an integer"))?;
                    plan.delay = Duration::from_millis(ms);
                }
                "conn_drop" => plan.conn_drop_p = parse_prob("conn_drop", v)?,
                "slow_read_ms" => {
                    let ms: u64 = v.trim().parse().map_err(|_| {
                        format!("TS_FAULT: 'slow_read_ms:{v}' is not an integer")
                    })?;
                    plan.slow_read = Duration::from_millis(ms);
                }
                "partial_write" => plan.partial_write_p = parse_prob("partial_write", v)?,
                "down_after_ms" => {
                    let ms: u64 = v.trim().parse().map_err(|_| {
                        format!("TS_FAULT: 'down_after_ms:{v}' is not an integer")
                    })?;
                    plan.down_after = Some(Duration::from_millis(ms));
                }
                "down_for_ms" => {
                    let ms: u64 = v.trim().parse().map_err(|_| {
                        format!("TS_FAULT: 'down_for_ms:{v}' is not an integer")
                    })?;
                    plan.down_for = Duration::from_millis(ms);
                }
                "seed" => {
                    plan.seed = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("TS_FAULT: 'seed:{v}' is not an integer"))?;
                }
                other => {
                    return Err(format!(
                        "TS_FAULT: unknown key '{other}' (expected panic|err|delay_ms|\
                         conn_drop|slow_read_ms|partial_write|down_after_ms|down_for_ms|seed)"
                    ))
                }
            }
        }
        if plan.down_after.is_none() && !plan.down_for.is_zero() {
            return Err(
                "TS_FAULT: 'down_for_ms' needs 'down_after_ms' to anchor the window".to_string(),
            );
        }
        Ok(plan)
    }

    /// Read the plan from `TS_FAULT`. `Ok(None)` when unset/empty,
    /// `Err` on a malformed value (never silently ignored).
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("TS_FAULT") {
            Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s).map(Some),
            _ => Ok(None),
        }
    }

    /// A plan that injects nothing (wrapping with it is pointless).
    pub fn is_noop(&self) -> bool {
        !self.has_backend_faults() && !self.has_net_faults()
    }

    /// Any backend-layer fault set (what [`FaultInjectingBackend`] applies)?
    pub fn has_backend_faults(&self) -> bool {
        self.panic_p > 0.0 || self.err_p > 0.0 || !self.delay.is_zero()
    }

    /// Any transport-layer fault set (what `TcpServer` applies)?
    pub fn has_net_faults(&self) -> bool {
        self.conn_drop_p > 0.0
            || self.partial_write_p > 0.0
            || !self.slow_read.is_zero()
            || self.down_after.is_some()
    }
}

/// [`Backend`] wrapper injecting faults per [`FaultPlan`] (module docs).
pub struct FaultInjectingBackend {
    inner: Arc<dyn Backend>,
    plan: FaultPlan,
    rng: Mutex<Rng>,
    /// Calls that panicked by injection (not inner-backend panics).
    pub injected_panics: AtomicU64,
    /// Calls that returned an injected error.
    pub injected_errors: AtomicU64,
    /// Total calls seen (delayed or not).
    pub calls: AtomicU64,
}

impl FaultInjectingBackend {
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> FaultInjectingBackend {
        FaultInjectingBackend {
            inner,
            plan,
            rng: Mutex::new(Rng::new(plan.seed)),
            injected_panics: AtomicU64::new(0),
            injected_errors: AtomicU64::new(0),
            calls: AtomicU64::new(0),
        }
    }

    /// Wrap `inner` per `TS_FAULT`, returning it untouched when the env
    /// var is unset or carries no *backend* faults (transport-only plans
    /// belong to `TcpServer`, not the backend). `Err` on a malformed plan.
    pub fn wrap_env(inner: Arc<dyn Backend>) -> Result<Arc<dyn Backend>, String> {
        match FaultPlan::from_env()? {
            Some(plan) if plan.has_backend_faults() => {
                Ok(Arc::new(FaultInjectingBackend::new(inner, plan)))
            }
            _ => Ok(inner),
        }
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }
}

impl Backend for FaultInjectingBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        // ORDERING: Relaxed — injector observability counter, read only by
        // test assertions after the threads under test are joined.
        self.calls.fetch_add(1, Ordering::Relaxed);
        // Draw every decision for this call under the lock, then release it
        // BEFORE acting: an injected panic while holding the lock would
        // poison it and turn one fault into a permanently broken injector.
        let (do_panic, do_err) = {
            let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
            (
                self.plan.panic_p > 0.0 && rng.uniform() < self.plan.panic_p,
                self.plan.err_p > 0.0 && rng.uniform() < self.plan.err_p,
            )
        };
        if !self.plan.delay.is_zero() {
            std::thread::sleep(self.plan.delay);
        }
        if do_panic {
            // ORDERING: Relaxed — observability counter (see `calls` above).
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
            panic!("injected fault: backend panic");
        }
        if do_err {
            // ORDERING: Relaxed — observability counter (see `calls` above).
            self.injected_errors.fetch_add(1, Ordering::Relaxed);
            return Err("injected fault: backend error".into());
        }
        self.inner.run_batch(op, n, rows, xs)
    }

    fn out_elems(&self, op: Op, n: usize) -> usize {
        self.inner.out_elems(op, n)
    }

    fn name(&self) -> &'static str {
        "fault"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeBackend;

    #[test]
    fn grammar_round_trips() {
        let p = FaultPlan::parse("panic:0.1,err:0.05,delay_ms:3,seed:9").unwrap();
        assert_eq!(p.panic_p, 0.1);
        assert_eq!(p.err_p, 0.05);
        assert_eq!(p.delay, Duration::from_millis(3));
        assert_eq!(p.seed, 9);
        assert!(!p.is_noop());
        // subsets, whitespace, trailing separators
        let p = FaultPlan::parse(" err:1 , seed:4 ,").unwrap();
        assert_eq!(p.err_p, 1.0);
        assert_eq!(p.seed, 4);
        assert_eq!(p.panic_p, 0.0);
        assert!(FaultPlan::parse("").unwrap().is_noop());
    }

    #[test]
    fn grammar_rejects_garbage_loudly() {
        assert!(FaultPlan::parse("panic").is_err(), "missing value");
        assert!(FaultPlan::parse("panic:1.5").is_err(), "prob out of range");
        assert!(FaultPlan::parse("panic:x").is_err(), "not a number");
        assert!(FaultPlan::parse("delay_ms:1.5").is_err(), "fractional ms");
        assert!(FaultPlan::parse("oops:1").is_err(), "unknown key");
        assert!(FaultPlan::parse("conn_drop:2").is_err(), "prob out of range");
        assert!(FaultPlan::parse("slow_read_ms:x").is_err(), "not an integer");
        assert!(FaultPlan::parse("partial_write:-1").is_err(), "negative prob");
        assert!(FaultPlan::parse("down_after_ms:1.5").is_err(), "fractional ms");
        assert!(
            FaultPlan::parse("down_for_ms:100").is_err(),
            "a window length without a start is a typo, not a plan"
        );
    }

    #[test]
    fn shard_kill_window_parses_as_a_net_fault() {
        let p = FaultPlan::parse("down_after_ms:50,down_for_ms:200").unwrap();
        assert_eq!(p.down_after, Some(Duration::from_millis(50)));
        assert_eq!(p.down_for, Duration::from_millis(200));
        assert!(p.has_net_faults() && !p.has_backend_faults());
        assert!(!p.is_noop());
        // down_for absent = the shard never comes back
        let forever = FaultPlan::parse("down_after_ms:10").unwrap();
        assert_eq!(forever.down_for, Duration::ZERO);
        assert!(forever.has_net_faults());
    }

    #[test]
    fn transport_keys_parse_and_split_from_backend_faults() {
        let p = FaultPlan::parse("conn_drop:0.25,slow_read_ms:2,partial_write:0.1").unwrap();
        assert_eq!(p.conn_drop_p, 0.25);
        assert_eq!(p.slow_read, Duration::from_millis(2));
        assert_eq!(p.partial_write_p, 0.1);
        assert!(p.has_net_faults() && !p.has_backend_faults());
        assert!(!p.is_noop(), "transport-only plans are not no-ops");
        let b = FaultPlan::parse("panic:0.1").unwrap();
        assert!(b.has_backend_faults() && !b.has_net_faults());
        // a transport-only plan must NOT wrap the backend — those faults
        // are the TcpServer's to apply
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 7));
        let fb = FaultInjectingBackend::new(Arc::clone(&inner), p);
        assert!(
            fb.run_batch(Op::Transform, 64, 1, &[1.0; 64]).is_ok(),
            "transport keys never fire at the backend layer"
        );
    }

    #[test]
    fn noop_plan_is_a_pure_passthrough() {
        let n = 64;
        let inner = Arc::new(NativeBackend::new(&[n], 1.0, 7));
        let direct = NativeBackend::new(&[n], 1.0, 7);
        let fb = FaultInjectingBackend::new(inner, FaultPlan::default());
        let mut rng = Rng::new(3);
        for _ in 0..5 {
            let x = rng.gaussian_vec(n);
            let got = fb.run_batch(Op::Transform, n, 1, &x).unwrap();
            let want = direct.run_batch(Op::Transform, n, 1, &x).unwrap();
            assert_eq!(got, want);
        }
        assert_eq!(fb.injected_panics.load(Ordering::Relaxed), 0);
        assert_eq!(fb.injected_errors.load(Ordering::Relaxed), 0);
        assert_eq!(fb.out_elems(Op::BinaryEmbed, n), n.div_ceil(64));
    }

    /// Run `calls` batches against a fresh injector, recording the
    /// per-call outcome (p = panicked, e = injected error, . = ok).
    fn outcome_trace(plan: FaultPlan, calls: usize) -> String {
        let n = 64;
        let inner = Arc::new(NativeBackend::new(&[n], 1.0, 7));
        let fb = FaultInjectingBackend::new(inner, plan);
        let x = vec![1.0f32; n];
        (0..calls)
            .map(|_| {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    fb.run_batch(Op::Transform, n, 1, &x)
                }));
                match r {
                    Err(_) => 'p',
                    Ok(Err(_)) => 'e',
                    Ok(Ok(_)) => '.',
                }
            })
            .collect()
    }

    #[test]
    fn fault_stream_is_deterministic_per_seed() {
        let plan = FaultPlan::parse("panic:0.3,err:0.3,seed:11").unwrap();
        let a = outcome_trace(plan, 60);
        let b = outcome_trace(plan, 60);
        assert_eq!(a, b, "same plan must inject the same fault sequence");
        assert!(a.contains('p') && a.contains('e') && a.contains('.'), "{a}");
        let other = FaultPlan::parse("panic:0.3,err:0.3,seed:12").unwrap();
        assert_ne!(a, outcome_trace(other, 60), "seed must steer the stream");
    }

    #[test]
    fn injector_survives_its_own_panics() {
        // drawing decisions before acting means a panic cannot poison the
        // RNG lock: the injector keeps working (deterministically) after.
        let plan = FaultPlan::parse("panic:1,seed:1").unwrap();
        let n = 64;
        let fb = FaultInjectingBackend::new(Arc::new(NativeBackend::new(&[n], 1.0, 7)), plan);
        let x = vec![1.0f32; n];
        for _ in 0..3 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                fb.run_batch(Op::Transform, n, 1, &x)
            }));
            assert!(r.is_err());
        }
        assert_eq!(fb.injected_panics.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn wrap_env_passthrough_when_unset() {
        // NOTE: relies on the test process not exporting TS_FAULT; the
        // chaos suite constructs plans directly to avoid env races.
        if std::env::var("TS_FAULT").is_ok() {
            return;
        }
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 7));
        let wrapped = FaultInjectingBackend::wrap_env(Arc::clone(&inner)).unwrap();
        assert_eq!(wrapped.name(), inner.name(), "no TS_FAULT: same backend");
    }
}
