//! Prometheus-style text exposition for serving metrics.
//!
//! Renders the coordinator's per-lane counters ([`crate::coordinator::
//! LaneMetrics`] via `metrics_json()`) and the router's fleet counters in
//! the standard `# TYPE`/`name{label="v"} value` text format, served over
//! the wire as the `metrics_text` op (the payload is one JSON string —
//! the codec's escaping keeps the multi-line exposition intact).
//!
//! The module carries its own [`parse`] so the format is round-trip
//! tested: anything [`render`] emits parses back to the same families,
//! which is what keeps the exposition grammatically valid for real
//! scrapers without taking a dependency on one.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One labeled measurement within a family.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Label pairs in render order (e.g. `[("lane", "transform_n64")]`).
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One metric family: a `# TYPE` header plus its samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Family {
    pub name: String,
    /// `"counter"` or `"gauge"`.
    pub kind: String,
    pub samples: Vec<Sample>,
}

/// Format a value the way the JSON layer does: integers render without a
/// fractional part, so counters stay clean and the text round-trips.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render families in the Prometheus text exposition format.
pub fn render(families: &[Family]) -> String {
    let mut out = String::new();
    for f in families {
        let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind);
        for s in &f.samples {
            if s.labels.is_empty() {
                let _ = writeln!(out, "{} {}", f.name, fmt_value(s.value));
            } else {
                let labels: Vec<String> = s
                    .labels
                    .iter()
                    .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
                    .collect();
                let _ = writeln!(out, "{}{{{}}} {}", f.name, labels.join(","), fmt_value(s.value));
            }
        }
    }
    out
}

/// Parse a text exposition back into families (the round-trip half; also
/// usable against any scraper-compatible source). Strict about what
/// [`render`] emits: every sample line must follow a `# TYPE` header for
/// its family, label values must be quoted, values must parse as f64.
pub fn parse(text: &str) -> Result<Vec<Family>, String> {
    let mut families: Vec<Family> = Vec::new();
    let mut index: BTreeMap<String, usize> = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .ok_or_else(|| format!("line {}: malformed TYPE header", ln + 1))?;
            if kind != "counter" && kind != "gauge" {
                return Err(format!("line {}: unknown metric kind '{kind}'", ln + 1));
            }
            if index.contains_key(name) {
                return Err(format!("line {}: duplicate family '{name}'", ln + 1));
            }
            index.insert(name.to_string(), families.len());
            families.push(Family {
                name: name.to_string(),
                kind: kind.to_string(),
                samples: Vec::new(),
            });
            continue;
        }
        if line.starts_with('#') {
            continue; // other comments (e.g. HELP) are legal noise
        }
        let (name, labels, value_str) = split_sample(line, ln + 1)?;
        let value: f64 = value_str
            .trim()
            .parse()
            .map_err(|_| format!("line {}: bad value '{value_str}'", ln + 1))?;
        let fi = *index
            .get(&name)
            .ok_or_else(|| format!("line {}: sample before TYPE for '{name}'", ln + 1))?;
        families[fi].samples.push(Sample { labels, value });
    }
    Ok(families)
}

/// Split one sample line into (name, labels, value text).
#[allow(clippy::type_complexity)]
fn split_sample(line: &str, ln: usize) -> Result<(String, Vec<(String, String)>, String), String> {
    let Some(brace) = line.find('{') else {
        // unlabeled: "name value"
        let (name, value) = line
            .split_once(' ')
            .ok_or_else(|| format!("line {ln}: malformed sample"))?;
        return Ok((name.to_string(), Vec::new(), value.to_string()));
    };
    let name = line[..brace].to_string();
    let mut labels = Vec::new();
    let chars: Vec<char> = line.chars().collect();
    let mut i = brace + 1;
    loop {
        if i >= chars.len() {
            return Err(format!("line {ln}: unterminated label block"));
        }
        if chars[i] == '}' {
            i += 1;
            break;
        }
        let key_start = i;
        while i < chars.len() && chars[i] != '=' {
            i += 1;
        }
        let key: String = chars[key_start..i].iter().collect();
        i += 1; // past '='
        if i >= chars.len() || chars[i] != '"' {
            return Err(format!("line {ln}: unquoted label value for '{key}'"));
        }
        i += 1; // past opening quote
        let mut value = String::new();
        while i < chars.len() && chars[i] != '"' {
            if chars[i] == '\\' && i + 1 < chars.len() {
                i += 1;
                match chars[i] {
                    'n' => value.push('\n'),
                    c => value.push(c),
                }
            } else {
                value.push(chars[i]);
            }
            i += 1;
        }
        if i >= chars.len() {
            return Err(format!("line {ln}: unterminated label value"));
        }
        i += 1; // past closing quote
        labels.push((key, value));
        if i < chars.len() && chars[i] == ',' {
            i += 1;
        }
    }
    let value: String = chars[i..].iter().collect();
    Ok((name, labels, value))
}

/// Is this per-lane metric key a point-in-time gauge (vs a monotonic
/// counter)? Latency summaries, means, in-flight depth, and the
/// response-cache occupancy move both ways.
fn is_gauge_key(key: &str) -> bool {
    key.starts_with("latency_")
        || key.starts_with("mean_")
        || key == "in_flight"
        || key == "cache_entries"
}

/// Convert a coordinator `metrics_json()` document into exposition
/// families: every per-lane numeric metric becomes `ts_lane_<key>{lane=
/// "<op>_n<dim>"}`, and the optional admission block becomes
/// `ts_admission_<key>` (process-wide, unlabeled). Generic over the keys
/// so new `LaneMetrics` counters show up without touching this module.
pub fn coordinator_families(metrics: &Json) -> Vec<Family> {
    let mut acc: BTreeMap<String, Family> = BTreeMap::new();
    let Some(top) = metrics.as_obj() else {
        return Vec::new();
    };
    for (lane, doc) in top {
        let Some(fields) = doc.as_obj() else { continue };
        for (key, value) in fields {
            let Some(v) = value.as_f64() else { continue };
            let (name, labels) = if lane == "admission" {
                (format!("ts_admission_{key}"), Vec::new())
            } else {
                (
                    format!("ts_lane_{key}"),
                    vec![("lane".to_string(), lane.clone())],
                )
            };
            let kind = if is_gauge_key(key) { "gauge" } else { "counter" };
            let fam = acc.entry(name.clone()).or_insert_with(|| Family {
                name,
                kind: kind.to_string(),
                samples: Vec::new(),
            });
            fam.samples.push(Sample { labels, value: v });
        }
    }
    acc.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Vec<Family> {
        vec![
            Family {
                name: "ts_lane_completed".into(),
                kind: "counter".into(),
                samples: vec![
                    Sample {
                        labels: vec![("lane".into(), "transform_n64".into())],
                        value: 41.0,
                    },
                    Sample {
                        labels: vec![("lane".into(), "binary_embed_n64".into())],
                        value: 7.0,
                    },
                ],
            },
            Family {
                name: "ts_lane_latency_p95_us".into(),
                kind: "gauge".into(),
                samples: vec![Sample {
                    labels: vec![("lane".into(), "transform_n64".into())],
                    value: 812.5,
                }],
            },
            Family {
                name: "ts_router_queries".into(),
                kind: "counter".into(),
                samples: vec![Sample {
                    labels: vec![],
                    value: 3.0,
                }],
            },
        ]
    }

    #[test]
    fn render_emits_type_headers_and_labeled_samples() {
        let text = render(&demo());
        let want = "# TYPE ts_lane_completed counter\n\
                    ts_lane_completed{lane=\"transform_n64\"} 41\n\
                    ts_lane_completed{lane=\"binary_embed_n64\"} 7\n\
                    # TYPE ts_lane_latency_p95_us gauge\n\
                    ts_lane_latency_p95_us{lane=\"transform_n64\"} 812.5\n\
                    # TYPE ts_router_queries counter\n\
                    ts_router_queries 3\n";
        assert_eq!(text, want);
    }

    #[test]
    fn format_round_trips() {
        let families = demo();
        let text = render(&families);
        let parsed = parse(&text).expect("rendered text must parse");
        assert_eq!(parsed, families);
        // and render is a fixed point of parse ∘ render
        assert_eq!(render(&parsed), text);
    }

    #[test]
    fn label_escaping_round_trips() {
        let families = vec![Family {
            name: "ts_shard_up".into(),
            kind: "gauge".into(),
            samples: vec![Sample {
                labels: vec![
                    ("shard".into(), "s\"quote\\slash\nline".into()),
                    ("addr".into(), "127.0.0.1:9".into()),
                ],
                value: 1.0,
            }],
        }];
        let text = render(&families);
        assert_eq!(parse(&text).unwrap(), families);
    }

    #[test]
    fn parse_rejects_malformed_expositions() {
        assert!(parse("# TYPE broken\n").is_err(), "headerless kind");
        assert!(parse("# TYPE m histogram\nm 1\n").is_err(), "unknown kind");
        assert!(parse("orphan 3\n").is_err(), "sample before TYPE");
        assert!(
            parse("# TYPE m counter\nm{x=\"unterminated} 1\n").is_err(),
            "unterminated label"
        );
        assert!(parse("# TYPE m counter\nm nope\n").is_err(), "bad value");
        assert!(
            parse("# TYPE m counter\n# TYPE m counter\n").is_err(),
            "duplicate family"
        );
        // HELP comments and blank lines are tolerated noise
        let ok = parse("# HELP m something\n\n# TYPE m counter\nm 1\n").unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn coordinator_families_map_lanes_and_admission() {
        let metrics = Json::obj(vec![
            (
                "transform_n64",
                Json::obj(vec![
                    ("completed", Json::Num(5.0)),
                    ("latency_p95_us", Json::Num(120.0)),
                    ("in_flight", Json::Num(1.0)),
                    ("cache_hits", Json::Num(3.0)),
                    ("cache_entries", Json::Num(2.0)),
                ]),
            ),
            (
                "admission",
                Json::obj(vec![("tokens", Json::Num(9.5))]),
            ),
        ]);
        let fams = coordinator_families(&metrics);
        let by_name: BTreeMap<&str, &Family> =
            fams.iter().map(|f| (f.name.as_str(), f)).collect();
        let completed = by_name["ts_lane_completed"];
        assert_eq!(completed.kind, "counter");
        assert_eq!(
            completed.samples[0].labels,
            vec![("lane".to_string(), "transform_n64".to_string())]
        );
        assert_eq!(by_name["ts_lane_latency_p95_us"].kind, "gauge");
        assert_eq!(by_name["ts_lane_in_flight"].kind, "gauge");
        // ingress counters flow through generically; occupancy is a gauge
        assert_eq!(by_name["ts_lane_cache_hits"].kind, "counter");
        assert_eq!(by_name["ts_lane_cache_entries"].kind, "gauge");
        let adm = by_name["ts_admission_tokens"];
        assert!(adm.samples[0].labels.is_empty());
        assert_eq!(adm.samples[0].value, 9.5);
        // the whole thing renders and round-trips
        let text = render(&fams);
        assert_eq!(parse(&text).unwrap(), fams);
    }
}
