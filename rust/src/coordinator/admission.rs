//! Overload protection for the serving front door: a per-client
//! token-bucket rate limiter denominated in **work units**, and a
//! CoDel-style adaptive queue-delay shedder.
//!
//! ## Cost model
//!
//! Admission tokens are not request counts — a 32-byte `binary_embed`
//! probe and a 4096-dim RFF matvec are wildly different amounts of work.
//! [`request_work`] mirrors the backend's `batch_work_per_row` estimate
//! (the same model the worker pool uses to decide sharding): the
//! butterfly chain costs `3·n·(log2(n)+1)` ops, and each op adds its
//! per-row epilogue (RFF's cos/sin expansion, the hash argmax, the sign
//! pack). One token == one estimated butterfly-op.
//!
//! ## Token bucket ([`AdmissionControl`])
//!
//! One bucket per client key (the wire `client_id`, falling back to the
//! peer address). Buckets refill at [`Config::admission_rate`] work
//! units/second up to a burst capacity; a request costing more than the
//! bucket holds is refused with [`SubmitError::Throttled`] carrying a
//! `retry_after_ms` hint computed from the refill rate — the client
//! knows exactly how long until the tokens exist. The client map is
//! bounded ([`MAX_TRACKED_CLIENTS`]): when full, the stalest bucket is
//! evicted, so an adversary cycling client ids costs O(1) memory.
//!
//! ## Queue-delay shedder ([`OverloadShedder`])
//!
//! Token buckets bound *per-client* rates but not aggregate overload.
//! The shedder watches each lane's admission→dequeue latency (the
//! signal CoDel uses: *sojourn time*, not queue length). When the delay
//! stays above [`Config::shed_target`] continuously for
//! [`Config::shed_window`], the lane starts shedding priority-0 work
//! with [`SubmitError::Overloaded`]; after a second window it sheds
//! priority ≤ 1 too. Priority-2 (interactive) work is never
//! shedder-shed — it still backpressures via `Busy` when the queue
//! fills. One observed dip below target resets the shedder instantly.
//!
//! [`SubmitError::Throttled`]: super::SubmitError::Throttled
//! [`SubmitError::Overloaded`]: super::SubmitError::Overloaded
//! [`Config::admission_rate`]: super::Config::admission_rate
//! [`Config::shed_target`]: super::Config::shed_target
//! [`Config::shed_window`]: super::Config::shed_window

use crate::runtime::Op;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Upper bound on distinct client buckets tracked at once; beyond this
/// the stalest bucket is evicted (memory stays O(1) under id churn).
pub const MAX_TRACKED_CLIENTS: usize = 1024;

/// Estimated work units for one request row of `(op, n)` — mirrors the
/// backend's `batch_work_per_row` model so admission and pool sharding
/// price work identically. The chain is `3·n·(log2(n)+1)` butterfly ops
/// (three HD blocks, each a Walsh–Hadamard pass plus the diagonal).
pub fn request_work(op: Op, n: usize) -> u64 {
    let n = n.max(2) as u64;
    let chain = 3 * n * (n.ilog2() as u64 + 1);
    match op {
        Op::Transform => chain,
        // cos/sin expansion to 2n outputs dominates the epilogue
        Op::Rff => chain + 16 * n,
        Op::CrossPolytope => chain + n,
        Op::BinaryEmbed => chain + n,
    }
}

/// One client's token bucket plus its lifetime admission counters.
struct Bucket {
    /// Current tokens (work units), ≤ burst.
    tokens: f64,
    /// Last refill instant (also the eviction staleness key).
    last: Instant,
    admitted: u64,
    throttled: u64,
}

/// Outcome of an admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    Granted,
    /// Refused; retry once the bucket has refilled (hint in ms).
    Throttled { retry_after_ms: u64 },
}

/// Per-client work-unit token buckets (see module docs).
pub struct AdmissionControl {
    /// Refill rate in work units per second per client.
    rate: f64,
    /// Bucket capacity (work units); buckets start full.
    burst: f64,
    clients: Mutex<HashMap<String, Bucket>>,
}

impl AdmissionControl {
    /// `rate` in work units/second; `burst` ≤ 0 defaults to one second
    /// of refill. Panics if `rate` is not finite and positive.
    pub fn new(rate: f64, burst: f64) -> AdmissionControl {
        assert!(
            rate.is_finite() && rate > 0.0,
            "admission rate must be finite and positive"
        );
        let burst = if burst > 0.0 { burst } else { rate };
        AdmissionControl {
            rate,
            burst,
            clients: Mutex::new(HashMap::new()),
        }
    }

    /// Charge `cost` work units against `client`'s bucket. A cost above
    /// the burst capacity is clamped to it, so one oversized request
    /// drains the full bucket instead of being unservable forever.
    pub fn check(&self, client: &str, cost: u64) -> Admit {
        let cost = (cost as f64).min(self.burst);
        let now = Instant::now();
        let mut map = self
            .clients
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        if !map.contains_key(client) && map.len() >= MAX_TRACKED_CLIENTS {
            // evict the stalest bucket (oldest refill instant)
            if let Some(stalest) = map
                .iter()
                .min_by_key(|(_, b)| b.last)
                .map(|(k, _)| k.clone())
            {
                map.remove(&stalest);
            }
        }
        let b = map.entry(client.to_string()).or_insert(Bucket {
            tokens: self.burst,
            last: now,
            admitted: 0,
            throttled: 0,
        });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * self.rate).min(self.burst);
        b.last = now;
        if b.tokens >= cost {
            b.tokens -= cost;
            b.admitted += 1;
            Admit::Granted
        } else {
            b.throttled += 1;
            let wait_s = (cost - b.tokens) / self.rate;
            Admit::Throttled {
                retry_after_ms: ((wait_s * 1000.0).ceil() as u64).max(1),
            }
        }
    }

    /// Per-client admission counters (sorted by client key) — exported
    /// under the `admission` key of the `metrics` wire op.
    pub fn to_json(&self) -> Json {
        let map = self
            .clients
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        Json::Obj(
            map.iter()
                .map(|(k, b)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("admitted", Json::Num(b.admitted as f64)),
                            ("throttled", Json::Num(b.throttled as f64)),
                            ("tokens", Json::Num(b.tokens)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

/// Priority of work the shedder drops first (the wire `priority` field;
/// anything above [`PRIORITY_HIGH`] is treated as high).
pub const PRIORITY_LOW: u8 = 0;
/// Default priority when the wire omits the field.
pub const PRIORITY_NORMAL: u8 = 1;
/// Never shedder-shed (still subject to `Busy` backpressure).
pub const PRIORITY_HIGH: u8 = 2;

/// CoDel-style per-lane queue-delay shedder (see module docs). All
/// state is atomics updated by the lane thread (`observe`) and read by
/// submitters (`should_shed`) — races cost at most one mis-shed
/// decision on a heuristic, never an invariant.
pub struct OverloadShedder {
    /// Sojourn-time target in µs; delays at or above it count as overload.
    target_us: u64,
    /// How long the delay must stay above target before shedding starts.
    window_us: u64,
    /// Epoch for encoding instants into the atomics.
    epoch: Instant,
    /// Microseconds-since-epoch when the delay first went above target;
    /// 0 = currently below target.
    above_since_us: AtomicU64,
    /// 0 = admit all; 1 = shed priority 0; 2 = shed priority ≤ 1.
    level: AtomicU8,
    /// Most recent observed queue delay (µs) — the retry hint basis.
    last_delay_us: AtomicU64,
}

impl OverloadShedder {
    /// A zero `target` disables the shedder entirely.
    pub fn new(target: Duration, window: Duration) -> OverloadShedder {
        OverloadShedder {
            target_us: target.as_micros() as u64,
            window_us: window.as_micros() as u64,
            epoch: Instant::now(),
            above_since_us: AtomicU64::new(0),
            level: AtomicU8::new(0),
            last_delay_us: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.target_us > 0
    }

    /// Current shed level (0 / 1 / 2) — exported for tests and metrics.
    pub fn level(&self) -> u8 {
        // ORDERING: Relaxed — single heuristic flag, no data guarded by it.
        self.level.load(Ordering::Relaxed)
    }

    /// Called by the lane thread for every dequeued job with its
    /// admission→dequeue sojourn time.
    pub fn observe(&self, delay: Duration) {
        if !self.enabled() {
            return;
        }
        let delay_us = delay.as_micros() as u64;
        // ORDERING: Relaxed throughout — the shedder is a heuristic
        // controller; readers tolerate stale values (one request mis-shed
        // or mis-admitted at a level transition), and no other memory is
        // published through these atomics.
        self.last_delay_us.store(delay_us, Ordering::Relaxed);
        if delay_us < self.target_us {
            // one good sojourn time resets the controller (CoDel's exit)
            self.above_since_us.store(0, Ordering::Relaxed);
            self.level.store(0, Ordering::Relaxed);
            return;
        }
        let now_us = (self.epoch.elapsed().as_micros() as u64).max(1);
        // ORDERING: Relaxed — heuristic controller state, see above.
        let since = self.above_since_us.load(Ordering::Relaxed);
        if since == 0 {
            // arm: first over-target observation starts the window clock
            // ORDERING: Relaxed — heuristic controller state, see above.
            self.above_since_us.store(now_us, Ordering::Relaxed);
            return;
        }
        let over_us = now_us.saturating_sub(since);
        let want = if over_us >= 2 * self.window_us {
            2
        } else if over_us >= self.window_us {
            1
        } else {
            0
        };
        // only escalate here; de-escalation is the sub-target reset above
        // ORDERING: Relaxed — heuristic controller state, see above.
        if want > self.level.load(Ordering::Relaxed) {
            self.level.store(want, Ordering::Relaxed);
        }
    }

    /// Should a submit at `priority` be shed right now? Returns the
    /// `retry_after_ms` hint when it should.
    pub fn should_shed(&self, priority: u8) -> Option<u64> {
        if !self.enabled() || priority >= PRIORITY_HIGH {
            return None;
        }
        // ORDERING: Relaxed — heuristic read, see `observe`.
        let level = self.level.load(Ordering::Relaxed);
        let shed = match level {
            0 => false,
            1 => priority == PRIORITY_LOW,
            _ => priority <= PRIORITY_NORMAL,
        };
        if !shed {
            return None;
        }
        // hint: the larger of the observed backlog delay and the target,
        // clamped to something a client can reasonably sleep
        // ORDERING: Relaxed — heuristic read, see `observe`.
        let delay_us = self.last_delay_us.load(Ordering::Relaxed);
        Some((delay_us.max(self.target_us) / 1000).clamp(1, 10_000))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_work_orders_ops_and_grows_with_n() {
        // chain-only transform is the floor; rff's expansion dominates
        assert!(request_work(Op::Transform, 64) < request_work(Op::CrossPolytope, 64));
        assert!(request_work(Op::CrossPolytope, 64) < request_work(Op::Rff, 64));
        assert_eq!(
            request_work(Op::CrossPolytope, 64),
            request_work(Op::BinaryEmbed, 64)
        );
        assert!(request_work(Op::Transform, 64) < request_work(Op::Transform, 4096));
        // exact chain model: 3·n·(log2(n)+1)
        assert_eq!(request_work(Op::Transform, 64), 3 * 64 * 7);
    }

    #[test]
    fn bucket_admits_until_drained_then_throttles_with_hint() {
        // 1k units/s, burst 100: one 60-unit request fits, the next does
        // not (tokens ≈ 40), and the hint says when the missing ~20
        // units will exist (≈20ms at 1k/s; generous upper bound below)
        let a = AdmissionControl::new(1000.0, 100.0);
        assert_eq!(a.check("alice", 60), Admit::Granted);
        match a.check("alice", 60) {
            Admit::Throttled { retry_after_ms } => {
                assert!(
                    (1..=100).contains(&retry_after_ms),
                    "hint {retry_after_ms}ms should approximate the refill gap"
                );
            }
            Admit::Granted => panic!("second 60-unit request must throttle"),
        }
        // an unrelated client has its own full bucket
        assert_eq!(a.check("bob", 60), Admit::Granted);
    }

    #[test]
    fn bucket_refills_over_time() {
        let a = AdmissionControl::new(10_000.0, 50.0);
        assert_eq!(a.check("c", 50), Admit::Granted);
        assert!(matches!(a.check("c", 50), Admit::Throttled { .. }));
        // 10k units/s refills the 50-unit burst in 5ms
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(a.check("c", 50), Admit::Granted);
    }

    #[test]
    fn oversized_cost_is_clamped_to_burst_not_starved() {
        let a = AdmissionControl::new(1000.0, 100.0);
        // cost 10× the burst still admits (drains the bucket fully)
        assert_eq!(a.check("big", 1000), Admit::Granted);
        assert!(matches!(a.check("big", 1), Admit::Throttled { .. }));
    }

    #[test]
    fn client_map_is_bounded_with_stalest_eviction() {
        let a = AdmissionControl::new(1000.0, 100.0);
        for i in 0..(MAX_TRACKED_CLIENTS + 50) {
            a.check(&format!("client-{i}"), 1);
        }
        let map = a.clients.lock().unwrap();
        assert!(map.len() <= MAX_TRACKED_CLIENTS, "map stays bounded");
    }

    #[test]
    fn admission_json_carries_per_client_counters() {
        let a = AdmissionControl::new(1000.0, 10.0);
        a.check("alice", 5);
        a.check("alice", 100);
        let j = a.to_json();
        let alice = j.get("alice").expect("client row");
        assert_eq!(alice.get("admitted").unwrap().as_f64(), Some(1.0));
        assert_eq!(alice.get("throttled").unwrap().as_f64(), Some(1.0));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn shedder_escalates_by_priority_and_resets_on_good_delay() {
        // window 0: a single above-target sojourn escalates straight to
        // level 2 on the next observation — deterministic for tests
        let s = OverloadShedder::new(Duration::from_micros(100), Duration::ZERO);
        assert!(s.should_shed(PRIORITY_LOW).is_none(), "starts cold");
        s.observe(Duration::from_millis(5)); // arms above_since
        s.observe(Duration::from_millis(5)); // over ≥ 2·window → level 2
        assert_eq!(s.level(), 2);
        assert!(s.should_shed(PRIORITY_LOW).is_some());
        let hint = s.should_shed(PRIORITY_NORMAL).expect("normal shed at L2");
        assert!(hint >= 1, "retry hint must be actionable");
        assert!(
            s.should_shed(PRIORITY_HIGH).is_none(),
            "priority-2 work is never shedder-shed"
        );
        // one sub-target sojourn resets everything
        s.observe(Duration::from_micros(10));
        assert_eq!(s.level(), 0);
        assert!(s.should_shed(PRIORITY_LOW).is_none());
    }

    #[test]
    fn disabled_shedder_never_sheds() {
        let s = OverloadShedder::new(Duration::ZERO, Duration::ZERO);
        assert!(!s.enabled());
        s.observe(Duration::from_secs(10));
        assert!(s.should_shed(PRIORITY_LOW).is_none());
    }
}
