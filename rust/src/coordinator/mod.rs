//! Layer-3 serving coordinator: request router → per-lane dynamic batcher →
//! backend execution (PJRT artifacts or native Rust), with bounded-queue
//! backpressure, per-lane metrics, and fault-isolated lanes.
//!
//! Topology: one ingress per lane (an `(op, n)` pair). [`Coordinator::submit`]
//! routes a request to its lane's bounded channel — a full channel rejects
//! with [`SubmitError::Busy`] (explicit load-shedding, never unbounded
//! memory). Each lane runs a thread that drains up to `max_batch` requests
//! (waiting at most `max_wait` after the first), answers any whose deadline
//! expired while queued, executes one backend call, and fans responses back
//! out on per-request channels. Backend batch execution shards over the
//! backend's **persistent** [`crate::runtime::WorkerPool`] — lane threads
//! never spawn per-batch workers, so steady-state serving touches a fixed
//! set of long-lived threads.
//!
//! ## Fault isolation
//!
//! Failure taxonomy, from cheapest to most severe:
//!
//! * **Backend error** — `run_batch` returns `Err`: every request in the
//!   batch gets [`RequestError::Backend`]; the lane keeps running.
//! * **Backend panic** — `run_batch` panics: caught with `catch_unwind`,
//!   and the batch is retried once as singletons so one poisoned input
//!   cannot fail its batchmates; only the request(s) that panic alone get
//!   [`RequestError::Panic`].
//! * **Deadline** — a request whose deadline passed while queued is
//!   answered with [`RequestError::Deadline`] *before* backend time is
//!   spent on it ([`Coordinator::submit_with_deadline`], or the per-lane
//!   [`Config::deadline`] default).
//! * **Circuit breaker** — [`Config::breaker_threshold`] consecutive
//!   backend failures flip the lane to `Degraded`: submits fail fast with
//!   [`SubmitError::Unavailable`] for [`Config::breaker_cooldown`], then
//!   half-open probes either close the breaker or re-arm it (see
//!   [`breaker`]).
//! * **Lane death** — a lane-fatal invariant violation (e.g. a backend
//!   returning a malformed batch shape) panics the lane thread. A
//!   supervisor catches it, counts it (`lane_failures`), fails submits
//!   fast with [`SubmitError::LaneDown`] meanwhile, and restarts the lane
//!   with bounded exponential backoff ([`Config::restart_backoff`] →
//!   [`Config::restart_backoff_max`], reset after a healthy run). Queued
//!   jobs survive the restart; only the batch in flight is lost (its
//!   callers observe a disconnected reply channel, surfaced by
//!   [`Coordinator::call_timeout`] as an error, never a hang).
//!
//! Fault *injection* for all of the above is [`fault::FaultInjectingBackend`]
//! (`TS_FAULT=panic:p,err:p,delay_ms:d,seed:s`, plus the transport keys
//! `conn_drop:p,slow_read_ms:d,partial_write:p` applied by [`TcpServer`]),
//! exercised by the chaos suite (`rust/tests/chaos_serving.rs`).
//!
//! ## Overload protection and lifecycle
//!
//! Ahead of the queues sits [`admission`]: a per-client work-unit token
//! bucket ([`SubmitError::Throttled`]) and a CoDel-style queue-delay
//! shedder ([`SubmitError::Overloaded`]) — both off by default
//! ([`Config::admission_rate`] / [`Config::shed_target`]) and both
//! carrying a `retry_after_ms` hint. [`Coordinator::begin_drain`] starts
//! graceful shutdown: new submits get [`SubmitError::Draining`],
//! [`Coordinator::drain`] waits for in-flight work under a deadline and
//! then answers anything still queued with a typed `Deadline` — queued
//! jobs are never silently dropped. [`client::RetryClient`] is the
//! matching caller: it retries exactly the retryable codes with full-
//! jitter backoff under a retry budget.
//!
//! Invariants (property-tested below and in `rust/tests/`):
//! * every accepted request receives exactly one terminal response (or,
//!   across a lane death, a visibly disconnected reply channel — never a
//!   silent hang);
//! * batch sizes never exceed `max_batch`;
//! * padding rows never leak into responses;
//! * routing is a pure function of `(op, dim)`;
//! * FIFO order within a lane (preserved by the singleton retry path).

pub mod admission;
pub mod backend;
pub mod batcher;
pub mod breaker;
pub mod client;
pub mod codec;
pub mod fault;
pub mod metrics;
pub mod prom;
pub mod server;

pub use admission::{AdmissionControl, OverloadShedder};
pub use backend::{Backend, ModelParams, NativeBackend, PjrtBackend};
pub use batcher::{Batcher, IngressOptions};
pub use breaker::{LaneState, Phase};
pub use client::{ClientError, RetryClient, RetryPolicy};
pub use fault::{FaultInjectingBackend, FaultPlan};
pub use metrics::LaneMetrics;
pub use server::{CoordinatorService, LineService, ServerOptions, TcpServer};

use crate::runtime::{Op, Output};
use crate::util::panic_message;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default request deadline for [`Coordinator::call`] — generous, so the
/// blocking convenience wrapper can never hang on a dead lane, but far
/// above any sane batch latency.
pub const DEFAULT_CALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Extra wait on the *response* channel beyond the request deadline: the
/// lane's own typed `Deadline` answer (sent when it pops the expired job)
/// should normally win the race against the caller's receive timeout.
pub const RESPONSE_GRACE: Duration = Duration::from_millis(250);

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Lanes to open: (op, input dim n). n must be a power of two.
    pub lanes: Vec<(Op, usize)>,
    /// Max requests per backend call.
    pub max_batch: usize,
    /// How long a lane waits to fill a batch after the first request.
    pub max_wait: Duration,
    /// Bounded ingress queue per lane (backpressure limit).
    pub queue_cap: usize,
    /// Gaussian-kernel bandwidth for the RFF op.
    pub sigma: f64,
    /// Model seed (both backends derive identical diagonals from it).
    pub seed: u64,
    /// Default per-request deadline applied at submit time (`None` = no
    /// deadline). [`Coordinator::submit_with_deadline`] overrides per call.
    pub deadline: Option<Duration>,
    /// Consecutive backend failures that open the lane's circuit breaker
    /// (`0` disables the breaker).
    pub breaker_threshold: u32,
    /// How long an open breaker sheds with [`SubmitError::Unavailable`]
    /// before admitting half-open probe traffic.
    pub breaker_cooldown: Duration,
    /// Initial supervisor backoff before restarting a dead lane thread.
    pub restart_backoff: Duration,
    /// Backoff ceiling (doubles up to this; a lane that ran healthy longer
    /// than this before dying restarts at `restart_backoff` again).
    pub restart_backoff_max: Duration,
    /// Per-client token-bucket refill rate in **work units**/second
    /// ([`admission::request_work`]); `0.0` disables admission control.
    pub admission_rate: f64,
    /// Token-bucket burst capacity in work units (`0.0` = one second of
    /// refill, i.e. `admission_rate`).
    pub admission_burst: f64,
    /// Queue-delay target for the overload shedder: sojourn times at or
    /// above this count as overload. `ZERO` disables the shedder.
    pub shed_target: Duration,
    /// How long the delay must stay above target before the shedder
    /// starts dropping priority-0 work (priority ≤ 1 after 2× window).
    pub shed_window: Duration,
    /// Cost-model flush bound: cap each coalesced batch so its estimated
    /// work ([`admission::request_work`] per row × rows) stays at or
    /// under this many work units — expensive rows flush in smaller
    /// batches instead of waiting on stragglers. `0` disables the cap
    /// (batches are bounded by [`Config::max_batch`] alone).
    pub flush_work: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            lanes: vec![
                (Op::Transform, 256),
                (Op::Rff, 256),
                (Op::CrossPolytope, 256),
                (Op::BinaryEmbed, 256),
            ],
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            sigma: 1.0,
            seed: 42,
            deadline: None,
            breaker_threshold: 8,
            breaker_cooldown: Duration::from_millis(250),
            restart_backoff: Duration::from_millis(10),
            restart_backoff_max: Duration::from_secs(2),
            admission_rate: 0.0,
            admission_burst: 0.0,
            shed_target: Duration::ZERO,
            shed_window: Duration::from_millis(100),
            flush_work: 0,
        }
    }
}

/// Typed per-request failure (the terminal error in a [`Response`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The request's deadline passed while it was queued; no backend time
    /// was spent on it.
    Deadline,
    /// The backend panicked executing this request (caught and isolated);
    /// carries the panic message.
    Panic(String),
    /// The backend returned an error; carries its message verbatim.
    Backend(String),
}

impl RequestError {
    /// Stable machine-readable tag (the wire protocol's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            RequestError::Deadline => "deadline",
            RequestError::Panic(_) => "panic",
            RequestError::Backend(_) => "backend",
        }
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Deadline => write!(f, "deadline exceeded"),
            RequestError::Panic(m) => write!(f, "backend panicked: {m}"),
            // backend messages pass through verbatim (pre-existing wire
            // contract: e.g. a bare "injected failure")
            RequestError::Backend(m) => write!(f, "{m}"),
        }
    }
}

/// A response: the per-request slice of the batch output, or a typed error.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<Output, RequestError>,
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The lane's queue is full — shed load and retry later.
    Busy,
    /// No lane for this (op, dim).
    UnknownLane,
    /// Input length != lane dim.
    BadDim,
    /// Coordinator is shutting down.
    Closed,
    /// The lane thread died; the supervisor is restarting it.
    LaneDown,
    /// The lane's circuit breaker is open (consecutive backend failures);
    /// fail fast instead of queueing doomed work.
    Unavailable,
    /// The client's work-unit token bucket is empty; retry after the
    /// hinted refill time.
    Throttled { retry_after_ms: u64 },
    /// The lane's queue-delay shedder tripped and this request's priority
    /// is being shed; retry after the hinted backlog time.
    Overloaded { retry_after_ms: u64 },
    /// The coordinator is draining for shutdown; retry against another
    /// replica after the hint.
    Draining { retry_after_ms: u64 },
}

impl SubmitError {
    /// Stable machine-readable tag (the wire protocol's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::Busy => "busy",
            SubmitError::UnknownLane => "unknown_lane",
            SubmitError::BadDim => "bad_dim",
            SubmitError::Closed => "closed",
            SubmitError::LaneDown => "lane_down",
            SubmitError::Unavailable => "unavailable",
            SubmitError::Throttled { .. } => "throttled",
            SubmitError::Overloaded { .. } => "overloaded",
            SubmitError::Draining { .. } => "draining",
        }
    }

    /// Retry hint in milliseconds for retryable refusals, `None` for
    /// errors a retry cannot fix (caller mistakes and `Closed`). This is
    /// the wire `retry_after_ms` field; [`client::RETRYABLE_CODES`] is
    /// the matching client-side contract.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            // queue-full and breaker/restart refusals clear quickly
            SubmitError::Busy => Some(25),
            SubmitError::LaneDown | SubmitError::Unavailable => Some(100),
            SubmitError::Throttled { retry_after_ms }
            | SubmitError::Overloaded { retry_after_ms }
            | SubmitError::Draining { retry_after_ms } => Some(*retry_after_ms),
            SubmitError::UnknownLane | SubmitError::BadDim | SubmitError::Closed => None,
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "lane queue full"),
            SubmitError::UnknownLane => write!(f, "no lane for (op, dim)"),
            SubmitError::BadDim => write!(f, "input dim mismatch"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::LaneDown => write!(f, "lane down (restarting)"),
            SubmitError::Unavailable => write!(f, "lane unavailable (circuit open)"),
            SubmitError::Throttled { .. } => write!(f, "client work budget exhausted"),
            SubmitError::Overloaded { .. } => write!(f, "lane overloaded (shedding)"),
            SubmitError::Draining { .. } => write!(f, "server draining for shutdown"),
        }
    }
}

/// Per-submit options beyond the vector itself (all optional; `default()`
/// reproduces [`Coordinator::submit`]'s behavior exactly).
#[derive(Clone, Copy, Debug)]
pub struct SubmitOptions<'a> {
    /// Per-request deadline (`None` falls back to [`Config::deadline`]).
    pub deadline: Option<Duration>,
    /// Admission-control key (the wire `client_id` / peer address);
    /// `None` charges the shared `"local"` bucket when admission is on.
    pub client: Option<&'a str>,
    /// Shedding priority (see [`admission::PRIORITY_LOW`] etc.): the
    /// shedder drops 0 first, then ≤ 1; ≥ 2 is never shedder-shed.
    pub priority: u8,
}

impl Default for SubmitOptions<'_> {
    fn default() -> Self {
        SubmitOptions {
            deadline: None,
            client: None,
            priority: admission::PRIORITY_NORMAL,
        }
    }
}

/// Retry hint attached to [`SubmitError::Draining`] refusals: drains are
/// seconds-scale, so point clients at a peer half a second out.
pub const DRAINING_RETRY_MS: u64 = 500;

struct Job {
    id: u64,
    vector: Vec<f32>,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
    /// Absolute expiry; the lane answers `Deadline` instead of executing
    /// once this passes.
    deadline: Option<Instant>,
}

struct Lane {
    tx: SyncSender<Job>,
    metrics: Arc<LaneMetrics>,
    state: Arc<LaneState>,
    shedder: Arc<OverloadShedder>,
    n: usize,
}

/// The running coordinator.
pub struct Coordinator {
    lanes: HashMap<(Op, usize), Lane>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    /// Per-client token buckets; `None` when admission control is off.
    admission: Option<AdmissionControl>,
    /// Set by [`Coordinator::begin_drain`]: new submits refuse with
    /// [`SubmitError::Draining`].
    draining: AtomicBool,
    /// Drain cutoff, shared with every lane: once set, lanes answer all
    /// queued jobs with `Deadline` instead of executing them.
    drain_cutoff: Arc<AtomicBool>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start one supervised batcher thread per lane over a shared backend.
    pub fn start(config: Config, backend: Arc<dyn Backend>) -> Coordinator {
        let mut lanes = HashMap::new();
        let mut joins = Vec::new();
        let drain_cutoff = Arc::new(AtomicBool::new(false));
        for (op, n) in &config.lanes {
            let (op, n) = (*op, *n);
            let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_cap);
            let metrics = Arc::new(LaneMetrics::new());
            let state = Arc::new(LaneState::new(
                config.breaker_threshold,
                config.breaker_cooldown,
            ));
            let shedder = Arc::new(OverloadShedder::new(
                config.shed_target,
                config.shed_window,
            ));
            let worker = LaneWorker {
                backend: Arc::clone(&backend),
                op,
                n,
                per: backend.out_elems(op, n),
                max_batch: config.max_batch,
                work_cap_rows: if config.flush_work > 0 {
                    ((config.flush_work / admission::request_work(op, n)).max(1)) as usize
                } else {
                    usize::MAX
                },
                max_wait: config.max_wait,
                metrics: Arc::clone(&metrics),
                state: Arc::clone(&state),
                shedder: Arc::clone(&shedder),
                drain_cutoff: Arc::clone(&drain_cutoff),
                backoff: config.restart_backoff,
                backoff_max: config.restart_backoff_max,
            };
            let join = std::thread::Builder::new()
                .name(format!("lane-{op}-{n}"))
                .spawn(move || worker.supervise(rx))
                .expect("spawn lane thread");
            joins.push(join);
            lanes.insert(
                (op, n),
                Lane {
                    tx,
                    metrics,
                    state,
                    shedder,
                    n,
                },
            );
        }
        Coordinator {
            lanes,
            next_id: AtomicU64::new(1),
            default_deadline: config.deadline,
            admission: (config.admission_rate > 0.0)
                .then(|| AdmissionControl::new(config.admission_rate, config.admission_burst)),
            draining: AtomicBool::new(false),
            drain_cutoff,
            joins,
        }
    }

    /// Submit a request with the lane's default deadline (if any). Returns
    /// the request id and a receiver for the response. Non-blocking: a
    /// full lane returns [`SubmitError::Busy`], a dead lane
    /// [`SubmitError::LaneDown`], an open breaker
    /// [`SubmitError::Unavailable`].
    pub fn submit(
        &self,
        op: Op,
        vector: Vec<f32>,
    ) -> Result<(u64, Receiver<Response>), SubmitError> {
        self.submit_with_deadline(op, vector, None)
    }

    /// [`Coordinator::submit`] with an explicit per-request deadline
    /// (`None` falls back to [`Config::deadline`]). The deadline is
    /// resolved to an absolute instant here, at admission.
    pub fn submit_with_deadline(
        &self,
        op: Op,
        vector: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<(u64, Receiver<Response>), SubmitError> {
        self.submit_with_opts(
            op,
            vector,
            SubmitOptions {
                deadline,
                ..SubmitOptions::default()
            },
        )
    }

    /// Full-control submit: deadline, admission client key, priority.
    /// Exactly [`Coordinator::admit`] followed by
    /// [`Coordinator::enqueue`] — the ingress batcher uses the two
    /// halves separately so dedup followers and cache hits still pay
    /// admission without enqueueing duplicate work.
    pub fn submit_with_opts(
        &self,
        op: Op,
        vector: Vec<f32>,
        opts: SubmitOptions<'_>,
    ) -> Result<(u64, Receiver<Response>), SubmitError> {
        self.admit(op, vector.len(), opts)?;
        self.enqueue(op, vector, opts.deadline)
    }

    /// Admission-only half of [`Coordinator::submit_with_opts`]: counts
    /// the submit and runs the full refusal chain without enqueueing any
    /// work. The refusal order is deliberate — drain beats everything
    /// (the instance is going away), lane health beats admission (don't
    /// charge tokens for doomed work), the token bucket beats the
    /// shedder (a throttled client shouldn't consume shedder headroom).
    /// The ingress batcher calls this for *every* request — leaders,
    /// dedup followers, and cache hits alike — so each client is charged
    /// its own work units and the refusal order matches the uncoalesced
    /// path exactly.
    pub fn admit(&self, op: Op, dim: usize, opts: SubmitOptions<'_>) -> Result<(), SubmitError> {
        let lane = self.lanes.get(&(op, dim)).ok_or(SubmitError::UnknownLane)?;
        if dim != lane.n {
            return Err(SubmitError::BadDim);
        }
        lane.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // ORDERING: Relaxed — one-way latch; the drain sequence does not
        // publish data through this flag, and a submit racing begin_drain
        // is equivalent to one arriving just before it.
        if self.draining.load(Ordering::Relaxed) {
            lane.metrics.drained.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Draining {
                retry_after_ms: DRAINING_RETRY_MS,
            });
        }
        match lane.state.phase() {
            Phase::Dead => return Err(SubmitError::LaneDown),
            Phase::Degraded if !lane.state.admit() => {
                lane.metrics.shed_unavailable.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Unavailable);
            }
            _ => {}
        }
        if let Some(ac) = &self.admission {
            let cost = admission::request_work(op, lane.n);
            let key = opts.client.unwrap_or("local");
            if let admission::Admit::Throttled { retry_after_ms } = ac.check(key, cost) {
                lane.metrics.throttled.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Throttled { retry_after_ms });
            }
        }
        if let Some(retry_after_ms) = lane.shedder.should_shed(opts.priority) {
            lane.metrics.shed_overloaded.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Overloaded { retry_after_ms });
        }
        Ok(())
    }

    /// Queueing half of [`Coordinator::submit_with_opts`]: assumes
    /// [`Coordinator::admit`] already accepted this request (it is not
    /// re-counted as a submit and pays no admission tokens here; only the
    /// queue itself can still refuse, with [`SubmitError::Busy`]).
    pub fn enqueue(
        &self,
        op: Op,
        vector: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<(u64, Receiver<Response>), SubmitError> {
        let lane = self
            .lanes
            .get(&(op, vector.len()))
            .ok_or(SubmitError::UnknownLane)?;
        // ORDERING: Relaxed — fetch_add's RMW atomicity alone guarantees
        // unique ids; ids never order other memory (responses are matched
        // by value over the reply channel, which synchronizes).
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let now = Instant::now();
        let job = Job {
            id,
            vector,
            reply,
            enqueued: now,
            deadline: deadline.or(self.default_deadline).map(|d| now + d),
        };
        // gauge up before try_send: the lane may dequeue (and decrement)
        // the instant the job lands, so the reverse order could underflow
        lane.metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        match lane.tx.try_send(job) {
            Ok(()) => Ok((id, rx)),
            Err(TrySendError::Full(_)) => {
                lane.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
                lane.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            // the receiver lives in the supervisor, which only exits on
            // clean shutdown — while the coordinator is alive a
            // disconnected lane means the supervisor itself died
            Err(TrySendError::Disconnected(_)) => {
                lane.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::LaneDown)
            }
        }
    }

    /// Metrics handle for one lane (`None` when the lane doesn't exist)
    /// — how the ingress batcher feeds its cache/dedup counters into the
    /// same per-lane document everything else reads.
    pub fn lane_metrics(&self, op: Op, n: usize) -> Option<Arc<LaneMetrics>> {
        self.lanes.get(&(op, n)).map(|l| Arc::clone(&l.metrics))
    }

    /// Submit and wait for the response (convenience for examples / CLI).
    /// Bounded by [`DEFAULT_CALL_TIMEOUT`] — never hangs, even across a
    /// lane death.
    pub fn call(&self, op: Op, vector: Vec<f32>) -> Result<Output, String> {
        self.call_timeout(op, vector, DEFAULT_CALL_TIMEOUT)
    }

    /// [`Coordinator::call`] with an explicit deadline: the request
    /// carries `timeout` as its deadline, and the response wait is bounded
    /// by `timeout + `[`RESPONSE_GRACE`] so the lane's typed `Deadline`
    /// answer normally arrives first.
    pub fn call_timeout(
        &self,
        op: Op,
        vector: Vec<f32>,
        timeout: Duration,
    ) -> Result<Output, String> {
        let (_, rx) = self
            .submit_with_deadline(op, vector, Some(timeout))
            .map_err(|e| e.to_string())?;
        match rx.recv_timeout(timeout.saturating_add(RESPONSE_GRACE)) {
            Ok(resp) => resp.result.map_err(|e| e.to_string()),
            Err(RecvTimeoutError::Timeout) => {
                Err(format!("response timed out after {timeout:?}"))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err("lane dropped response (restarted mid-request)".to_string())
            }
        }
    }

    /// Per-lane metrics handles.
    pub fn metrics(&self) -> Vec<((Op, usize), Arc<LaneMetrics>)> {
        let mut v: Vec<_> = self
            .lanes
            .iter()
            .map(|(k, l)| (*k, Arc::clone(&l.metrics)))
            .collect();
        v.sort_by_key(|((op, n), _)| (op.name(), *n));
        v
    }

    /// Refuse all new submits with [`SubmitError::Draining`] from now on.
    /// Idempotent; already-queued and in-flight work is unaffected (that
    /// is [`Coordinator::drain`]'s job).
    pub fn begin_drain(&self) {
        // ORDERING: Relaxed — one-way latch, see the submit-path load.
        self.draining.store(true, Ordering::Relaxed);
    }

    pub fn is_draining(&self) -> bool {
        // ORDERING: Relaxed — one-way latch, see the submit-path load.
        self.draining.load(Ordering::Relaxed)
    }

    /// Requests admitted but not yet given a terminal answer, summed over
    /// lanes (can overcount across lane deaths — see
    /// [`LaneMetrics::in_flight`]).
    pub fn pending(&self) -> u64 {
        self.lanes
            .values()
            .map(|l| l.metrics.in_flight.load(Ordering::Relaxed))
            .sum()
    }

    /// Graceful drain: [`Coordinator::begin_drain`], then wait up to
    /// `deadline` for in-flight work to finish naturally. If work
    /// remains at the deadline, flip the drain cutoff so lanes answer
    /// everything still queued with a typed `Deadline` (never a silent
    /// drop) and give them [`RESPONSE_GRACE`] to flush. Returns `true`
    /// if everything completed without the cutoff.
    pub fn drain(&self, deadline: Duration) -> bool {
        self.begin_drain();
        let until = Instant::now() + deadline;
        while self.pending() > 0 {
            if Instant::now() >= until {
                // ORDERING: Relaxed — one-way latch polled by lane loops;
                // the jobs it guards travel through the lane channel,
                // which synchronizes.
                self.drain_cutoff.store(true, Ordering::Relaxed);
                let grace = Instant::now() + RESPONSE_GRACE;
                while self.pending() > 0 && Instant::now() < grace {
                    std::thread::sleep(Duration::from_millis(1));
                }
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    /// Metrics as a JSON document. When admission control is on, the
    /// extra `admission` key carries per-client counters.
    pub fn metrics_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut map: std::collections::BTreeMap<String, Json> = self
            .metrics()
            .into_iter()
            .map(|((op, n), m)| (format!("{op}_n{n}"), m.to_json()))
            .collect();
        if let Some(ac) = &self.admission {
            map.insert("admission".to_string(), ac.to_json());
        }
        Json::Obj(map)
    }

    /// Per-lane health as a JSON document (the `health` wire op): current
    /// phase (`open` / `degraded` / `dead-restarting`) plus the
    /// supervision counters.
    pub fn health_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut map = std::collections::BTreeMap::new();
        map.insert("draining".to_string(), Json::Bool(self.is_draining()));
        map.extend(
            self.lanes
                .iter()
                .map(|((op, n), lane)| {
                    (
                        format!("{op}_n{n}"),
                        Json::obj(vec![
                            ("state", Json::Str(lane.state.phase().name().into())),
                            (
                                "consecutive_failures",
                                Json::Num(lane.state.consecutive_failures() as f64),
                            ),
                            (
                                "lane_failures",
                                Json::Num(
                                    lane.metrics.lane_failures.load(Ordering::Relaxed) as f64
                                ),
                            ),
                            (
                                "restarts",
                                Json::Num(lane.metrics.restarts.load(Ordering::Relaxed) as f64),
                            ),
                            (
                                "cache_entries",
                                Json::Num(
                                    lane.metrics.cache_entries.load(Ordering::Relaxed) as f64
                                ),
                            ),
                        ]),
                    )
                }),
        );
        Json::Obj(map)
    }

    /// Stop accepting requests, drain lanes, join threads.
    pub fn shutdown(mut self) {
        // dropping the senders closes the lanes
        self.lanes.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Everything one lane's thread needs, owned by its supervisor loop.
struct LaneWorker {
    backend: Arc<dyn Backend>,
    op: Op,
    n: usize,
    /// Output elements per request row.
    per: usize,
    max_batch: usize,
    /// Cost-model row cap derived from [`Config::flush_work`] and this
    /// lane's per-row work estimate (`usize::MAX` when disabled): big
    /// rows flush in smaller batches instead of waiting for stragglers.
    work_cap_rows: usize,
    max_wait: Duration,
    metrics: Arc<LaneMetrics>,
    state: Arc<LaneState>,
    /// Queue-delay shedder fed with every dequeued job's sojourn time.
    shedder: Arc<OverloadShedder>,
    /// Drain cutoff: once set, every queued job is answered `Deadline`.
    drain_cutoff: Arc<AtomicBool>,
    /// Current restart backoff (doubles per consecutive death).
    backoff: Duration,
    backoff_max: Duration,
}

impl LaneWorker {
    /// Supervisor: run [`LaneWorker::lane_loop`] until clean shutdown,
    /// restarting it after lane-fatal panics with bounded exponential
    /// backoff. Owns the receiver, so jobs queued while the lane is down
    /// survive the restart.
    fn supervise(mut self, rx: Receiver<Job>) {
        let initial_backoff = self.backoff;
        loop {
            let started = Instant::now();
            match catch_unwind(AssertUnwindSafe(|| self.lane_loop(&rx))) {
                // channel disconnected: clean coordinator shutdown
                Ok(()) => return,
                Err(payload) => {
                    let msg = panic_message(&*payload);
                    self.metrics.lane_failures.fetch_add(1, Ordering::Relaxed);
                    self.state.set_dead();
                    // a healthy run longer than the ceiling resets the
                    // backoff — only *rapid* death loops escalate
                    if started.elapsed() > self.backoff_max {
                        self.backoff = initial_backoff;
                    }
                    eprintln!(
                        "lane-{}-{}: lane-fatal panic ({msg}); restarting in {:?}",
                        self.op, self.n, self.backoff
                    );
                    std::thread::sleep(self.backoff);
                    self.backoff = (self.backoff * 2).min(self.backoff_max);
                    self.metrics.restarts.fetch_add(1, Ordering::Relaxed);
                    self.state.restart();
                }
            }
        }
    }

    /// One lane incarnation: batch, expire, execute, fan out. Returns on
    /// channel disconnect (shutdown); panics only on lane-fatal invariant
    /// violations (the supervisor's job).
    fn lane_loop(&self, rx: &Receiver<Job>) {
        loop {
            // block for the first job of the batch
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => return, // all senders dropped -> shutdown
            };
            // the earliest queued deadline bounds the flush window — a
            // request near expiry must not burn its remaining budget
            // waiting for batchmates; the cost-model row cap keeps one
            // flush's total estimated work bounded for expensive lanes
            let mut jobs = vec![first];
            let mut fill_deadline = Instant::now() + self.max_wait;
            if let Some(d) = jobs[0].deadline {
                fill_deadline = fill_deadline.min(d);
            }
            let batch_cap = self.max_batch.min(self.work_cap_rows);
            while jobs.len() < batch_cap {
                let now = Instant::now();
                if now >= fill_deadline {
                    break;
                }
                match rx.recv_timeout(fill_deadline - now) {
                    Ok(j) => {
                        if let Some(d) = j.deadline {
                            fill_deadline = fill_deadline.min(d);
                        }
                        jobs.push(j);
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            debug_assert!(jobs.len() <= self.max_batch);

            // answer expired jobs before spending backend time on them;
            // the drain cutoff expires *everything* still queued (typed
            // terminal answers, never silent drops)
            let now = Instant::now();
            // ORDERING: Relaxed — one-way drain latch, see Coordinator::drain.
            let cutoff = self.drain_cutoff.load(Ordering::Relaxed);
            let mut live = Vec::with_capacity(jobs.len());
            for job in jobs {
                self.shedder
                    .observe(now.saturating_duration_since(job.enqueued));
                let expired = cutoff || matches!(job.deadline, Some(d) if now >= d);
                if expired {
                    self.metrics.expired.fetch_add(1, Ordering::Relaxed);
                    self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
                    let _ = job.reply.send(Response {
                        id: job.id,
                        result: Err(RequestError::Deadline),
                    });
                } else {
                    live.push(job);
                }
            }
            if live.is_empty() {
                continue;
            }
            self.run_jobs(live);
        }
    }

    /// Execute one batch of live jobs and answer every one of them.
    fn run_jobs(&self, mut jobs: Vec<Job>) {
        let rows = jobs.len();
        if rows > 1 {
            // the coalescing ledger: rows that actually shared a backend
            // call with at least one batchmate
            self.metrics
                .coalesced_rows
                .fetch_add(rows as u64, Ordering::Relaxed);
        }
        let mut xs = Vec::with_capacity(rows * self.n);
        for j in &jobs {
            xs.extend_from_slice(&j.vector);
        }
        match self.exec_recorded(rows, &xs) {
            Ok(out) => self.respond_ok(out, jobs),
            Err(RequestError::Panic(msg)) => {
                if rows == 1 {
                    self.respond_err(RequestError::Panic(msg), jobs.pop().unwrap());
                } else {
                    // one poisoned input must not fail its batchmates:
                    // retry each job alone, once (FIFO order preserved);
                    // only the request(s) that panic solo wear the error
                    for job in jobs {
                        match self.exec_recorded(1, &job.vector) {
                            Ok(out) => self.respond_ok(out, vec![job]),
                            Err(e) => self.respond_err(e, job),
                        }
                    }
                }
            }
            Err(e) => {
                for job in jobs {
                    self.respond_err(e.clone(), job);
                }
            }
        }
    }

    /// One isolated backend call: panics are caught and typed, outcomes
    /// feed the circuit breaker, and a malformed output shape is
    /// *lane-fatal* (deliberately panics out to the supervisor — slicing
    /// garbage into responses would be worse than a counted restart).
    fn exec_recorded(&self, rows: usize, xs: &[f32]) -> Result<Output, RequestError> {
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .batched_rows
            .fetch_add(rows as u64, Ordering::Relaxed);
        let result = match catch_unwind(AssertUnwindSafe(|| {
            self.backend.run_batch(self.op, self.n, rows, xs)
        })) {
            Ok(Ok(out)) => Ok(out),
            Ok(Err(e)) => Err(RequestError::Backend(e)),
            Err(payload) => {
                self.metrics.panics.fetch_add(1, Ordering::Relaxed);
                Err(RequestError::Panic(panic_message(&*payload)))
            }
        };
        match &result {
            Ok(out) => {
                let got = match out {
                    Output::F32(v) => v.len(),
                    Output::I32(v) => v.len(),
                    Output::Bits(v) => v.len(),
                };
                assert_eq!(
                    got,
                    rows * self.per,
                    "backend '{}' returned a malformed batch shape",
                    self.backend.name()
                );
                self.state.record_success();
            }
            Err(_) => {
                if self.state.record_failure() {
                    self.metrics.breaker_opens.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        result
    }

    /// Fan a successful batch output back out to its requests.
    fn respond_ok(&self, out: Output, jobs: Vec<Job>) {
        let per = self.per;
        for (i, job) in jobs.into_iter().enumerate() {
            let slice = match &out {
                Output::F32(v) => Output::F32(v[i * per..(i + 1) * per].to_vec()),
                Output::I32(v) => Output::I32(v[i * per..(i + 1) * per].to_vec()),
                Output::Bits(v) => Output::Bits(v[i * per..(i + 1) * per].to_vec()),
            };
            // footprint ledger: packed words carry 64 bits/elem,
            // floats and ids 32 — what makes the binary lane's 32×
            // response compression visible in metrics
            let bits_per_elem = match &slice {
                Output::Bits(_) => 64,
                _ => 32,
            };
            self.metrics
                .output_bits
                .fetch_add((per * bits_per_elem) as u64, Ordering::Relaxed);
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
            self.metrics
                .latency
                .record_us(job.enqueued.elapsed().as_micros() as u64);
            let _ = job.reply.send(Response {
                id: job.id,
                result: Ok(slice),
            });
        }
    }

    fn respond_err(&self, e: RequestError, job: Job) {
        self.metrics.failed.fetch_add(1, Ordering::Relaxed);
        self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
        let _ = job.reply.send(Response {
            id: job.id,
            result: Err(e),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_coordinator(max_batch: usize, queue_cap: usize) -> Coordinator {
        let config = Config {
            lanes: vec![
                (Op::Transform, 64),
                (Op::Rff, 64),
                (Op::CrossPolytope, 64),
            ],
            max_batch,
            max_wait: Duration::from_micros(500),
            queue_cap,
            sigma: 1.0,
            seed: 9,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], config.sigma, config.seed));
        Coordinator::start(config, backend)
    }

    #[test]
    fn wire_codes_round_trip_and_match_roadmap() {
        // one entry per variant; the matches in code() are exhaustive, so
        // a new variant missing from these lists surfaces below as a code
        // absent from ROADMAP's table (or vice versa).
        let request = [
            RequestError::Deadline,
            RequestError::Panic("boom".into()),
            RequestError::Backend("bad".into()),
        ];
        let submit = [
            SubmitError::Busy,
            SubmitError::UnknownLane,
            SubmitError::BadDim,
            SubmitError::Closed,
            SubmitError::LaneDown,
            SubmitError::Unavailable,
            SubmitError::Throttled { retry_after_ms: 1 },
            SubmitError::Overloaded { retry_after_ms: 1 },
            SubmitError::Draining { retry_after_ms: 1 },
        ];
        // retry hints and the client's retryable-code set are the same
        // contract: exactly the retryable refusals carry `retry_after_ms`
        for e in &submit {
            assert_eq!(
                e.retry_after_ms().is_some(),
                client::RETRYABLE_CODES.contains(&e.code()),
                "retry hint must match the retryable contract: {e:?}"
            );
        }
        // round trip: the wire code alone identifies the variant
        for e in &request {
            let back = request.iter().find(|c| c.code() == e.code()).expect("code resolves");
            assert_eq!(std::mem::discriminant(back), std::mem::discriminant(e));
        }
        for e in &submit {
            let back = submit.iter().find(|c| c.code() == e.code()).expect("code resolves");
            assert_eq!(std::mem::discriminant(back), std::mem::discriminant(e));
        }
        // global uniqueness across both enums plus the server-side consts
        let mut codes: Vec<&str> = request.iter().map(RequestError::code).collect();
        codes.extend(submit.iter().map(SubmitError::code));
        codes.push(server::CODE_BAD_REQUEST);
        codes.push(server::CODE_TIMEOUT);
        codes.push(codec::CODE_SHARD_DOWN);
        codes.push(codec::CODE_PARTIAL);
        // fleet-tier contract: shard_down is a retryable refusal (and the
        // codec pins its hint); partial is a success-with-flag marker, so
        // the retry client must never treat it as retryable
        assert!(client::RETRYABLE_CODES.contains(&codec::CODE_SHARD_DOWN));
        assert!(!client::RETRYABLE_CODES.contains(&codec::CODE_PARTIAL));
        let unique: std::collections::BTreeSet<&str> = codes.iter().copied().collect();
        assert_eq!(unique.len(), codes.len(), "duplicate wire codes: {codes:?}");
        // exact set equality against ROADMAP.md's failure-model table —
        // the same cross-check `cargo xtask lint` (R4) runs pre-build
        let roadmap =
            std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../ROADMAP.md"))
                .expect("ROADMAP.md sits at the repo root");
        let table: std::collections::BTreeSet<&str> = roadmap
            .lines()
            .filter_map(|l| l.strip_prefix("| `")?.split_once("` |").map(|(code, _)| code))
            .collect();
        assert_eq!(table, unique, "ROADMAP failure-model table out of sync with the code");
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        let c = test_coordinator(8, 256);
        let mut rng = Rng::new(1);
        let mut rxs = Vec::new();
        for _ in 0..100 {
            let v = rng.gaussian_vec(64);
            let (id, rx) = c.submit(Op::Transform, v).unwrap();
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let resp = rx.recv().expect("one response");
            assert_eq!(resp.id, id);
            assert_eq!(resp.result.unwrap().as_f32().unwrap().len(), 64);
        }
        c.shutdown();
    }

    #[test]
    fn unknown_lane_and_bad_dim_rejected() {
        let c = test_coordinator(8, 16);
        assert_eq!(
            c.submit(Op::Transform, vec![0.0; 128]).unwrap_err(),
            SubmitError::UnknownLane
        );
        c.shutdown();
    }

    #[test]
    fn responses_match_direct_backend_call() {
        // padding rows must never leak: coordinator output == direct call
        let config = Config {
            lanes: vec![(Op::Rff, 64)],
            max_batch: 16,
            max_wait: Duration::from_micros(100),
            queue_cap: 64,
            sigma: 2.0,
            seed: 11,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 2.0, 11));
        let direct = NativeBackend::new(&[64], 2.0, 11);
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let v = rng.gaussian_vec(64);
            let got = c.call(Op::Rff, v.clone()).unwrap();
            let want = direct.run_batch(Op::Rff, 64, 1, &v).unwrap();
            assert_eq!(got, want);
        }
        c.shutdown();
    }

    #[test]
    fn binary_embed_lane_matches_backend_and_ships_32x_less() {
        let config = Config {
            lanes: vec![(Op::Transform, 64), (Op::BinaryEmbed, 64)],
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            sigma: 1.0,
            seed: 21,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 21));
        let direct = NativeBackend::new(&[64], 1.0, 21);
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let v = rng.gaussian_vec(64);
            let got = c.call(Op::BinaryEmbed, v.clone()).unwrap();
            let want = direct.run_batch(Op::BinaryEmbed, 64, 1, &v).unwrap();
            assert_eq!(got, want);
            assert_eq!(got.as_bits().unwrap().len(), 1); // 64 bits = 1 word
            // the packed code is the sign pattern of the f32 transform lane
            let dense = c.call(Op::Transform, v).unwrap();
            let word = got.as_bits().unwrap()[0];
            for (i, y) in dense.as_f32().unwrap().iter().enumerate() {
                assert_eq!((word >> i) & 1 == 1, y.is_sign_negative(), "bit {i}");
            }
        }
        // footprint ledger: 64 bits/response vs 64*32 on the float lane
        let m = c.metrics();
        let bits = |op: Op| {
            m.iter()
                .find(|((o, _), _)| *o == op)
                .unwrap()
                .1
                .output_bits
                .load(Ordering::Relaxed)
        };
        assert_eq!(bits(Op::Transform), 20 * 64 * 32);
        assert_eq!(bits(Op::BinaryEmbed), 20 * 64);
        assert_eq!(bits(Op::Transform), 32 * bits(Op::BinaryEmbed));
        c.shutdown();
    }

    #[test]
    fn fifo_within_lane() {
        let c = test_coordinator(4, 256);
        let mut rng = Rng::new(3);
        let mut pairs = Vec::new();
        for _ in 0..50 {
            let v = rng.gaussian_vec(64);
            pairs.push(c.submit(Op::CrossPolytope, v).unwrap());
        }
        let mut last = 0u64;
        for (id, rx) in pairs {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, id);
            assert!(id > last, "ids must arrive in submit order");
            last = id;
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue + slow drain: force Busy
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 1,
            max_wait: Duration::from_millis(50),
            queue_cap: 2,
            sigma: 1.0,
            seed: 1,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(4);
        let mut saw_busy = false;
        let mut rxs = Vec::new();
        for _ in 0..200 {
            match c.submit(Op::Transform, rng.gaussian_vec(64)) {
                Ok(p) => rxs.push(p),
                Err(SubmitError::Busy) => {
                    saw_busy = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_busy, "bounded queue must eventually reject");
        // accepted requests all complete
        for (_, rx) in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        c.shutdown();
    }

    #[test]
    fn metrics_track_counts() {
        let c = test_coordinator(8, 256);
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            c.call(Op::Transform, rng.gaussian_vec(64)).unwrap();
        }
        let m = c.metrics();
        let (_, tm) = m
            .iter()
            .find(|((op, n), _)| *op == Op::Transform && *n == 64)
            .unwrap();
        assert_eq!(tm.submitted.load(Ordering::Relaxed), 30);
        assert_eq!(tm.completed.load(Ordering::Relaxed), 30);
        assert_eq!(tm.failed.load(Ordering::Relaxed), 0);
        assert_eq!(tm.lane_failures.load(Ordering::Relaxed), 0);
        assert_eq!(tm.restarts.load(Ordering::Relaxed), 0);
        assert!(tm.latency.count() == 30);
        let j = c.metrics_json().to_string();
        assert!(crate::util::json::Json::parse(&j).is_ok());
        c.shutdown();
    }

    #[test]
    fn health_json_reports_open_lanes() {
        let c = test_coordinator(8, 256);
        let h = c.health_json();
        let lane = h.get("transform_n64").expect("transform lane in health");
        assert_eq!(lane.get("state").unwrap().as_str(), Some("open"));
        assert_eq!(lane.get("restarts").unwrap().as_f64(), Some(0.0));
        // response-cache occupancy rides health (fed by the ingress)
        assert_eq!(lane.get("cache_entries").unwrap().as_f64(), Some(0.0));
        assert!(crate::util::json::Json::parse(&h.to_string()).is_ok());
        c.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let c = Arc::new(test_coordinator(16, 1024));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cc = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..25 {
                    let out = cc.call(Op::Transform, rng.gaussian_vec(64)).unwrap();
                    assert_eq!(out.as_f32().unwrap().len(), 64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn batching_actually_batches() {
        // submit a burst, then check mean batch size > 1
        let c = test_coordinator(32, 1024);
        let mut rng = Rng::new(6);
        let mut rxs = Vec::new();
        for _ in 0..64 {
            rxs.push(c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap());
        }
        for (_, rx) in rxs {
            rx.recv().unwrap().result.unwrap();
        }
        let m = c.metrics();
        let (_, tm) = m
            .iter()
            .find(|((op, _), _)| *op == Op::Transform)
            .unwrap();
        assert!(
            tm.mean_batch_size() > 1.5,
            "mean batch {} — burst should batch",
            tm.mean_batch_size()
        );
        assert!(
            tm.coalesced_rows.load(Ordering::Relaxed) > 0,
            "multi-row batches must feed the coalescing ledger"
        );
        c.shutdown();
    }

    #[test]
    fn flush_work_caps_batch_rows() {
        // two rows' worth of work per flush: a 16-deep burst against a
        // 32-row max_batch must still flush in ≤ 2-row batches
        let per_row = admission::request_work(Op::Transform, 64);
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
            sigma: 1.0,
            seed: 9,
            flush_work: per_row * 2,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 9));
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(7);
        let mut rxs = Vec::new();
        for _ in 0..16 {
            rxs.push(c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap());
        }
        for (_, rx) in rxs {
            rx.recv().unwrap().result.unwrap();
        }
        let m = c.metrics();
        let (_, tm) = &m[0];
        let mean = tm.mean_batch_size();
        assert!(mean > 0.0 && mean <= 2.0, "work cap must bound flushes: {mean}");
        c.shutdown();
    }

    #[test]
    fn admit_then_enqueue_matches_submit_refusals() {
        // split halves behave like submit_with_opts: admission charges
        // the client's bucket at admit() time, enqueue() then queues
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            admission_rate: 100_000.0,
            admission_burst: admission::request_work(Op::Transform, 64) as f64 + 10.0,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 7));
        let c = Coordinator::start(config, backend);
        let alice = SubmitOptions {
            client: Some("alice"),
            ..SubmitOptions::default()
        };
        assert_eq!(c.admit(Op::Transform, 64, alice), Ok(()));
        let (_, rx) = c.enqueue(Op::Transform, vec![1.0; 64], None).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        // the bucket was charged by admit(), so a second admit throttles
        assert!(matches!(
            c.admit(Op::Transform, 64, alice),
            Err(SubmitError::Throttled { .. })
        ));
        // dimension mistakes refuse at the admit half
        assert_eq!(
            c.admit(Op::Transform, 128, SubmitOptions::default()),
            Err(SubmitError::UnknownLane)
        );
        // the metrics handle resolves exactly the configured lanes
        assert!(c.lane_metrics(Op::Transform, 64).is_some());
        assert!(c.lane_metrics(Op::Rff, 64).is_none());
        c.shutdown();
    }

    #[test]
    fn admission_throttles_per_client_with_hint_and_counters() {
        // burst fits exactly one transform_n64 request (1344 work units);
        // the refill rate is fast so hints stay small but nonzero
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            admission_rate: 100_000.0,
            admission_burst: admission::request_work(Op::Transform, 64) as f64 + 10.0,
            ..Config::default()
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 7));
        let c = Coordinator::start(config, backend);
        let alice = SubmitOptions {
            client: Some("alice"),
            ..SubmitOptions::default()
        };
        let (_, rx) = c.submit_with_opts(Op::Transform, vec![1.0; 64], alice).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        match c.submit_with_opts(Op::Transform, vec![1.0; 64], alice) {
            Err(SubmitError::Throttled { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "hint must be actionable");
            }
            other => panic!("drained bucket must throttle, got {other:?}"),
        }
        // an unrelated client still has a full bucket
        let bob = SubmitOptions {
            client: Some("bob"),
            ..SubmitOptions::default()
        };
        let (_, rx) = c.submit_with_opts(Op::Transform, vec![1.0; 64], bob).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        let m = c.metrics();
        assert_eq!(m[0].1.throttled.load(Ordering::Relaxed), 1);
        // per-client counters ride the metrics document
        let j = c.metrics_json();
        let adm = j.get("admission").expect("admission section when enabled");
        assert_eq!(
            adm.get("alice").unwrap().get("throttled").unwrap().as_f64(),
            Some(1.0)
        );
        c.shutdown();
    }

    #[test]
    fn submit_options_default_matches_submit() {
        let c = test_coordinator(8, 64);
        let (_, rx) = c
            .submit_with_opts(Op::Transform, vec![1.0; 64], SubmitOptions::default())
            .unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        c.shutdown();
    }
}

#[cfg(test)]
mod failure_tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicBool;

    /// Backend that fails every call — exercises the error fan-out path.
    struct FailingBackend;

    impl Backend for FailingBackend {
        fn run_batch(
            &self,
            _op: Op,
            _n: usize,
            _rows: usize,
            _xs: &[f32],
        ) -> Result<Output, String> {
            Err("injected failure".into())
        }
        fn name(&self) -> &'static str {
            "failing"
        }
    }

    /// Backend that fails intermittently (every other batch).
    struct FlakyBackend {
        inner: NativeBackend,
        calls: std::sync::atomic::AtomicU64,
    }

    impl Backend for FlakyBackend {
        fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
            let c = self.calls.fetch_add(1, Ordering::Relaxed);
            if c % 2 == 1 {
                Err("flaky".into())
            } else {
                self.inner.run_batch(op, n, rows, xs)
            }
        }
        fn name(&self) -> &'static str {
            "flaky"
        }
    }

    /// Backend that panics whenever the batch contains a poisoned row
    /// (first element above 900) — singleton retries then isolate it.
    struct PanickyBackend {
        inner: NativeBackend,
    }

    impl Backend for PanickyBackend {
        fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
            for row in xs.chunks_exact(n) {
                if row[0] > 900.0 {
                    panic!("poisoned input row");
                }
            }
            self.inner.run_batch(op, n, rows, xs)
        }
        fn name(&self) -> &'static str {
            "panicky"
        }
    }

    /// Backend returning a wrong-shape batch for its first `bad` calls —
    /// the lane-fatal invariant violation the supervisor must absorb.
    struct MalformedBackend {
        inner: NativeBackend,
        bad: std::sync::atomic::AtomicU64,
    }

    impl Backend for MalformedBackend {
        fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
            let left = self.bad.load(Ordering::Relaxed);
            if left > 0 {
                self.bad.store(left - 1, Ordering::Relaxed);
                return Ok(Output::F32(vec![0.0])); // wrong length
            }
            self.inner.run_batch(op, n, rows, xs)
        }
        fn name(&self) -> &'static str {
            "malformed"
        }
    }

    /// Backend whose failure mode is toggled at runtime (breaker tests).
    struct SwitchableBackend {
        inner: NativeBackend,
        failing: AtomicBool,
    }

    impl Backend for SwitchableBackend {
        fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
            if self.failing.load(Ordering::Relaxed) {
                Err("switched off".into())
            } else {
                self.inner.run_batch(op, n, rows, xs)
            }
        }
        fn name(&self) -> &'static str {
            "switchable"
        }
    }

    fn config() -> Config {
        Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            queue_cap: 64,
            sigma: 1.0,
            seed: 1,
            // most failure tests drive long failure streaks on purpose;
            // the breaker has its own dedicated test below
            breaker_threshold: 0,
            ..Config::default()
        }
    }

    #[test]
    fn failing_backend_errors_propagate_to_every_request() {
        let c = Coordinator::start(config(), Arc::new(FailingBackend));
        let mut rng = Rng::new(1);
        let mut rxs = Vec::new();
        for _ in 0..20 {
            rxs.push(c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap());
        }
        for (id, rx) in rxs {
            let resp = rx.recv().expect("a response, even on failure");
            assert_eq!(resp.id, id);
            assert_eq!(
                resp.result.unwrap_err(),
                RequestError::Backend("injected failure".into())
            );
        }
        let m = c.metrics();
        let (_, lm) = &m[0];
        assert_eq!(lm.failed.load(Ordering::Relaxed), 20);
        assert_eq!(lm.completed.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn flaky_backend_keeps_lane_alive() {
        // a failed batch must not kill the lane: later requests succeed.
        let be = FlakyBackend {
            inner: NativeBackend::new(&[64], 1.0, 1),
            calls: std::sync::atomic::AtomicU64::new(0),
        };
        let c = Coordinator::start(config(), Arc::new(be));
        let mut rng = Rng::new(2);
        let (mut ok, mut err) = (0, 0);
        for _ in 0..30 {
            match c.call(Op::Transform, rng.gaussian_vec(64)) {
                Ok(out) => {
                    assert_eq!(out.as_f32().unwrap().len(), 64);
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(e, "flaky");
                    err += 1;
                }
            }
        }
        assert!(ok > 0, "some requests must succeed");
        assert!(err > 0, "some requests must fail (flaky backend)");
        c.shutdown();
    }

    #[test]
    fn panicking_batch_is_retried_as_singletons() {
        let be = PanickyBackend {
            inner: NativeBackend::new(&[64], 1.0, 1),
        };
        let cfg = Config {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            ..config()
        };
        let c = Coordinator::start(cfg, Arc::new(be));
        let mut rng = Rng::new(3);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let mut v = rng.gaussian_vec(64);
            if i == 2 {
                v[0] = 1000.0; // the poisoned request
            }
            rxs.push((i, c.submit(Op::Transform, v).unwrap()));
        }
        for (i, (id, rx)) in rxs {
            let resp = rx.recv().expect("terminal response despite panics");
            assert_eq!(resp.id, id);
            if i == 2 {
                let err = resp.result.unwrap_err();
                assert!(
                    matches!(&err, RequestError::Panic(m) if m.contains("poisoned")),
                    "poisoned request must wear the panic: {err:?}"
                );
            } else {
                assert_eq!(
                    resp.result.unwrap().as_f32().unwrap().len(),
                    64,
                    "batchmates of a poisoned request must still succeed"
                );
            }
        }
        let m = c.metrics();
        let (_, lm) = &m[0];
        assert!(lm.panics.load(Ordering::Relaxed) >= 1, "panic counted");
        assert_eq!(lm.lane_failures.load(Ordering::Relaxed), 0, "lane lived");
        c.shutdown();
    }

    #[test]
    fn deadline_expires_queued_jobs_before_backend_time() {
        // a 150ms-per-call backend: the second request queues behind the
        // first and expires (20ms deadline) before the lane reaches it
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let plan = FaultPlan::parse("delay_ms:150").unwrap();
        let be = Arc::new(FaultInjectingBackend::new(inner, plan));
        let cfg = Config {
            max_batch: 1,
            ..config()
        };
        let c = Coordinator::start(cfg, be);
        let mut rng = Rng::new(4);
        let (_, rx1) = c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap();
        let (_, rx2) = c
            .submit_with_deadline(
                Op::Transform,
                rng.gaussian_vec(64),
                Some(Duration::from_millis(20)),
            )
            .unwrap();
        assert!(rx1.recv().unwrap().result.is_ok(), "undeadlined job runs");
        assert_eq!(
            rx2.recv().unwrap().result.unwrap_err(),
            RequestError::Deadline
        );
        let m = c.metrics();
        let (_, lm) = &m[0];
        assert_eq!(lm.expired.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn call_timeout_never_hangs_on_a_slow_backend() {
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let plan = FaultPlan::parse("delay_ms:800").unwrap();
        let be = Arc::new(FaultInjectingBackend::new(inner, plan));
        let c = Coordinator::start(config(), be);
        let t0 = Instant::now();
        let r = c.call_timeout(
            Op::Transform,
            vec![1.0; 64],
            Duration::from_millis(50),
        );
        let err = r.unwrap_err();
        assert!(err.contains("timed out"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(700),
            "call_timeout must return before the slow backend does"
        );
        c.shutdown();
    }

    #[test]
    fn breaker_opens_sheds_then_recovers() {
        let be = Arc::new(SwitchableBackend {
            inner: NativeBackend::new(&[64], 1.0, 1),
            failing: AtomicBool::new(true),
        });
        let cfg = Config {
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(100),
            ..config()
        };
        let c = Coordinator::start(cfg, Arc::clone(&be));
        // two consecutive failing calls open the breaker (record happens
        // before the response is sent, so after call() returns it's set)
        for _ in 0..2 {
            assert!(c.call(Op::Transform, vec![1.0; 64]).is_err());
        }
        let shed = c.submit(Op::Transform, vec![1.0; 64]).unwrap_err();
        assert_eq!(shed, SubmitError::Unavailable, "open breaker sheds");
        let m = c.metrics();
        let (_, lm) = &m[0];
        assert_eq!(lm.breaker_opens.load(Ordering::Relaxed), 1);
        assert!(lm.shed_unavailable.load(Ordering::Relaxed) >= 1);
        // heal the backend, wait out the cooldown: the half-open probe
        // succeeds and the breaker closes
        be.failing.store(false, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(120));
        c.call(Op::Transform, vec![1.0; 64])
            .expect("half-open probe after cooldown must be admitted");
        c.call(Op::Transform, vec![1.0; 64])
            .expect("breaker closed after a successful probe");
        c.shutdown();
    }

    #[test]
    fn shedder_sheds_low_priority_under_queue_delay() {
        // 50ms-per-call backend + 1µs sojourn target + zero window: the
        // jobs queued behind the first observe ≥50ms delays, escalating
        // the shedder to level 2 (sticky until a sub-target observation,
        // which a shed-everything lane never produces)
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let plan = FaultPlan::parse("delay_ms:50").unwrap();
        let be = Arc::new(FaultInjectingBackend::new(inner, plan));
        let cfg = Config {
            max_batch: 1,
            shed_target: Duration::from_micros(1),
            shed_window: Duration::ZERO,
            ..config()
        };
        let c = Coordinator::start(cfg, be);
        let high = SubmitOptions {
            priority: admission::PRIORITY_HIGH,
            ..SubmitOptions::default()
        };
        let mut rxs = Vec::new();
        for _ in 0..3 {
            rxs.push(c.submit_with_opts(Op::Transform, vec![1.0; 64], high).unwrap());
        }
        for (_, rx) in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let low = SubmitOptions {
            priority: admission::PRIORITY_LOW,
            ..SubmitOptions::default()
        };
        match c.submit_with_opts(Op::Transform, vec![1.0; 64], low) {
            Err(SubmitError::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "hint must be actionable");
            }
            other => panic!("overloaded lane must shed priority-0 work, got {other:?}"),
        }
        // priority-2 work is never shedder-shed
        let (_, rx) = c.submit_with_opts(Op::Transform, vec![1.0; 64], high).unwrap();
        assert!(rx.recv().unwrap().result.is_ok());
        let m = c.metrics();
        assert!(m[0].1.shed_overloaded.load(Ordering::Relaxed) >= 1);
        c.shutdown();
    }

    #[test]
    fn drain_refuses_new_gives_queued_typed_answers_and_empties() {
        // 100ms-per-call backend, 4 queued jobs: drain with a 10ms
        // deadline lets the in-flight job finish, expires the rest with
        // typed Deadline answers, and leaves nothing pending
        let inner: Arc<dyn Backend> = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let plan = FaultPlan::parse("delay_ms:100").unwrap();
        let be = Arc::new(FaultInjectingBackend::new(inner, plan));
        let cfg = Config {
            max_batch: 1,
            ..config()
        };
        let c = Coordinator::start(cfg, be);
        let mut rxs = Vec::new();
        for _ in 0..4 {
            rxs.push(c.submit(Op::Transform, vec![1.0; 64]).unwrap());
        }
        std::thread::sleep(Duration::from_millis(20)); // first job in flight
        c.begin_drain();
        assert!(c.is_draining());
        match c.submit(Op::Transform, vec![1.0; 64]) {
            Err(SubmitError::Draining { retry_after_ms }) => {
                assert_eq!(retry_after_ms, DRAINING_RETRY_MS);
            }
            other => panic!("draining coordinator must refuse, got {other:?}"),
        }
        assert!(
            !c.drain(Duration::from_millis(10)),
            "a 10ms deadline cannot drain 400ms of backlog naturally"
        );
        let mut ok = 0;
        let mut expired = 0;
        for (_, rx) in rxs {
            match rx
                .recv_timeout(Duration::from_secs(2))
                .expect("every admitted request gets a terminal answer")
                .result
            {
                Ok(_) => ok += 1,
                Err(RequestError::Deadline) => expired += 1,
                Err(e) => panic!("unexpected terminal error {e:?}"),
            }
        }
        assert!(ok >= 1, "the in-flight job must complete");
        assert!(expired >= 1, "cutoff must expire still-queued jobs");
        assert_eq!(ok + expired, 4);
        // the drain counter and gauge tell the story in metrics
        let m = c.metrics();
        assert_eq!(m[0].1.drained.load(Ordering::Relaxed), 1);
        assert_eq!(c.pending(), 0, "nothing may remain in flight after drain");
        assert_eq!(
            c.health_json().get("draining").unwrap(),
            &crate::util::json::Json::Bool(true)
        );
        c.shutdown();
    }

    #[test]
    fn drain_returns_true_when_work_finishes_under_deadline() {
        let c = Coordinator::start(config(), Arc::new(NativeBackend::new(&[64], 1.0, 1)));
        let (_, rx) = c.submit(Op::Transform, vec![1.0; 64]).unwrap();
        assert!(c.drain(Duration::from_secs(5)), "fast lane drains cleanly");
        assert!(rx.recv().unwrap().result.is_ok());
        assert_eq!(c.pending(), 0);
        c.shutdown();
    }

    #[test]
    fn dead_lane_is_detected_counted_and_restarted() {
        let be = Arc::new(MalformedBackend {
            inner: NativeBackend::new(&[64], 1.0, 1),
            bad: std::sync::atomic::AtomicU64::new(1),
        });
        let cfg = Config {
            restart_backoff: Duration::from_millis(5),
            restart_backoff_max: Duration::from_millis(40),
            ..config()
        };
        let c = Coordinator::start(cfg, be);
        // first call hits the malformed output -> lane-fatal panic; the
        // in-flight reply channel disconnects but call_timeout surfaces it
        let err = c
            .call_timeout(Op::Transform, vec![1.0; 64], Duration::from_secs(2))
            .unwrap_err();
        assert!(
            err.contains("restarted") || err.contains("timed out"),
            "lost in-flight request must surface an error: {err}"
        );
        // the supervisor restarts the lane within the backoff window
        let m = c.metrics();
        let (_, lm) = &m[0];
        let deadline = Instant::now() + Duration::from_secs(5);
        while lm.restarts.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "lane must restart");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(lm.lane_failures.load(Ordering::Relaxed) >= 1);
        // restarted lane serves traffic again
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match c.call_timeout(Op::Transform, vec![1.0; 64], Duration::from_secs(1)) {
                Ok(out) => {
                    assert_eq!(out.as_f32().unwrap().len(), 64);
                    break;
                }
                Err(_) => {
                    assert!(Instant::now() < deadline, "restarted lane must serve");
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        c.shutdown();
    }
}
