//! Layer-3 serving coordinator: request router → per-lane dynamic batcher →
//! backend execution (PJRT artifacts or native Rust), with bounded-queue
//! backpressure and per-lane metrics.
//!
//! Topology: one ingress per lane (an `(op, n)` pair). [`Coordinator::submit`]
//! routes a request to its lane's bounded channel — a full channel rejects
//! with [`SubmitError::Busy`] (explicit load-shedding, never unbounded
//! memory). Each lane runs a thread that drains up to `max_batch` requests
//! (waiting at most `max_wait` after the first), pads the tail, executes one
//! backend call, and fans responses back out on per-request channels.
//! Backend batch execution shards over the backend's **persistent**
//! [`crate::runtime::WorkerPool`] — lane threads never spawn per-batch
//! workers, so steady-state serving touches a fixed set of long-lived
//! threads.
//!
//! Invariants (property-tested below and in `rust/tests/`):
//! * every accepted request receives exactly one response;
//! * batch sizes never exceed `max_batch`;
//! * padding rows never leak into responses;
//! * routing is a pure function of `(op, dim)`;
//! * FIFO order within a lane.

pub mod backend;
pub mod server;
pub mod metrics;

pub use backend::{Backend, ModelParams, NativeBackend, PjrtBackend};
pub use metrics::LaneMetrics;
pub use server::TcpServer;

use crate::runtime::{Op, Output};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Lanes to open: (op, input dim n). n must be a power of two.
    pub lanes: Vec<(Op, usize)>,
    /// Max requests per backend call.
    pub max_batch: usize,
    /// How long a lane waits to fill a batch after the first request.
    pub max_wait: Duration,
    /// Bounded ingress queue per lane (backpressure limit).
    pub queue_cap: usize,
    /// Gaussian-kernel bandwidth for the RFF op.
    pub sigma: f64,
    /// Model seed (both backends derive identical diagonals from it).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            lanes: vec![
                (Op::Transform, 256),
                (Op::Rff, 256),
                (Op::CrossPolytope, 256),
                (Op::BinaryEmbed, 256),
            ],
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            sigma: 1.0,
            seed: 42,
        }
    }
}

/// A response: the per-request slice of the batch output.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<Output, String>,
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The lane's queue is full — shed load and retry later.
    Busy,
    /// No lane for this (op, dim).
    UnknownLane,
    /// Input length != lane dim.
    BadDim,
    /// Coordinator is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "lane queue full"),
            SubmitError::UnknownLane => write!(f, "no lane for (op, dim)"),
            SubmitError::BadDim => write!(f, "input dim mismatch"),
            SubmitError::Closed => write!(f, "coordinator closed"),
        }
    }
}

struct Job {
    id: u64,
    vector: Vec<f32>,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
}

struct Lane {
    tx: SyncSender<Job>,
    metrics: Arc<LaneMetrics>,
    n: usize,
}

/// The running coordinator.
pub struct Coordinator {
    lanes: HashMap<(Op, usize), Lane>,
    next_id: AtomicU64,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start one batcher thread per lane over a shared backend.
    pub fn start(config: Config, backend: Arc<dyn Backend>) -> Coordinator {
        let mut lanes = HashMap::new();
        let mut joins = Vec::new();
        for (op, n) in &config.lanes {
            let (op, n) = (*op, *n);
            let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_cap);
            let metrics = Arc::new(LaneMetrics::new());
            let be = Arc::clone(&backend);
            let m = Arc::clone(&metrics);
            let max_batch = config.max_batch;
            let max_wait = config.max_wait;
            let join = std::thread::Builder::new()
                .name(format!("lane-{op}-{n}"))
                .spawn(move || lane_loop(rx, be, op, n, max_batch, max_wait, m))
                .expect("spawn lane thread");
            joins.push(join);
            lanes.insert((op, n), Lane { tx, metrics, n });
        }
        Coordinator {
            lanes,
            next_id: AtomicU64::new(1),
            joins,
        }
    }

    /// Submit a request. Returns the request id and a receiver for the
    /// response. Non-blocking: a full lane returns [`SubmitError::Busy`].
    pub fn submit(
        &self,
        op: Op,
        vector: Vec<f32>,
    ) -> Result<(u64, Receiver<Response>), SubmitError> {
        let lane = self
            .lanes
            .get(&(op, vector.len()))
            .ok_or(SubmitError::UnknownLane)?;
        if vector.len() != lane.n {
            return Err(SubmitError::BadDim);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let job = Job {
            id,
            vector,
            reply,
            enqueued: Instant::now(),
        };
        lane.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match lane.tx.try_send(job) {
            Ok(()) => Ok((id, rx)),
            Err(TrySendError::Full(_)) => {
                lane.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Submit and wait for the response (convenience for examples / CLI).
    pub fn call(&self, op: Op, vector: Vec<f32>) -> Result<Output, String> {
        let (_, rx) = self.submit(op, vector).map_err(|e| e.to_string())?;
        rx.recv()
            .map_err(|_| "coordinator dropped response".to_string())?
            .result
    }

    /// Per-lane metrics handles.
    pub fn metrics(&self) -> Vec<((Op, usize), Arc<LaneMetrics>)> {
        let mut v: Vec<_> = self
            .lanes
            .iter()
            .map(|(k, l)| (*k, Arc::clone(&l.metrics)))
            .collect();
        v.sort_by_key(|((op, n), _)| (op.name(), *n));
        v
    }

    /// Metrics as a JSON document.
    pub fn metrics_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(
            self.metrics()
                .into_iter()
                .map(|((op, n), m)| (format!("{op}_n{n}"), m.to_json()))
                .collect(),
        )
    }

    /// Stop accepting requests, drain lanes, join threads.
    pub fn shutdown(mut self) {
        // dropping the senders closes the lanes
        self.lanes.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn lane_loop(
    rx: mpsc::Receiver<Job>,
    backend: Arc<dyn Backend>,
    op: Op,
    n: usize,
    max_batch: usize,
    max_wait: Duration,
    metrics: Arc<LaneMetrics>,
) {
    let per = backend.out_elems(op, n);
    loop {
        // block for the first job of the batch
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders dropped -> shutdown
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + max_wait;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        debug_assert!(jobs.len() <= max_batch);

        // assemble the batch buffer
        let rows = jobs.len();
        let mut xs = Vec::with_capacity(rows * n);
        for j in &jobs {
            xs.extend_from_slice(&j.vector);
        }
        let result = backend.run_batch(op, n, rows, &xs);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);

        match result {
            Ok(out) => {
                for (i, job) in jobs.into_iter().enumerate() {
                    let slice = match &out {
                        Output::F32(v) => Output::F32(v[i * per..(i + 1) * per].to_vec()),
                        Output::I32(v) => Output::I32(v[i * per..(i + 1) * per].to_vec()),
                        Output::Bits(v) => Output::Bits(v[i * per..(i + 1) * per].to_vec()),
                    };
                    // footprint ledger: packed words carry 64 bits/elem,
                    // floats and ids 32 — what makes the binary lane's 32×
                    // response compression visible in metrics
                    let bits_per_elem = match &slice {
                        Output::Bits(_) => 64,
                        _ => 32,
                    };
                    metrics
                        .output_bits
                        .fetch_add((per * bits_per_elem) as u64, Ordering::Relaxed);
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .latency
                        .record_us(job.enqueued.elapsed().as_micros() as u64);
                    let _ = job.reply.send(Response {
                        id: job.id,
                        result: Ok(slice),
                    });
                }
            }
            Err(e) => {
                for job in jobs {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Response {
                        id: job.id,
                        result: Err(e.clone()),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_coordinator(max_batch: usize, queue_cap: usize) -> Coordinator {
        let config = Config {
            lanes: vec![
                (Op::Transform, 64),
                (Op::Rff, 64),
                (Op::CrossPolytope, 64),
            ],
            max_batch,
            max_wait: Duration::from_micros(500),
            queue_cap,
            sigma: 1.0,
            seed: 9,
        };
        let backend = Arc::new(NativeBackend::new(&[64], config.sigma, config.seed));
        Coordinator::start(config, backend)
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        let c = test_coordinator(8, 256);
        let mut rng = Rng::new(1);
        let mut rxs = Vec::new();
        for _ in 0..100 {
            let v = rng.gaussian_vec(64);
            let (id, rx) = c.submit(Op::Transform, v).unwrap();
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let resp = rx.recv().expect("one response");
            assert_eq!(resp.id, id);
            assert_eq!(resp.result.unwrap().as_f32().unwrap().len(), 64);
        }
        c.shutdown();
    }

    #[test]
    fn unknown_lane_and_bad_dim_rejected() {
        let c = test_coordinator(8, 16);
        assert_eq!(
            c.submit(Op::Transform, vec![0.0; 128]).unwrap_err(),
            SubmitError::UnknownLane
        );
        c.shutdown();
    }

    #[test]
    fn responses_match_direct_backend_call() {
        // padding rows must never leak: coordinator output == direct call
        let config = Config {
            lanes: vec![(Op::Rff, 64)],
            max_batch: 16,
            max_wait: Duration::from_micros(100),
            queue_cap: 64,
            sigma: 2.0,
            seed: 11,
        };
        let backend = Arc::new(NativeBackend::new(&[64], 2.0, 11));
        let direct = NativeBackend::new(&[64], 2.0, 11);
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let v = rng.gaussian_vec(64);
            let got = c.call(Op::Rff, v.clone()).unwrap();
            let want = direct.run_batch(Op::Rff, 64, 1, &v).unwrap();
            assert_eq!(got, want);
        }
        c.shutdown();
    }

    #[test]
    fn binary_embed_lane_matches_backend_and_ships_32x_less() {
        let config = Config {
            lanes: vec![(Op::Transform, 64), (Op::BinaryEmbed, 64)],
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            sigma: 1.0,
            seed: 21,
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 21));
        let direct = NativeBackend::new(&[64], 1.0, 21);
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let v = rng.gaussian_vec(64);
            let got = c.call(Op::BinaryEmbed, v.clone()).unwrap();
            let want = direct.run_batch(Op::BinaryEmbed, 64, 1, &v).unwrap();
            assert_eq!(got, want);
            assert_eq!(got.as_bits().unwrap().len(), 1); // 64 bits = 1 word
            // the packed code is the sign pattern of the f32 transform lane
            let dense = c.call(Op::Transform, v).unwrap();
            let word = got.as_bits().unwrap()[0];
            for (i, y) in dense.as_f32().unwrap().iter().enumerate() {
                assert_eq!((word >> i) & 1 == 1, y.is_sign_negative(), "bit {i}");
            }
        }
        // footprint ledger: 64 bits/response vs 64*32 on the float lane
        let m = c.metrics();
        let bits = |op: Op| {
            m.iter()
                .find(|((o, _), _)| *o == op)
                .unwrap()
                .1
                .output_bits
                .load(Ordering::Relaxed)
        };
        assert_eq!(bits(Op::Transform), 20 * 64 * 32);
        assert_eq!(bits(Op::BinaryEmbed), 20 * 64);
        assert_eq!(bits(Op::Transform), 32 * bits(Op::BinaryEmbed));
        c.shutdown();
    }

    #[test]
    fn fifo_within_lane() {
        let c = test_coordinator(4, 256);
        let mut rng = Rng::new(3);
        let mut pairs = Vec::new();
        for _ in 0..50 {
            let v = rng.gaussian_vec(64);
            pairs.push(c.submit(Op::CrossPolytope, v).unwrap());
        }
        let mut last = 0u64;
        for (id, rx) in pairs {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, id);
            assert!(id > last, "ids must arrive in submit order");
            last = id;
        }
        c.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue + slow drain: force Busy
        let config = Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 1,
            max_wait: Duration::from_millis(50),
            queue_cap: 2,
            sigma: 1.0,
            seed: 1,
        };
        let backend = Arc::new(NativeBackend::new(&[64], 1.0, 1));
        let c = Coordinator::start(config, backend);
        let mut rng = Rng::new(4);
        let mut saw_busy = false;
        let mut rxs = Vec::new();
        for _ in 0..200 {
            match c.submit(Op::Transform, rng.gaussian_vec(64)) {
                Ok(p) => rxs.push(p),
                Err(SubmitError::Busy) => {
                    saw_busy = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_busy, "bounded queue must eventually reject");
        // accepted requests all complete
        for (_, rx) in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        c.shutdown();
    }

    #[test]
    fn metrics_track_counts() {
        let c = test_coordinator(8, 256);
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            c.call(Op::Transform, rng.gaussian_vec(64)).unwrap();
        }
        let m = c.metrics();
        let (_, tm) = m
            .iter()
            .find(|((op, n), _)| *op == Op::Transform && *n == 64)
            .unwrap();
        assert_eq!(tm.submitted.load(Ordering::Relaxed), 30);
        assert_eq!(tm.completed.load(Ordering::Relaxed), 30);
        assert_eq!(tm.failed.load(Ordering::Relaxed), 0);
        assert!(tm.latency.count() == 30);
        let j = c.metrics_json().to_string();
        assert!(crate::util::json::Json::parse(&j).is_ok());
        c.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let c = Arc::new(test_coordinator(16, 1024));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cc = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..25 {
                    let out = cc.call(Op::Transform, rng.gaussian_vec(64)).unwrap();
                    assert_eq!(out.as_f32().unwrap().len(), 64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn batching_actually_batches() {
        // submit a burst, then check mean batch size > 1
        let c = test_coordinator(32, 1024);
        let mut rng = Rng::new(6);
        let mut rxs = Vec::new();
        for _ in 0..64 {
            rxs.push(c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap());
        }
        for (_, rx) in rxs {
            rx.recv().unwrap().result.unwrap();
        }
        let m = c.metrics();
        let (_, tm) = m
            .iter()
            .find(|((op, _), _)| *op == Op::Transform)
            .unwrap();
        assert!(
            tm.mean_batch_size() > 1.5,
            "mean batch {} — burst should batch",
            tm.mean_batch_size()
        );
        c.shutdown();
    }
}

#[cfg(test)]
mod failure_tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Backend that fails every call — exercises the error fan-out path.
    struct FailingBackend;

    impl Backend for FailingBackend {
        fn run_batch(
            &self,
            _op: Op,
            _n: usize,
            _rows: usize,
            _xs: &[f32],
        ) -> Result<Output, String> {
            Err("injected failure".into())
        }
        fn name(&self) -> &'static str {
            "failing"
        }
    }

    /// Backend that fails intermittently (every other batch).
    struct FlakyBackend {
        inner: NativeBackend,
        calls: std::sync::atomic::AtomicU64,
    }

    impl Backend for FlakyBackend {
        fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
            let c = self.calls.fetch_add(1, Ordering::Relaxed);
            if c % 2 == 1 {
                Err("flaky".into())
            } else {
                self.inner.run_batch(op, n, rows, xs)
            }
        }
        fn name(&self) -> &'static str {
            "flaky"
        }
    }

    fn config() -> Config {
        Config {
            lanes: vec![(Op::Transform, 64)],
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            queue_cap: 64,
            sigma: 1.0,
            seed: 1,
        }
    }

    #[test]
    fn failing_backend_errors_propagate_to_every_request() {
        let c = Coordinator::start(config(), Arc::new(FailingBackend));
        let mut rng = Rng::new(1);
        let mut rxs = Vec::new();
        for _ in 0..20 {
            rxs.push(c.submit(Op::Transform, rng.gaussian_vec(64)).unwrap());
        }
        for (id, rx) in rxs {
            let resp = rx.recv().expect("a response, even on failure");
            assert_eq!(resp.id, id);
            assert_eq!(resp.result.unwrap_err(), "injected failure");
        }
        let m = c.metrics();
        let (_, lm) = &m[0];
        assert_eq!(lm.failed.load(Ordering::Relaxed), 20);
        assert_eq!(lm.completed.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn flaky_backend_keeps_lane_alive() {
        // a failed batch must not kill the lane: later requests succeed.
        let be = FlakyBackend {
            inner: NativeBackend::new(&[64], 1.0, 1),
            calls: std::sync::atomic::AtomicU64::new(0),
        };
        let c = Coordinator::start(config(), Arc::new(be));
        let mut rng = Rng::new(2);
        let (mut ok, mut err) = (0, 0);
        for _ in 0..30 {
            match c.call(Op::Transform, rng.gaussian_vec(64)) {
                Ok(out) => {
                    assert_eq!(out.as_f32().unwrap().len(), 64);
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(e, "flaky");
                    err += 1;
                }
            }
        }
        assert!(ok > 0, "some requests must succeed");
        assert!(err > 0, "some requests must fail (flaky backend)");
        c.shutdown();
    }
}
