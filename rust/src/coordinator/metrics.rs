//! Lock-free coordinator metrics: per-lane counters and a log-bucketed
//! latency histogram with percentile queries and a JSON dump.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of latency buckets: bucket `i` holds samples in
/// `[2^i, 2^{i+1})` microseconds; bucket 0 holds `< 2 µs`.
const BUCKETS: usize = 32;

/// Latency histogram over microseconds (powers of two).
#[derive(Default)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    total: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate percentile (upper bucket edge), q in [0, 1].
    pub fn percentile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// Per-lane metrics.
#[derive(Default)]
pub struct LaneMetrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    /// Total payload bits shipped in responses — the footprint ledger the
    /// binary lane's 32× compression shows up in (f32/i32 elements count
    /// 32 bits, packed words 64).
    pub output_bits: AtomicU64,
    /// Requests answered with `Deadline` (expired while queued, dropped
    /// before backend time was spent on them).
    pub expired: AtomicU64,
    /// Backend calls that panicked and were caught by the lane (the
    /// fine-grained isolation path, not lane deaths).
    pub panics: AtomicU64,
    /// Lane-thread deaths (lane-fatal panics caught by the supervisor).
    pub lane_failures: AtomicU64,
    /// Supervisor restarts of this lane (each follows a `lane_failures`
    /// increment after the backoff sleep).
    pub restarts: AtomicU64,
    /// Submits shed with `Unavailable` while the circuit breaker was open.
    pub shed_unavailable: AtomicU64,
    /// Times the circuit breaker newly opened (closed→open edges only).
    pub breaker_opens: AtomicU64,
    /// Submits refused with `Throttled` by the per-client token bucket.
    pub throttled: AtomicU64,
    /// Submits refused with `Overloaded` by the queue-delay shedder.
    pub shed_overloaded: AtomicU64,
    /// Submits refused with `Draining` after drain began.
    pub drained: AtomicU64,
    /// Gauge: requests admitted to the lane queue but not yet answered.
    /// Drain polls this to zero. A lane-fatal death loses the in-flight
    /// batch's decrements, so across lane deaths the gauge can overcount
    /// — drain is deadline-bounded, never gauge-trusting.
    pub in_flight: AtomicU64,
    /// Rows executed as part of a multi-row batch (rows in batches of
    /// size ≥ 2 — the ingress coalescing win the bench measures).
    pub coalesced_rows: AtomicU64,
    /// Requests answered by subscribing to another in-flight identical
    /// request's response slot instead of reaching the backend.
    pub dedup_followers: AtomicU64,
    /// Requests answered straight from the response cache.
    pub cache_hits: AtomicU64,
    /// Cache lookups that missed (only counted when the cache was
    /// actually consulted — `no_cache` requests are not misses).
    pub cache_misses: AtomicU64,
    /// Entries evicted from the response cache to stay under capacity.
    pub cache_evictions: AtomicU64,
    /// Gauge: current response-cache occupancy for this lane.
    pub cache_entries: AtomicU64,
    pub latency: Histogram,
}

impl LaneMetrics {
    pub fn new() -> LaneMetrics {
        LaneMetrics::default()
    }

    /// Mean response payload in bytes (completed requests only).
    pub fn mean_response_bytes(&self) -> f64 {
        let c = self.completed.load(Ordering::Relaxed);
        if c == 0 {
            0.0
        } else {
            self.output_bits.load(Ordering::Relaxed) as f64 / 8.0 / c as f64
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "submitted",
                Json::Num(self.submitted.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected",
                Json::Num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "completed",
                Json::Num(self.completed.load(Ordering::Relaxed) as f64),
            ),
            (
                "failed",
                Json::Num(self.failed.load(Ordering::Relaxed) as f64),
            ),
            (
                "batches",
                Json::Num(self.batches.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch", Json::Num(self.mean_batch_size())),
            (
                "output_bits",
                Json::Num(self.output_bits.load(Ordering::Relaxed) as f64),
            ),
            ("mean_response_bytes", Json::Num(self.mean_response_bytes())),
            (
                "expired",
                Json::Num(self.expired.load(Ordering::Relaxed) as f64),
            ),
            (
                "panics",
                Json::Num(self.panics.load(Ordering::Relaxed) as f64),
            ),
            (
                "lane_failures",
                Json::Num(self.lane_failures.load(Ordering::Relaxed) as f64),
            ),
            (
                "restarts",
                Json::Num(self.restarts.load(Ordering::Relaxed) as f64),
            ),
            (
                "shed_unavailable",
                Json::Num(self.shed_unavailable.load(Ordering::Relaxed) as f64),
            ),
            (
                "breaker_opens",
                Json::Num(self.breaker_opens.load(Ordering::Relaxed) as f64),
            ),
            (
                "throttled",
                Json::Num(self.throttled.load(Ordering::Relaxed) as f64),
            ),
            (
                "shed_overloaded",
                Json::Num(self.shed_overloaded.load(Ordering::Relaxed) as f64),
            ),
            (
                "drained",
                Json::Num(self.drained.load(Ordering::Relaxed) as f64),
            ),
            (
                "in_flight",
                Json::Num(self.in_flight.load(Ordering::Relaxed) as f64),
            ),
            (
                "coalesced_rows",
                Json::Num(self.coalesced_rows.load(Ordering::Relaxed) as f64),
            ),
            (
                "dedup_followers",
                Json::Num(self.dedup_followers.load(Ordering::Relaxed) as f64),
            ),
            (
                "cache_hits",
                Json::Num(self.cache_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "cache_misses",
                Json::Num(self.cache_misses.load(Ordering::Relaxed) as f64),
            ),
            (
                "cache_evictions",
                Json::Num(self.cache_evictions.load(Ordering::Relaxed) as f64),
            ),
            (
                "cache_entries",
                Json::Num(self.cache_entries.load(Ordering::Relaxed) as f64),
            ),
            ("latency_mean_us", Json::Num(self.latency.mean_us())),
            (
                "latency_p50_us",
                Json::Num(self.latency.percentile_us(0.50) as f64),
            ),
            (
                "latency_p95_us",
                Json::Num(self.latency.percentile_us(0.95) as f64),
            ),
            (
                "latency_p99_us",
                Json::Num(self.latency.percentile_us(0.99) as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for us in [1u64, 2, 4, 10, 100, 1000, 10_000] {
            for _ in 0..10 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 70);
        let p50 = h.percentile_us(0.5);
        let p95 = h.percentile_us(0.95);
        let p99 = h.percentile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_covers_large_values() {
        let h = Histogram::new();
        h.record_us(u64::MAX / 2);
        assert!(h.percentile_us(0.5) > 0);
    }

    #[test]
    fn lane_metrics_json() {
        let m = LaneMetrics::new();
        m.submitted.store(10, Ordering::Relaxed);
        m.completed.store(9, Ordering::Relaxed);
        m.batches.store(3, Ordering::Relaxed);
        m.batched_rows.store(9, Ordering::Relaxed);
        m.lane_failures.store(2, Ordering::Relaxed);
        m.restarts.store(2, Ordering::Relaxed);
        m.breaker_opens.store(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("submitted").unwrap().as_f64(), Some(10.0));
        assert_eq!(j.get("mean_batch").unwrap().as_f64(), Some(3.0));
        // fault-isolation counters are part of the exported schema
        assert_eq!(j.get("lane_failures").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("restarts").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("breaker_opens").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("expired").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("panics").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("shed_unavailable").unwrap().as_f64(), Some(0.0));
        // overload-protection counters are part of the exported schema
        m.throttled.store(4, Ordering::Relaxed);
        m.shed_overloaded.store(5, Ordering::Relaxed);
        m.drained.store(6, Ordering::Relaxed);
        m.in_flight.store(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("throttled").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("shed_overloaded").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("drained").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("in_flight").unwrap().as_f64(), Some(1.0));
        // ingress counters (coalescing / dedup / response cache) are part
        // of the exported schema
        m.coalesced_rows.store(12, Ordering::Relaxed);
        m.dedup_followers.store(7, Ordering::Relaxed);
        m.cache_hits.store(3, Ordering::Relaxed);
        m.cache_misses.store(8, Ordering::Relaxed);
        m.cache_evictions.store(2, Ordering::Relaxed);
        m.cache_entries.store(6, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("coalesced_rows").unwrap().as_f64(), Some(12.0));
        assert_eq!(j.get("dedup_followers").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("cache_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("cache_misses").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("cache_evictions").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("cache_entries").unwrap().as_f64(), Some(6.0));
        // serializes to valid JSON
        let s = j.to_string();
        assert!(Json::parse(&s).is_ok());
    }
}
