//! Resilient TCP client for the serving protocol: typed retry policy
//! with exponential backoff, full jitter, and a retry *budget*.
//!
//! ## Retry contract
//!
//! The wire taxonomy is closed (machine-checked by lint R4), so the
//! retryable set can be too: [`RETRYABLE_CODES`] lists exactly the
//! codes that mean "the request was refused without being executed and
//! a later attempt may succeed" — queue backpressure (`busy`), breaker
//! and restart windows (`unavailable`, `lane_down`), overload refusals
//! (`throttled`, `overloaded`), shutdown (`draining`), and the fleet
//! tier's replica-exhausted refusal (`shard_down`). Everything else is
//! terminal on the first answer: caller mistakes (`bad_request`,
//! `bad_dim`, `unknown_lane`) would fail identically forever, and
//! executed-but-failed outcomes (`backend`, `panic`, `deadline`,
//! `timeout`) are not refusals at all. `partial` is not an error code
//! at all — it rides on `ok: true` answers as a success-with-flag
//! degradation marker, so it is counted ([`RetryClient::partials`]) and
//! surfaced via [`RetryClient::call_full`], never retried.
//!
//! Retrying after an **I/O error** (connection drop mid-request) is
//! safe here even though the request may have executed: every op is a
//! deterministic pure function of the model seed and the input vector,
//! so re-executing is idempotent. A client of a mutating service could
//! not reuse this policy blindly.
//!
//! ## Backoff and budget
//!
//! Sleep before attempt `k` is `hint + U(0, min(max_backoff,
//! base·2^k))` — the server's `retry_after_ms` hint is the floor (it
//! knows when capacity will exist), full jitter decorrelates the
//! retrying herd. The token *budget* (spent per retry, refilled
//! fractionally per success) caps the retry amplification a broken
//! server sees at `1 + budget_per_success : 1` in steady state —
//! per-request attempt caps alone cannot bound fleet-wide retry storms.

use crate::util::json::Json;
use crate::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The closed set of wire codes a retry may fix. Kept in lockstep with
/// the taxonomy by `wire_codes_round_trip_and_match_roadmap` (every
/// member must carry a `retry_after_ms` hint server-side).
pub const RETRYABLE_CODES: [&str; 7] = [
    "busy",
    "unavailable",
    "lane_down",
    "throttled",
    "overloaded",
    "draining",
    "shard_down",
];

/// Is `code` in [`RETRYABLE_CODES`]?
pub fn is_retryable(code: &str) -> bool {
    RETRYABLE_CODES.contains(&code)
}

/// Retry policy knobs (see module docs for the semantics).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Attempts per logical request (first try included).
    pub max_attempts: u32,
    /// Backoff base: attempt `k` waits up to `base·2^(k-1)` plus hint.
    pub base_backoff: Duration,
    /// Cap on the jittered component of any single backoff.
    pub max_backoff: Duration,
    /// Retry-budget capacity in tokens (1 token = 1 retry).
    pub budget_max: f64,
    /// Tokens refunded per successful request (keeps steady-state retry
    /// amplification ≤ 1 + this).
    pub budget_per_success: f64,
    /// Per-attempt server-side deadline, sent as the wire `timeout_ms`.
    pub request_timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            budget_max: 10.0,
            budget_per_success: 0.1,
            request_timeout: Duration::from_secs(2),
        }
    }
}

/// Terminal outcome of [`RetryClient::call`].
#[derive(Debug, Clone, PartialEq)]
pub enum ClientError {
    /// Non-retryable coded answer — surfaced immediately, never retried.
    Rejected { code: String, error: String },
    /// Retryable code every time, but `max_attempts` exhausted.
    Exhausted { code: String, attempts: u32 },
    /// Retryable, but the client-wide retry budget is empty.
    BudgetExhausted { code: String },
    /// I/O failure on the final attempt.
    Io(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Rejected { code, error } => {
                write!(f, "rejected ({code}): {error}")
            }
            ClientError::Exhausted { code, attempts } => {
                write!(f, "retries exhausted after {attempts} attempts (last: {code})")
            }
            ClientError::BudgetExhausted { code } => {
                write!(f, "retry budget exhausted (last: {code})")
            }
            ClientError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

/// What one wire attempt produced.
enum Attempt {
    /// `ok: true` — carries the whole reply document (so partial markers
    /// survive to the caller), with `result` presence already checked.
    Ok(Json),
    Coded {
        code: String,
        error: String,
        retry_after_ms: Option<u64>,
    },
    Io(String),
}

/// Connection + randomness + budget, serialized under one lock (one
/// in-flight request per client; spawn one client per concurrent caller).
struct ClientState {
    conn: Option<(BufReader<TcpStream>, TcpStream)>,
    rng: Rng,
    budget: f64,
    next_id: u64,
}

/// See module docs. Construct with [`RetryClient::connect`]; `call` is
/// the only request path.
pub struct RetryClient {
    addr: String,
    client_id: Option<String>,
    policy: RetryPolicy,
    state: Mutex<ClientState>,
    /// Total wire attempts (first tries + retries) — observability.
    pub attempts: AtomicU64,
    /// Retries only (attempts beyond each request's first).
    pub retries: AtomicU64,
    /// Reconnects after an I/O error or server-closed connection.
    pub reconnects: AtomicU64,
    /// Successful answers that carried the `partial` degradation marker
    /// (fleet-tier scatter-gather with at least one shard missing).
    pub partials: AtomicU64,
}

impl RetryClient {
    /// Lazy client: no connection is made until the first call. `addr`
    /// is `host:port`; `client_id` rides every request for admission
    /// accounting (`None` lets the server fall back to the peer address).
    pub fn connect(addr: &str, client_id: Option<&str>, policy: RetryPolicy) -> RetryClient {
        RetryClient {
            addr: addr.to_string(),
            client_id: client_id.map(str::to_string),
            policy,
            state: Mutex::new(ClientState {
                conn: None,
                rng: Rng::new(0xC11E_4701),
                budget: policy.budget_max,
                next_id: 1,
            }),
            attempts: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            partials: AtomicU64::new(0),
        }
    }

    /// One logical request: returns the wire `result` value, retrying
    /// retryable refusals per the policy. Exactly one terminal outcome
    /// per call, always.
    pub fn call(&self, op: &str, vector: &[f32]) -> Result<Json, ClientError> {
        self.call_priority(op, vector, super::admission::PRIORITY_NORMAL)
    }

    /// [`RetryClient::call`] with an explicit shedding priority.
    pub fn call_priority(
        &self,
        op: &str,
        vector: &[f32],
        priority: u8,
    ) -> Result<Json, ClientError> {
        self.call_full_priority(op, vector, priority)
            .map(|doc| doc.get("result").cloned().unwrap_or(Json::Null))
    }

    /// One logical request returning the **whole reply document**, not
    /// just `result` — callers that care about success-with-flag markers
    /// (the fleet tier's `code: "partial"` + `degraded` shard list) read
    /// them from here; [`RetryClient::call`] strips down to `result`.
    pub fn call_full(&self, op: &str, vector: &[f32]) -> Result<Json, ClientError> {
        self.call_full_priority(op, vector, super::admission::PRIORITY_NORMAL)
    }

    /// [`RetryClient::call_full`] with an explicit shedding priority.
    pub fn call_full_priority(
        &self,
        op: &str,
        vector: &[f32],
        priority: u8,
    ) -> Result<Json, ClientError> {
        let mut state = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            // ORDERING: Relaxed — observability counters only.
            self.attempts.fetch_add(1, Ordering::Relaxed);
            if attempt > 1 {
                self.retries.fetch_add(1, Ordering::Relaxed);
            }
            let (code, hint) = match self.try_once(&mut state, op, vector, priority) {
                Attempt::Ok(doc) => {
                    state.budget =
                        (state.budget + self.policy.budget_per_success).min(self.policy.budget_max);
                    // a partial is a success on the wire (`ok: true`)
                    // carrying a degradation marker — counted and
                    // surfaced, never retried
                    if doc.get("code").and_then(Json::as_str) == Some(super::codec::CODE_PARTIAL) {
                        // ORDERING: Relaxed — observability counter only.
                        self.partials.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(doc);
                }
                Attempt::Coded {
                    code,
                    error,
                    retry_after_ms,
                } => {
                    if !is_retryable(&code) {
                        return Err(ClientError::Rejected { code, error });
                    }
                    (code, retry_after_ms)
                }
                Attempt::Io(e) => {
                    // drop the stream: the next attempt reconnects fresh
                    // (safe to re-send — the compute is idempotent)
                    state.conn = None;
                    if attempt >= self.policy.max_attempts {
                        return Err(ClientError::Io(e));
                    }
                    ("io".to_string(), None)
                }
            };
            if attempt >= self.policy.max_attempts {
                return Err(ClientError::Exhausted {
                    code,
                    attempts: attempt,
                });
            }
            if state.budget < 1.0 {
                return Err(ClientError::BudgetExhausted { code });
            }
            state.budget -= 1.0;
            let sleep = self.backoff(&mut state.rng, attempt, hint);
            std::thread::sleep(sleep);
        }
    }

    /// Full-jitter backoff before the next attempt: the server's hint is
    /// the floor, `U(0, min(max, base·2^(attempt-1)))` rides on top.
    fn backoff(&self, rng: &mut Rng, attempt: u32, hint_ms: Option<u64>) -> Duration {
        let exp = self
            .policy
            .base_backoff
            .saturating_mul(1u32 << (attempt - 1).min(16));
        let cap = exp.min(self.policy.max_backoff);
        let jitter = cap.mul_f64(rng.uniform());
        Duration::from_millis(hint_ms.unwrap_or(0)) + jitter
    }

    /// One wire attempt: (re)connect if needed, send, read the matching
    /// reply line.
    fn try_once(
        &self,
        state: &mut ClientState,
        op: &str,
        vector: &[f32],
        priority: u8,
    ) -> Attempt {
        if state.conn.is_none() {
            match self.dial() {
                Ok(conn) => {
                    if state.next_id > 1 {
                        // ORDERING: Relaxed — observability counter only.
                        self.reconnects.fetch_add(1, Ordering::Relaxed);
                    }
                    state.conn = Some(conn);
                }
                Err(e) => return Attempt::Io(e),
            }
        }
        let id = state.next_id;
        state.next_id += 1;
        let mut req = vec![
            ("id".to_string(), Json::Num(id as f64)),
            ("op".to_string(), Json::Str(op.to_string())),
            (
                "vector".to_string(),
                Json::Arr(vector.iter().map(|x| Json::Num(*x as f64)).collect()),
            ),
            (
                "timeout_ms".to_string(),
                Json::Num(self.policy.request_timeout.as_millis() as f64),
            ),
            ("priority".to_string(), Json::Num(priority as f64)),
        ];
        if let Some(cid) = &self.client_id {
            req.push(("client_id".to_string(), Json::Str(cid.clone())));
        }
        let line = format!("{}\n", Json::Obj(req.into_iter().collect()));
        let (reader, writer) = state.conn.as_mut().expect("connected above");
        if let Err(e) = writer.write_all(line.as_bytes()).and_then(|()| writer.flush()) {
            return Attempt::Io(e.to_string());
        }
        let mut reply = String::new();
        match reader.read_line(&mut reply) {
            Ok(0) => return Attempt::Io("server closed the connection".to_string()),
            Ok(_) => {}
            Err(e) => return Attempt::Io(e.to_string()),
        }
        let doc = match Json::parse(reply.trim()) {
            Ok(d) => d,
            Err(e) => return Attempt::Io(format!("unparseable reply: {e:?}")),
        };
        // a reply for a different id means the stream lost framing
        // (e.g. a partial_write fault truncated the previous reply) —
        // treat as an I/O failure and reconnect
        if doc.get("id").and_then(Json::as_f64) != Some(id as f64) {
            return Attempt::Io("reply id mismatch (stream desynced)".to_string());
        }
        if doc.get("ok").and_then(Json::as_bool) == Some(true) {
            match doc.get("result") {
                Some(_) => Attempt::Ok(doc),
                None => Attempt::Io("ok reply without result".to_string()),
            }
        } else {
            Attempt::Coded {
                code: doc
                    .get("code")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                error: doc
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                retry_after_ms: doc
                    .get("retry_after_ms")
                    .and_then(Json::as_f64)
                    .map(|v| v as u64),
            }
        }
    }

    fn dial(&self) -> Result<(BufReader<TcpStream>, TcpStream), String> {
        let stream = TcpStream::connect(&self.addr).map_err(|e| e.to_string())?;
        // read bound = server deadline + slack, so a hung server surfaces
        // as a retryable I/O timeout instead of a client hang
        stream
            .set_read_timeout(Some(self.policy.request_timeout + Duration::from_secs(1)))
            .map_err(|e| e.to_string())?;
        stream
            .set_write_timeout(Some(Duration::from_secs(5)))
            .map_err(|e| e.to_string())?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok((reader, stream))
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::{CODE_BAD_REQUEST, CODE_TIMEOUT};
    use super::*;

    #[test]
    fn retryable_set_matches_taxonomy_hints() {
        use super::super::SubmitError;
        // every RETRYABLE_CODES member is a real taxonomy code with a
        // server-side retry hint; no caller-mistake code sneaks in
        let submit = [
            SubmitError::Busy,
            SubmitError::UnknownLane,
            SubmitError::BadDim,
            SubmitError::Closed,
            SubmitError::LaneDown,
            SubmitError::Unavailable,
            SubmitError::Throttled { retry_after_ms: 1 },
            SubmitError::Overloaded { retry_after_ms: 1 },
            SubmitError::Draining { retry_after_ms: 1 },
        ];
        for code in RETRYABLE_CODES {
            if code == super::super::codec::CODE_SHARD_DOWN {
                // fleet-tier refusal: born in the router, not a
                // SubmitError — the codec pins its server-side hint
                assert!(super::super::codec::SHARD_DOWN_RETRY_MS > 0);
                continue;
            }
            let e = submit
                .iter()
                .find(|e| e.code() == code)
                .unwrap_or_else(|| panic!("retryable '{code}' must exist in the taxonomy"));
            assert!(e.retry_after_ms().is_some(), "'{code}' must carry a hint");
        }
        assert!(!is_retryable(CODE_BAD_REQUEST));
        assert!(!is_retryable(CODE_TIMEOUT));
        // partial is a success-with-flag marker, never a retryable refusal
        assert!(!is_retryable(super::super::codec::CODE_PARTIAL));
        assert!(!is_retryable("bad_dim"));
        assert!(!is_retryable("unknown_lane"));
        assert!(!is_retryable("deadline"));
        assert!(!is_retryable("backend"));
        assert!(!is_retryable("panic"));
        assert!(!is_retryable("closed"));
    }

    #[test]
    fn backoff_is_hint_floored_and_capped() {
        let policy = RetryPolicy {
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(80),
            ..RetryPolicy::default()
        };
        let client = RetryClient::connect("127.0.0.1:1", None, policy);
        let mut rng = Rng::new(7);
        for attempt in 1..=10 {
            let d = client.backoff(&mut rng, attempt, Some(25));
            assert!(d >= Duration::from_millis(25), "hint is the floor");
            assert!(
                d <= Duration::from_millis(25 + 80),
                "jitter never exceeds max_backoff above the hint"
            );
        }
        // exponential growth before the cap bites
        let no_hint: Vec<Duration> = (1..=4)
            .map(|a| {
                // max over many draws approximates the envelope
                (0..200)
                    .map(|_| client.backoff(&mut rng, a, None))
                    .max()
                    .unwrap()
            })
            .collect();
        assert!(no_hint[1] > no_hint[0], "envelope doubles per attempt");
        assert!(no_hint[3] <= Duration::from_millis(80), "cap holds");
    }

    #[test]
    fn budget_exhaustion_is_a_typed_terminal_outcome() {
        // a dead address: every attempt is an I/O error, and a tiny
        // budget must stop the loop before max_attempts does
        let policy = RetryPolicy {
            max_attempts: 50,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(2),
            budget_max: 2.0,
            ..RetryPolicy::default()
        };
        // reserved TEST-NET-3 address: connects fail fast (refused) or
        // not at all — either way attempts consume budget
        let client = RetryClient::connect("127.0.0.1:9", None, policy);
        let err = client.call("transform", &[0.0; 4]).unwrap_err();
        match err {
            ClientError::BudgetExhausted { .. } | ClientError::Io(_) => {}
            other => panic!("expected budget/io terminal, got {other:?}"),
        }
        let attempts = client.attempts.load(Ordering::Relaxed);
        assert!(
            attempts <= 4,
            "2-token budget must stop retries early, saw {attempts} attempts"
        );
    }
}
