//! Johnson–Lindenstrauss transforms (paper §1/§2's motivating application).
//!
//! A JLT embeds `R^n` into `R^k` (`k ≪ n`) while preserving pairwise
//! Euclidean distances to `1 ± ε`. With a TripleSpin projection the embed
//! costs `O(n log n)` instead of `O(kn)` — the "fast JLT" line of work
//! [Ailon–Chazelle, Ailon–Liberty, Vybíral] that the TripleSpin family
//! subsumes (all those constructions are members).

use crate::linalg::vecops::euclidean;
use crate::linalg::Workspace;
use crate::runtime::WorkerPool;
use crate::transform::{make, Family, Transform};
use crate::util::rng::Rng;

/// A `k`-dimensional JL embedding backed by any TripleSpin family.
pub struct Jlt {
    transform: Box<dyn Transform>,
    k: usize,
    scale: f32,
}

impl Jlt {
    /// Embed into `k` dims; inputs of dim `n` (padded to the next power of
    /// two internally).
    pub fn new(family: Family, k: usize, n: usize, seed: u64) -> Jlt {
        let n_pad = n.next_power_of_two();
        let mut rng = Rng::new(seed);
        let transform = make(family, k, n_pad, n_pad, &mut rng);
        Jlt {
            transform,
            k,
            // rows act like N(0,1)^n directions; E||Tx||² = k||x||², so
            // normalize by 1/√k to make the embedding isometric on average.
            scale: (1.0 / (k as f64).sqrt()) as f32,
        }
    }

    pub fn dim_out(&self) -> usize {
        self.k
    }

    /// Embed one vector into `out` (`out.len() == dim_out()`), all scratch
    /// drawn from `ws` — the zero-allocation path.
    pub fn embed_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        debug_assert_eq!(out.len(), self.k);
        self.transform.apply_padded_into(x, out, ws);
        for v in out.iter_mut() {
            *v *= self.scale;
        }
    }

    /// Embed one vector. Thin allocating wrapper over [`Jlt::embed_into`].
    pub fn embed(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        let mut ws = Workspace::new();
        self.embed_into(x, &mut out, &mut ws);
        out
    }

    /// Embed a row-major batch (`rows` inputs of the transform's padded
    /// input dim) into `rows * dim_out()` outputs, sharding rows across the
    /// persistent worker pool. Bit-identical per row to [`Jlt::embed_into`].
    pub fn embed_batch_into(&self, xs: &[f32], out: &mut [f32], pool: &WorkerPool) {
        let n = self.transform.dim_in();
        debug_assert_eq!(xs.len() % n, 0);
        debug_assert_eq!(out.len(), (xs.len() / n) * self.k);
        self.transform.apply_batch_into(xs, out, pool);
        for v in out.iter_mut() {
            *v *= self.scale;
        }
    }

    /// Padded input dimensionality of the underlying transform (batch rows
    /// for [`Jlt::embed_batch_into`] must be zero-padded to this length).
    pub fn dim_in_padded(&self) -> usize {
        self.transform.dim_in()
    }

    /// The number of dimensions the classic JL lemma prescribes for `m`
    /// points at distortion `eps`: `k = ⌈8 ln(m) / eps²⌉`.
    pub fn required_dims(m: usize, eps: f64) -> usize {
        ((8.0 * (m as f64).ln()) / (eps * eps)).ceil() as usize
    }
}

/// Worst-case pairwise distance distortion of an embedding over a point
/// set: `max |  ||f(x)-f(y)|| / ||x-y||  - 1 |`.
pub fn max_distortion(jlt: &Jlt, points: &[Vec<f32>]) -> f64 {
    // one padded input batch + one flat output matrix: all embeddings run
    // as a single sweep over the persistent worker pool
    let k = jlt.dim_out();
    let np = jlt.dim_in_padded();
    let mut xs = vec![0.0f32; points.len() * np];
    for (p, row) in points.iter().zip(xs.chunks_exact_mut(np)) {
        row[..p.len()].copy_from_slice(p);
    }
    let mut embedded = vec![0.0f32; points.len() * k];
    jlt.embed_batch_into(&xs, &mut embedded, WorkerPool::global());
    let mut worst = 0.0f64;
    for i in 0..points.len() {
        for j in i + 1..points.len() {
            let orig = euclidean(&points[i], &points[j]);
            if orig < 1e-9 {
                continue;
            }
            let emb = euclidean(&embedded[i * k..(i + 1) * k], &embedded[j * k..(j + 1) * k]);
            worst = worst.max((emb / orig - 1.0).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    fn cloud(m: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| rng.gaussian_vec(n)).collect()
    }

    #[test]
    fn distances_preserved_dense_and_structured() {
        let pts = cloud(30, 512, 1);
        for fam in [Family::Dense, Family::Hd3, Family::Circulant] {
            let jlt = Jlt::new(fam, 256, 512, 7);
            let d = max_distortion(&jlt, &pts);
            assert!(d < 0.35, "{fam:?}: max distortion {d}");
        }
    }

    #[test]
    fn distortion_shrinks_with_k() {
        let pts = cloud(25, 512, 2);
        let avg = |k: usize| -> f64 {
            (0..3)
                .map(|s| max_distortion(&Jlt::new(Family::Hd3, k, 512, 10 + s), &pts))
                .sum::<f64>()
                / 3.0
        };
        let d32 = avg(32);
        let d128 = avg(128);
        let d512 = avg(512);
        assert!(d128 < d32, "{d128} !< {d32}");
        assert!(d512 < d128, "{d512} !< {d128}");
    }

    #[test]
    fn embedding_is_linear() {
        for_all(12, |g| {
            let n = 128;
            let jlt = Jlt::new(Family::Hdg, 64, n, g.u64());
            let x = g.gaussian_vec(n);
            let y = g.gaussian_vec(n);
            let a = g.f32_in(-2.0, 2.0);
            let comb: Vec<f32> = x.iter().zip(&y).map(|(u, v)| a * u + v).collect();
            let lhs = jlt.embed(&comb);
            let ex = jlt.embed(&x);
            let ey = jlt.embed(&y);
            for i in 0..64 {
                let rhs = a * ex[i] + ey[i];
                assert!((lhs[i] - rhs).abs() < 2e-2 * (1.0 + rhs.abs()));
            }
        });
    }

    #[test]
    fn norm_preserved_in_expectation() {
        let n = 256;
        let x = Rng::new(3).unit_vec(n);
        let mut total = 0.0;
        let trials = 50;
        for s in 0..trials {
            let jlt = Jlt::new(Family::Hd3, 128, n, 100 + s);
            let y = jlt.embed(&x);
            total += y.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        }
        let avg = total / trials as f64;
        assert!((avg - 1.0).abs() < 0.1, "E||f(x)||² = {avg}");
    }

    #[test]
    fn batch_embedding_matches_single_bitwise() {
        let n = 200; // pads to 256
        let jlt = Jlt::new(Family::Toeplitz, 48, n, 11);
        let np = jlt.dim_in_padded();
        let pts = cloud(30, n, 12);
        let mut xs = vec![0.0f32; pts.len() * np];
        for (p, row) in pts.iter().zip(xs.chunks_exact_mut(np)) {
            row[..p.len()].copy_from_slice(p);
        }
        let mut out = vec![0.0f32; pts.len() * 48];
        jlt.embed_batch_into(&xs, &mut out, WorkerPool::global());
        for (p, got) in pts.iter().zip(out.chunks_exact(48)) {
            assert_eq!(got, &jlt.embed(p)[..]);
        }
    }

    #[test]
    fn required_dims_formula() {
        let k = Jlt::required_dims(1000, 0.5);
        assert_eq!(k, ((8.0 * 1000f64.ln()) / 0.25).ceil() as usize);
        assert!(Jlt::required_dims(1000, 0.1) > k);
    }

    #[test]
    fn non_pow2_input_padded() {
        let pts = cloud(10, 300, 4);
        let jlt = Jlt::new(Family::Hd3, 64, 300, 5);
        let d = max_distortion(&jlt, &pts);
        assert!(d < 1.0);
    }
}
