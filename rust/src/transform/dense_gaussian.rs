//! The unstructured baseline: a dense i.i.d. Gaussian matrix.
//!
//! This is the `G` every TripleSpin member is measured against (Table 1's
//! `time(G)/time(T)`, Figures 1/2/4's accuracy reference).

use super::Transform;
use crate::linalg::{Mat, Workspace};
use crate::util::rng::Rng;

/// Dense `m x n` matrix with i.i.d. `N(0,1)` entries.
pub struct DenseGaussian {
    mat: Mat,
}

impl DenseGaussian {
    pub fn new(m: usize, n: usize, rng: &mut Rng) -> DenseGaussian {
        DenseGaussian {
            mat: Mat::gaussian(m, n, rng),
        }
    }

    /// Access the underlying matrix (tests compare against it directly).
    pub fn mat(&self) -> &Mat {
        &self.mat
    }
}

impl Transform for DenseGaussian {
    fn dim_in(&self) -> usize {
        self.mat.cols
    }

    fn dim_out(&self) -> usize {
        self.mat.rows
    }

    fn apply_into(&self, x: &[f32], out: &mut [f32], _ws: &mut Workspace) {
        self.mat.matvec_into(x, out);
    }

    /// A dense matvec is `m * n` multiply-adds — far above the structured
    /// families, so dense batches clear the pool's work gate early.
    fn batch_work_per_row(&self) -> usize {
        self.mat.rows * self.mat.cols
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn param_bits(&self) -> usize {
        self.mat.rows * self.mat.cols * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_apply() {
        let mut rng = Rng::new(1);
        let t = DenseGaussian::new(3, 5, &mut rng);
        assert_eq!(t.dim_out(), 3);
        assert_eq!(t.dim_in(), 5);
        let y = t.apply(&[1.0, 0.0, 0.0, 0.0, 0.0]);
        // G e_0 is the first column
        for i in 0..3 {
            assert_eq!(y[i], t.mat().at(i, 0));
        }
    }

    #[test]
    fn param_bits() {
        let mut rng = Rng::new(2);
        let t = DenseGaussian::new(4, 8, &mut rng);
        assert_eq!(t.param_bits(), 4 * 8 * 32);
    }
}
