//! The TripleSpin structured-matrix family (paper §3).
//!
//! Every member implements [`Transform`]: a linear map `R^n -> R^m` whose
//! rows behave like i.i.d. Gaussian directions but which applies in
//! `O(n log n)` and stores `O(n)` parameters (sometimes only random bits).
//!
//! Implemented members (Lemma 1 variants plus the experimental section's):
//!
//! | name                 | structure                      | params stored |
//! |----------------------|--------------------------------|---------------|
//! | `dense`              | unstructured Gaussian `G`      | `m·n` floats  |
//! | `hd3`                | `√n·HD3·HD2·HD1`               | `3n` bits     |
//! | `hdg`                | `√n·HDg·HD2·HD1`               | `n` floats + `2n` bits |
//! | `circulant`          | `G_circ·D2·HD1`                | `n` floats + `2n` bits |
//! | `toeplitz`           | `G_Toeplitz·D2·HD1`            | `2n-1` floats + `2n` bits |
//! | `hankel`             | `G_Hankel·D2·HD1`              | `2n-1` floats + `2n` bits |
//! | `skew_circulant`     | `G_skew-circ·D2·HD1`           | `n` floats + `2n` bits |
//!
//! Rectangular / stacked shapes (paper §3.1) are provided by
//! [`blocks::StackedTransform`].

pub mod blocks;
pub mod circulant;
pub mod dense_gaussian;
pub mod hd;

pub use blocks::StackedTransform;
pub use circulant::StructuredGaussian;
pub use dense_gaussian::DenseGaussian;
pub use hd::{HdChain, SignDiag};

use crate::linalg::Workspace;
use crate::runtime::pool::{shard_rows, WorkerPool};
use crate::util::rng::Rng;

/// A randomized linear transform `R^{dim_in} -> R^{dim_out}` standing in for
/// a Gaussian projection matrix.
///
/// The execution surface is **batch-first and zero-allocation**: the one
/// required compute method is [`Transform::apply_into`], which draws every
/// intermediate buffer from a caller-owned [`Workspace`]. Batches go through
/// [`Transform::apply_batch_into`], which shards rows across the persistent
/// [`WorkerPool`] (env-tunable via `TS_WORKERS`) — worker threads are
/// spawned once and live for the pool's lifetime, each driving the family's
/// serial batch kernel with its own pinned workspace, so steady state pays
/// zero thread spawns and zero heap allocations per batch. The allocating
/// [`Transform::apply`] / [`Transform::apply_batch`] remain as thin wrappers
/// for call sites off the hot path.
pub trait Transform: Send + Sync {
    /// Input dimensionality `n` (callers zero-pad shorter vectors).
    fn dim_in(&self) -> usize;

    /// Output dimensionality `m`.
    fn dim_out(&self) -> usize;

    /// `out = G_struct x`, all scratch drawn from `ws` — the
    /// zero-allocation hot path (no heap traffic once `ws` is warm).
    /// `x.len() == dim_in()`, `out.len() == dim_out()`.
    fn apply_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace);

    /// Human-readable family name (stable; used by benches and the CLI).
    fn name(&self) -> &'static str;

    /// Number of stored parameters, counting a ±1 entry as one bit and a
    /// float as 32 bits. Reported by the compression tables.
    fn param_bits(&self) -> usize;

    /// Bits the parameters *actually occupy in memory*. Families whose
    /// Rademacher diagonals are packed into `u64` sign bitmasks
    /// ([`hd::SignDiag`]) report the real packed footprint (≈ `n` bits per
    /// discrete diagonal, not `32n`); the default assumes storage matches
    /// the model-theoretic [`Transform::param_bits`].
    fn stored_bits(&self) -> usize {
        self.param_bits()
    }

    /// `y = G_struct x`. Thin allocating wrapper over
    /// [`Transform::apply_into`].
    fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim_out()];
        let mut ws = Workspace::new();
        self.apply_into(x, &mut out, &mut ws);
        out
    }

    /// Like [`Transform::apply_into`] but accepting inputs shorter than
    /// `dim_in()`, zero-padded through workspace scratch (`take_f32` hands
    /// out zeroed buffers, so only the prefix copy is paid). The shared
    /// padding path for every consumer of Hadamard-based families.
    fn apply_padded_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let n = self.dim_in();
        debug_assert!(x.len() <= n);
        if x.len() == n {
            self.apply_into(x, out, ws);
        } else {
            let mut padded = ws.take_f32(n);
            padded[..x.len()].copy_from_slice(x);
            self.apply_into(&padded, out, ws);
            ws.put_f32(padded);
        }
    }

    /// Estimated per-row batch cost in ~f32-butterfly-op units, feeding the
    /// worker pool's work gate ([`WorkerPool::workers_for_work`]): batches
    /// whose total estimate cannot give every worker
    /// `min_work_per_worker` units stay on the caller thread. The default
    /// assumes one FWHT-like `n log n` pass; families with heavier kernels
    /// (f64 FFTs, dense matvecs) override it so their batches fan out
    /// sooner.
    fn batch_work_per_row(&self) -> usize {
        let n = self.dim_in().max(2);
        n * (n.ilog2() as usize + 1)
    }

    /// Single-threaded batch kernel over row-major rows. Families override
    /// this with batch-level kernels (row-resident multi-stage pipelines,
    /// FFT scratch reuse across rows); the default loops
    /// [`Transform::apply_into`].
    fn apply_batch_serial(&self, xs: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let n = self.dim_in();
        let m = self.dim_out();
        debug_assert_eq!(xs.len() % n, 0);
        debug_assert_eq!(out.len() / m.max(1) * n, xs.len());
        for (row, dst) in xs.chunks_exact(n).zip(out.chunks_exact_mut(m)) {
            self.apply_into(row, dst, ws);
        }
    }

    /// Batch-first entry point: apply to each row of a row-major batch,
    /// writing row outputs into `out` (`rows * dim_out()` elements). Rows
    /// shard across the persistent [`WorkerPool`] — at most
    /// [`WorkerPool::workers_for`] workers (so no worker gets fewer than
    /// `MIN_ROWS_PER_WORKER` rows), each executing the family's serial
    /// batch kernel against its pinned, batch-to-batch-reused
    /// [`Workspace`]. Sub-threshold batches run on the caller thread and
    /// never start the pool.
    fn apply_batch_into(&self, xs: &[f32], out: &mut [f32], pool: &WorkerPool) {
        let n = self.dim_in();
        let m = self.dim_out();
        debug_assert_eq!(xs.len() % n.max(1), 0);
        let rows = if n == 0 { 0 } else { xs.len() / n };
        debug_assert_eq!(out.len(), rows * m);
        if rows == 0 {
            return;
        }
        let out_ptr = out.as_mut_ptr() as usize;
        shard_rows(pool, rows, self.batch_work_per_row(), &|lo, hi, _slot, ws| {
            let xc = &xs[lo * n..hi * n];
            // SAFETY: shard_rows hands out disjoint, covering row ranges,
            // and WorkerPool::run blocks until every worker acked — no two
            // workers alias, no write outlives this call.
            let oc = unsafe {
                std::slice::from_raw_parts_mut((out_ptr as *mut f32).add(lo * m), (hi - lo) * m)
            };
            self.apply_batch_serial(xc, oc, ws);
        });
    }

    /// Apply to each row of a row-major batch, concatenating outputs. Thin
    /// allocating wrapper over [`Transform::apply_batch_into`] on the
    /// process-wide pool.
    fn apply_batch(&self, xs: &[f32]) -> Vec<f32> {
        let n = self.dim_in();
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        let mut out = vec![0.0f32; rows * self.dim_out()];
        self.apply_batch_into(xs, &mut out, WorkerPool::global());
        out
    }

    /// A [`Workspace`] pre-warmed for this transform: one throwaway apply
    /// populates the buffer pools, so every subsequent
    /// [`Transform::apply_into`] through it is allocation-free.
    fn make_workspace(&self) -> Workspace {
        let mut ws = Workspace::new();
        let x = vec![0.0f32; self.dim_in()];
        let mut out = vec![0.0f32; self.dim_out()];
        self.apply_into(&x, &mut out, &mut ws);
        ws
    }
}

/// The transform families the library can construct by name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Unstructured i.i.d. Gaussian baseline.
    Dense,
    /// `√n · HD3 HD2 HD1` — fully discrete, bit-only storage.
    Hd3,
    /// `√n · HDg HD2 HD1` — Gaussian last diagonal.
    Hdg,
    /// `G_circ · D2 · H D1` — Gaussian circulant top block.
    Circulant,
    /// `G_Toeplitz · D2 · H D1`.
    Toeplitz,
    /// `G_Hankel · D2 · H D1`.
    Hankel,
    /// `G_skew-circ · D2 · H D1` (the experiments' `G_skew-circ D2HD1`).
    SkewCirculant,
}

impl Family {
    /// All structured members (everything except the dense baseline).
    pub const STRUCTURED: [Family; 6] = [
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
        Family::Hankel,
        Family::SkewCirculant,
    ];

    /// The four variants Figure 1 / Figure 2 / Table 1 sweep.
    pub const PAPER_SET: [Family; 4] = [
        Family::Toeplitz,
        Family::SkewCirculant,
        Family::Hdg,
        Family::Hd3,
    ];

    pub fn parse(s: &str) -> Option<Family> {
        Some(match s {
            "dense" | "gaussian" => Family::Dense,
            "hd3" => Family::Hd3,
            "hdg" => Family::Hdg,
            "circulant" | "circ" => Family::Circulant,
            "toeplitz" => Family::Toeplitz,
            "hankel" => Family::Hankel,
            "skew" | "skew_circulant" | "skew-circulant" => Family::SkewCirculant,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Family::Dense => "dense",
            Family::Hd3 => "hd3",
            Family::Hdg => "hdg",
            Family::Circulant => "circulant",
            Family::Toeplitz => "toeplitz",
            Family::Hankel => "hankel",
            Family::SkewCirculant => "skew_circulant",
        }
    }

    /// Display label matching the paper's notation.
    pub fn label(&self) -> &'static str {
        match self {
            Family::Dense => "G (unstructured)",
            Family::Hd3 => "HD3 HD2 HD1",
            Family::Hdg => "HDg HD2 HD1",
            Family::Circulant => "Gcirc D2 HD1",
            Family::Toeplitz => "GToeplitz D2 HD1",
            Family::Hankel => "GHankel D2 HD1",
            Family::SkewCirculant => "Gskew-circ D2 HD1",
        }
    }
}

/// Build a **square** `n x n` transform of the given family. `n` must be a
/// power of two for every Hadamard-based family (callers zero-pad; see
/// [`crate::linalg::fwht::next_pow2`]).
pub fn make_square(family: Family, n: usize, rng: &mut Rng) -> Box<dyn Transform> {
    match family {
        Family::Dense => Box::new(DenseGaussian::new(n, n, rng)),
        Family::Hd3 => Box::new(HdChain::hd3(n, rng)),
        Family::Hdg => Box::new(HdChain::hdg(n, rng)),
        Family::Circulant => Box::new(StructuredGaussian::circulant(n, rng)),
        Family::Toeplitz => Box::new(StructuredGaussian::toeplitz(n, rng)),
        Family::Hankel => Box::new(StructuredGaussian::hankel(n, rng)),
        Family::SkewCirculant => Box::new(StructuredGaussian::skew_circulant(n, rng)),
    }
}

/// Build a `k x n` transform: square for structured families truncated /
/// stacked per §3.1 (block size `m` rows, `m <= n`), or a dense `k x n`
/// Gaussian for [`Family::Dense`].
pub fn make(family: Family, k: usize, n: usize, m: usize, rng: &mut Rng) -> Box<dyn Transform> {
    match family {
        Family::Dense => Box::new(DenseGaussian::new(k, n, rng)),
        _ => Box::new(StackedTransform::new(family, k, n, m, rng)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dot, norm2};
    use crate::util::prop::for_all;

    /// Shared statistical check: across many random constructions, the
    /// projection of a fixed unit vector should have ~N(0,1) marginals.
    fn marginal_check(family: Family) {
        let n = 64;
        let mut rng = Rng::new(100 + family as u64);
        let x = rng.unit_vec(n);
        let mut samples: Vec<f64> = Vec::new();
        for trial in 0..200 {
            let t = make_square(family, n, &mut Rng::new(1000 + trial));
            let y = t.apply(&x);
            samples.push(y[0] as f64);
            samples.push(y[n / 2] as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
            / (samples.len() - 1) as f64;
        assert!(mean.abs() < 0.15, "{family:?} mean={mean}");
        assert!(
            (var - 1.0).abs() < 0.30,
            "{family:?} var={var} (want ~1: rows act like N(0,1) directions)"
        );
    }

    #[test]
    fn all_families_gaussian_like_marginals() {
        for f in [Family::Dense, Family::Hd3, Family::Hdg, Family::Circulant] {
            marginal_check(f);
        }
    }

    #[test]
    fn more_families_gaussian_like_marginals() {
        for f in [Family::Toeplitz, Family::Hankel, Family::SkewCirculant] {
            marginal_check(f);
        }
    }

    #[test]
    fn linearity_of_every_family() {
        for_all(12, |g| {
            let n = 32;
            let fam = *g.choose(&[
                Family::Dense,
                Family::Hd3,
                Family::Hdg,
                Family::Circulant,
                Family::Toeplitz,
                Family::Hankel,
                Family::SkewCirculant,
            ]);
            let t = make_square(fam, n, &mut Rng::new(g.u64()));
            let x = g.gaussian_vec(n);
            let y = g.gaussian_vec(n);
            let a = g.f32_in(-2.0, 2.0);
            let combined: Vec<f32> = x.iter().zip(&y).map(|(u, v)| a * u + v).collect();
            let lhs = t.apply(&combined);
            let tx = t.apply(&x);
            let ty = t.apply(&y);
            for i in 0..n {
                let rhs = a * tx[i] + ty[i];
                assert!(
                    (lhs[i] - rhs).abs() < 2e-2 * (1.0 + rhs.abs()),
                    "{fam:?} i={i}: {} vs {rhs}",
                    lhs[i]
                );
            }
        });
    }

    #[test]
    fn expected_norm_preservation() {
        // E||G_struct x||^2 = n ||x||^2 for all families (rows ~ N(0,1)^n).
        for fam in [Family::Hd3, Family::Hdg, Family::Circulant, Family::Toeplitz] {
            let n = 64;
            let x = Rng::new(5).unit_vec(n);
            let mut total = 0.0f64;
            let trials = 100;
            for s in 0..trials {
                let t = make_square(fam, n, &mut Rng::new(7_000 + s));
                let y = t.apply(&x);
                total += norm2(&y).powi(2);
            }
            let avg = total / trials as f64;
            assert!(
                (avg / n as f64 - 1.0).abs() < 0.25,
                "{fam:?}: E||y||^2/n = {}",
                avg / n as f64
            );
        }
    }

    #[test]
    fn rows_nearly_orthogonal_hd3() {
        // Theorem 5.1's mechanism: distinct rows of the structured matrix
        // are near-orthogonal after normalization.
        let n = 256;
        let t = make_square(Family::Hd3, n, &mut Rng::new(3));
        // extract rows by applying to canonical basis vectors: row_i = (G e_j)_i
        // -> build full matrix column by column.
        let mut cols: Vec<Vec<f32>> = Vec::with_capacity(n);
        for j in 0..n {
            let mut e = vec![0.0f32; n];
            e[j] = 1.0;
            cols.push(t.apply(&e));
        }
        let row = |i: usize| -> Vec<f32> { (0..n).map(|j| cols[j][i]).collect() };
        let r0 = row(0);
        let r1 = row(n / 3);
        let r2 = row(2 * n / 3);
        let c01 = dot(&r0, &r1) / (norm2(&r0) * norm2(&r1));
        let c02 = dot(&r0, &r2) / (norm2(&r0) * norm2(&r2));
        let c12 = dot(&r1, &r2) / (norm2(&r1) * norm2(&r2));
        for c in [c01, c02, c12] {
            assert!(c.abs() < 0.2, "cosine {c} too large for near-orthogonality");
        }
    }

    const ALL_FAMILIES: [Family; 7] = [
        Family::Dense,
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
        Family::Hankel,
        Family::SkewCirculant,
    ];

    #[test]
    fn apply_into_matches_apply_bitwise_all_families() {
        // Zero-allocation path == allocating path, square and stacked, with
        // one long-lived workspace reused across every call.
        for_all(14, |g| {
            let n = g.pow2_in(2, 6);
            let fam = *g.choose(&ALL_FAMILIES);
            let t: Box<dyn Transform> = if g.bool() {
                make_square(fam, n, &mut Rng::new(g.u64()))
            } else {
                let m = g.usize_in(1, n);
                let k = g.usize_in(1, 2 * n);
                make(fam, k, n, m, &mut Rng::new(g.u64()))
            };
            let mut ws = t.make_workspace();
            let mut out = vec![0.0f32; t.dim_out()];
            for _ in 0..3 {
                let x = g.gaussian_vec(n);
                let expect = t.apply(&x);
                t.apply_into(&x, &mut out, &mut ws);
                assert_eq!(out, expect, "{fam:?} n={n}");
            }
        });
    }

    #[test]
    fn apply_batch_into_matches_apply_bitwise_across_worker_counts() {
        // The batch engine (batch kernels + row sharding) must reproduce the
        // per-row path bit for bit at every worker count.
        for_all(10, |g| {
            let n = g.pow2_in(2, 5);
            let fam = *g.choose(&ALL_FAMILIES);
            let t: Box<dyn Transform> = if g.bool() {
                make_square(fam, n, &mut Rng::new(g.u64()))
            } else {
                let m = g.usize_in(1, n);
                let k = g.usize_in(1, 2 * n);
                make(fam, k, n, m, &mut Rng::new(g.u64()))
            };
            let rows = g.usize_in(1, 40);
            let xs = g.gaussian_vec(rows * n);
            let m_out = t.dim_out();
            let mut expect = Vec::with_capacity(rows * m_out);
            for r in xs.chunks_exact(n) {
                expect.extend_from_slice(&t.apply(r));
            }
            for workers in [1usize, 2, 4] {
                // gate disabled so small shapes exercise the parallel path
                let pool = WorkerPool::with_min_work(workers, 0);
                let mut out = vec![0.0f32; rows * m_out];
                // twice through the same pool: reused pinned workspaces
                // stay clean across batches
                for _ in 0..2 {
                    t.apply_batch_into(&xs, &mut out, &pool);
                    assert_eq!(out, expect, "{fam:?} n={n} rows={rows} workers={workers}");
                }
            }
            assert_eq!(t.apply_batch(&xs), expect, "{fam:?} wrapper");
        });
    }

    #[test]
    fn large_batch_deterministically_hits_the_parallel_path() {
        // rows = 70 with 4 workers guarantees the pool actually engages
        // (70 / MIN_ROWS_PER_WORKER >= 4) for every family.
        let n = 32;
        let rows = 70;
        let xs = Rng::new(21).gaussian_vec(rows * n);
        let pool = WorkerPool::with_min_work(4, 0);
        for fam in ALL_FAMILIES {
            let t = make_square(fam, n, &mut Rng::new(22));
            let mut expect = Vec::with_capacity(rows * n);
            for r in xs.chunks_exact(n) {
                expect.extend_from_slice(&t.apply(r));
            }
            let mut out = vec![0.0f32; rows * n];
            t.apply_batch_into(&xs, &mut out, &pool);
            assert_eq!(out, expect, "{fam:?}");
        }
        assert!(pool.started(), "this batch shape must engage the worker threads");
    }

    #[test]
    fn small_batches_never_start_the_pool() {
        // below MIN_ROWS_PER_WORKER * 2 rows there is nothing to fan out:
        // the serial path must run on the caller thread with no spawns.
        let n = 32;
        let pool = WorkerPool::new(8);
        let t = make_square(Family::Hd3, n, &mut Rng::new(33));
        for rows in [1usize, 3, 7, 15] {
            let xs = Rng::new(34).gaussian_vec(rows * n);
            let mut out = vec![0.0f32; rows * n];
            t.apply_batch_into(&xs, &mut out, &pool);
            let mut expect = Vec::new();
            for r in xs.chunks_exact(n) {
                expect.extend_from_slice(&t.apply(r));
            }
            assert_eq!(out, expect, "rows={rows}");
        }
        assert!(!pool.started(), "small batches must stay single-threaded");
    }

    #[test]
    fn family_parse_round_trip() {
        for f in [
            Family::Dense,
            Family::Hd3,
            Family::Hdg,
            Family::Circulant,
            Family::Toeplitz,
            Family::Hankel,
            Family::SkewCirculant,
        ] {
            assert_eq!(Family::parse(f.name()), Some(f));
        }
        assert_eq!(Family::parse("nope"), None);
    }

    #[test]
    fn param_bits_ordering() {
        // compression: hd3 < hdg < circulant-family < dense
        let n = 256;
        let mut rng = Rng::new(9);
        let dense = make_square(Family::Dense, n, &mut rng).param_bits();
        let hd3 = make_square(Family::Hd3, n, &mut rng).param_bits();
        let hdg = make_square(Family::Hdg, n, &mut rng).param_bits();
        let circ = make_square(Family::Circulant, n, &mut rng).param_bits();
        assert!(hd3 < hdg, "hd3={hd3} hdg={hdg}");
        assert!(hdg <= circ, "hdg={hdg} circ={circ}");
        assert!(circ < dense / 50, "circ={circ} dense={dense}");
    }
}
