//! `G_struct · D2 · H D1` members with a Gaussian circulant / Toeplitz /
//! Hankel / skew-circulant top block (Lemma 1 of the paper).
//!
//! Pipeline for one matvec: `x → D1 x → H x → D2 x → G_top x`, where the
//! top block multiplies in `O(n log n)` via an FFT circulant embedding whose
//! spectrum is precomputed once at construction ([`ConvPlan`]). Every row
//! entering the convolution is real, so the plan runs the half-spectrum
//! RFFT engine by default (half the butterflies, kernel spectrum and
//! scratch; `TS_FFT=complex` selects the legacy full-complex lane — see
//! [`crate::linalg::fft`]).

use super::hd::SignDiag;
use super::Transform;
use crate::linalg::fft::ConvPlan;
use crate::linalg::fwht::fwht;
use crate::linalg::simd;
use crate::linalg::Workspace;
use crate::util::rng::Rng;

/// Top-block structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TopKind {
    Circulant,
    Toeplitz,
    Hankel,
    SkewCirculant,
}

/// A `G_top · D2 · H D1` transform (square, `n` a power of two).
///
/// The two Rademacher diagonals are stored as packed [`SignDiag`] bitmasks
/// (their `2n` model bits really occupy ~`2n` bits) and applied as SIMD
/// sign XORs — `D1` directly on the f32 stage, `D2` fused into the
/// f32→f64 FFT promotion together with the `1/√n` normalization.
pub struct StructuredGaussian {
    n: usize,
    d1: SignDiag,
    d2: SignDiag,
    /// Precomputed spectrum of the circulant embedding of `G_top`.
    plan: ConvPlan,
    /// Hankel is reduced to Toeplitz on the *reversed* input — the only
    /// kind-specific behavior left at apply time.
    reverse_input: bool,
    /// Stored Gaussian parameter count (for `param_bits`).
    gaussians: usize,
    name: &'static str,
    /// Inverse FWHT normalization `1/√n`, fused with the `d2` scaling.
    inv_sqrt_n: f32,
}

impl StructuredGaussian {
    fn build(n: usize, kind: TopKind, rng: &mut Rng) -> StructuredGaussian {
        assert!(n.is_power_of_two(), "needs power-of-two n, got {n}");
        // same RNG stream as the historical Vec<f32> layout, packed to bits
        let d1 = SignDiag::random(n, rng);
        let d2 = SignDiag::random(n, rng);
        let (plan, gaussians, name) = match kind {
            TopKind::Circulant => {
                // first row r; first column col[i] = r[(n-i) % n]
                let row: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                let mut col = vec![0.0f64; n];
                for i in 0..n {
                    col[i] = row[(n - i) % n];
                }
                (ConvPlan::new(&col), n, "circulant")
            }
            TopKind::Toeplitz => {
                let diag: Vec<f64> = (0..2 * n - 1).map(|_| rng.gaussian()).collect();
                (Self::toeplitz_plan(&diag, n), 2 * n - 1, "toeplitz")
            }
            TopKind::Hankel => {
                // Hankel(anti) x = Toeplitz(diag) xr with
                // diag[d] = anti[2(n-1)-d] and xr the reversed input.
                let anti: Vec<f64> = (0..2 * n - 1).map(|_| rng.gaussian()).collect();
                let mut diag = vec![0.0f64; 2 * n - 1];
                for d in 0..2 * n - 1 {
                    diag[d] = anti[2 * (n - 1) - d];
                }
                (Self::toeplitz_plan(&diag, n), 2 * n - 1, "hankel")
            }
            TopKind::SkewCirculant => {
                // skew-circulant with first row r == Toeplitz with
                // diag[d] = r[d-(n-1)] above/on the main diagonal and
                // -r[d+1] below it.
                let row: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                let mut diag = vec![0.0f64; 2 * n - 1];
                for d in 0..2 * n - 1 {
                    diag[d] = if d >= n - 1 { row[d - (n - 1)] } else { -row[d + 1] };
                }
                (Self::toeplitz_plan(&diag, n), n, "skew_circulant")
            }
        };
        StructuredGaussian {
            n,
            d1,
            d2,
            plan,
            reverse_input: kind == TopKind::Hankel,
            gaussians,
            name,
            inv_sqrt_n: 1.0 / (n as f32).sqrt(),
        }
    }

    /// Promote the FWHT stage output to the f64 FFT buffer, fusing the
    /// `1/√n · d2` scaling (and the Hankel input reversal). `re[n..]` is
    /// the circulant-embedding padding and must be zeroed by the caller.
    /// Forward order runs the SIMD sign+scale+promote kernel; the reversed
    /// (Hankel) gather is scalar on every dispatch level, so both stay
    /// bit-identical across levels.
    #[inline]
    fn load_fft_input(&self, stage: &[f32], re: &mut [f64]) {
        let n = self.n;
        if self.reverse_input {
            for i in 0..n {
                let j = n - 1 - i;
                let flipped = f32::from_bits(stage[j].to_bits() ^ self.d2.sign_mask(j));
                re[i] = (flipped * self.inv_sqrt_n) as f64;
            }
        } else {
            simd::promote_signs_scaled(stage, self.d2.words(), self.inv_sqrt_n, &mut re[..n]);
        }
    }

    /// 2n-point circulant embedding of a Toeplitz matrix given its 2n-1
    /// diagonals (`diag[n-1]` = main).
    fn toeplitz_plan(diag: &[f64], n: usize) -> ConvPlan {
        let m = (2 * n).next_power_of_two();
        let mut c = vec![0.0f64; m];
        for i in 0..n {
            c[i] = diag[n - 1 - i];
        }
        for j in 1..n {
            c[m - j] = diag[n - 1 + j];
        }
        ConvPlan::new(&c)
    }

    pub fn circulant(n: usize, rng: &mut Rng) -> StructuredGaussian {
        Self::build(n, TopKind::Circulant, rng)
    }

    pub fn toeplitz(n: usize, rng: &mut Rng) -> StructuredGaussian {
        Self::build(n, TopKind::Toeplitz, rng)
    }

    pub fn hankel(n: usize, rng: &mut Rng) -> StructuredGaussian {
        Self::build(n, TopKind::Hankel, rng)
    }

    pub fn skew_circulant(n: usize, rng: &mut Rng) -> StructuredGaussian {
        Self::build(n, TopKind::SkewCirculant, rng)
    }
}

impl Transform for StructuredGaussian {
    fn dim_in(&self) -> usize {
        self.n
    }

    fn dim_out(&self) -> usize {
        self.n
    }

    fn apply_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        let n = self.n;
        // `out` doubles as the f32 stage buffer: D1 (sign XOR), then
        // unnormalized FWHT; the 1/√n normalization is fused into the D2
        // promotion below.
        out.copy_from_slice(x);
        self.d1.apply(out);
        fwht(out);
        // FFT top block on reused workspace scratch.
        // OVERWRITE: dirty checkouts — every
        // element below `n` is overwritten by the promotion, the spectrum
        // scratch is fully overwritten (RFFT) or cleared (complex legacy
        // lane) inside the plan kernel — only the circulant-embedding
        // padding `re[n..]` needs an explicit zero.
        let m = self.plan.len();
        let mut re = ws.take_f64_uninit(m);
        let mut im = ws.take_f64_uninit(self.plan.batch_scratch_len(1));
        self.load_fft_input(out, &mut re);
        for v in re[n..].iter_mut() {
            *v = 0.0;
        }
        self.plan.apply_in_place(&mut re, &mut im);
        for i in 0..n {
            out[i] = re[i] as f32;
        }
        ws.put_f64(im);
        ws.put_f64(re);
    }

    /// Batch kernel, row-major with blocked FFT scratch: each row runs
    /// `D1` + FWHT while L1-resident and is promoted straight into its f64
    /// FFT row; the top block then runs through
    /// [`ConvPlan::apply_batch_in_place`] over the block — shared twiddle
    /// tables, scratch reused across every block of every batch (a
    /// full-batch `D1`/FWHT pre-pass was reverted with the other
    /// level-major sweeps; see [`crate::linalg::fwht::fwht_batch`]).
    fn apply_batch_serial(&self, xs: &[f32], out: &mut [f32], ws: &mut Workspace) {
        debug_assert_eq!(xs.len(), out.len());
        let n = self.n;
        let m = self.plan.len();
        let block = self.plan.batch_block_rows();
        // OVERWRITE: dirty checkouts — every row's `dst[..n]` is written by
        // the
        // promotion and `dst[n..]` is explicitly zeroed below; the
        // spectrum scratch is the plan kernel's concern (fully overwritten
        // on the RFFT lane — one shared row, half the old checkout — and
        // cleared on the complex lane).
        let mut re = ws.take_f64_uninit(block * m);
        let mut im = ws.take_f64_uninit(self.plan.batch_scratch_len(block));
        for (xchunk, ochunk) in xs.chunks(block * n).zip(out.chunks_mut(block * n)) {
            let crows = xchunk.len() / n;
            for ((src, stage), dst) in xchunk
                .chunks_exact(n)
                .zip(ochunk.chunks_exact_mut(n))
                .zip(re.chunks_exact_mut(m))
            {
                stage.copy_from_slice(src);
                self.d1.apply(stage);
                fwht(stage);
                self.load_fft_input(stage, dst);
                // re-zero the embedding padding a previous block's
                // convolution (or the dirty checkout) left behind
                for v in dst[n..].iter_mut() {
                    *v = 0.0;
                }
            }
            self.plan.apply_batch_in_place(
                &mut re[..crows * m],
                &mut im[..self.plan.batch_scratch_len(crows)],
            );
            for (dst, src) in ochunk.chunks_exact_mut(n).zip(re.chunks_exact(m)) {
                for i in 0..n {
                    dst[i] = src[i] as f32;
                }
            }
        }
        ws.put_f64(im);
        ws.put_f64(re);
    }

    /// One FWHT pass plus the plan's matvec (two f64 FFT sweeps — full
    /// length on the complex lane, half length under the default RFFT —
    /// at ~8x an f32 add/sub pair per complex butterfly), so FFT families
    /// clear the pool's work gate at much smaller batches than plain HD
    /// chains and the gate tracks the active engine.
    fn batch_work_per_row(&self) -> usize {
        let n = self.n.max(2);
        n * (n.ilog2() as usize + 1) + self.plan.matvec_work()
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn param_bits(&self) -> usize {
        32 * self.gaussians + 2 * self.n
    }

    /// Real packed footprint of the random parameters: the Gaussian top
    /// block as f32s plus the two sign diagonals at one bit per entry
    /// (whole `u64` words). The precomputed spectrum/twiddles are derived
    /// caches, not parameters.
    fn stored_bits(&self) -> usize {
        32 * self.gaussians + self.d1.storage_bits() + self.d2.storage_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fwht::hadamard_dense;
    use crate::util::prop::for_all;

    /// Dense reference for each kind, reconstructing G_top explicitly from
    /// the same RNG stream the constructor consumed.
    fn dense_top(kind: TopKind, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let d1 = rng.rademacher_vec(n);
        let d2 = rng.rademacher_vec(n);
        let mut g = vec![0.0f32; n * n];
        match kind {
            TopKind::Circulant => {
                let row: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                for i in 0..n {
                    for j in 0..n {
                        g[i * n + j] = row[(n + j - i) % n] as f32;
                    }
                }
            }
            TopKind::Toeplitz => {
                let diag: Vec<f64> = (0..2 * n - 1).map(|_| rng.gaussian()).collect();
                for i in 0..n {
                    for j in 0..n {
                        g[i * n + j] = diag[j + n - 1 - i] as f32;
                    }
                }
            }
            TopKind::Hankel => {
                let anti: Vec<f64> = (0..2 * n - 1).map(|_| rng.gaussian()).collect();
                for i in 0..n {
                    for j in 0..n {
                        g[i * n + j] = anti[i + j] as f32;
                    }
                }
            }
            TopKind::SkewCirculant => {
                let row: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                for i in 0..n {
                    for j in 0..n {
                        g[i * n + j] = if j >= i {
                            row[j - i] as f32
                        } else {
                            -row[n + j - i] as f32
                        };
                    }
                }
            }
        }
        (d1, d2, g)
    }

    fn check_kind(kind: TopKind, ctor: fn(usize, &mut Rng) -> StructuredGaussian) {
        for n in [2usize, 8, 32] {
            let seed = 40 + n as u64;
            let t = ctor(n, &mut Rng::new(seed));
            let (d1, d2, g) = dense_top(kind, n, &mut Rng::new(seed));
            let h = hadamard_dense(n);
            let norm = 1.0 / (n as f32).sqrt();
            let mut rng = Rng::new(99);
            let x = rng.gaussian_vec(n);
            // reference: y = G * D2 * H * D1 * x
            let v1: Vec<f32> = x.iter().zip(&d1).map(|(a, b)| a * b).collect();
            let v2: Vec<f32> = (0..n)
                .map(|i| (0..n).map(|j| h[i * n + j] * norm * v1[j]).sum())
                .collect();
            let v3: Vec<f32> = v2.iter().zip(&d2).map(|(a, b)| a * b).collect();
            let expect: Vec<f32> = (0..n)
                .map(|i| (0..n).map(|j| g[i * n + j] * v3[j]).sum())
                .collect();
            let got = t.apply(&x);
            for i in 0..n {
                assert!(
                    (got[i] - expect[i]).abs() < 1e-3 * (1.0 + expect[i].abs()),
                    "{kind:?} n={n} i={i}: {} vs {}",
                    got[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn circulant_matches_dense() {
        check_kind(TopKind::Circulant, StructuredGaussian::circulant);
    }

    #[test]
    fn toeplitz_matches_dense() {
        check_kind(TopKind::Toeplitz, StructuredGaussian::toeplitz);
    }

    #[test]
    fn hankel_matches_dense() {
        check_kind(TopKind::Hankel, StructuredGaussian::hankel);
    }

    #[test]
    fn skew_circulant_matches_dense() {
        check_kind(TopKind::SkewCirculant, StructuredGaussian::skew_circulant);
    }

    #[test]
    fn apply_is_deterministic() {
        for_all(8, |g| {
            let n = g.pow2_in(1, 7);
            let seed = g.u64();
            let t1 = StructuredGaussian::circulant(n, &mut Rng::new(seed));
            let t2 = StructuredGaussian::circulant(n, &mut Rng::new(seed));
            let x = g.gaussian_vec(n);
            assert_eq!(t1.apply(&x), t2.apply(&x));
        });
    }

    #[test]
    fn param_bits_counts() {
        let mut rng = Rng::new(1);
        let n = 64;
        assert_eq!(
            StructuredGaussian::circulant(n, &mut rng).param_bits(),
            32 * n + 2 * n
        );
        assert_eq!(
            StructuredGaussian::toeplitz(n, &mut rng).param_bits(),
            32 * (2 * n - 1) + 2 * n
        );
    }

    #[test]
    fn stored_bits_packs_sign_diagonals() {
        // with n a multiple of 64 the packed footprint is exactly the
        // model-theoretic count: 32 bits per Gaussian + 1 bit per sign.
        let mut rng = Rng::new(1);
        let n = 128;
        let t = StructuredGaussian::circulant(n, &mut rng);
        assert_eq!(t.stored_bits(), 32 * n + 2 * n);
        assert_eq!(t.stored_bits(), t.param_bits());
    }
}
