//! Hadamard–diagonal chains: `√n · H D_k ··· H D_2 H D_1`.
//!
//! The fully discrete `HD3 HD2 HD1` (k = 3, Rademacher diagonals) is the
//! paper's flagship construction — the fastest known cross-polytope LSH
//! variant [Andoni et al. 2015], storable in `3n` random *bits*. Theorem 5.2
//! gives it the same convex-set distributional guarantees as the Gaussian
//! matrix it replaces. `HDg HD2 HD1` swaps the last diagonal for Gaussian
//! entries (Lemma 1's second member).
//!
//! Each `H D` factor costs one elementwise pass plus one FWHT — the whole
//! chain is `O(k · n log n)` with zero stored floats for the discrete case.
//!
//! ## Packed-bit diagonal layout
//!
//! Rademacher diagonals are **not stored as `Vec<f32>`**. A [`SignDiag`]
//! packs the `n` signs into `⌈n/64⌉` `u64` words — bit `i` of word `i/64`
//! set means "negate element `i`" — so the flagship `hd3` chain really does
//! store ~`3n` bits instead of `96n`. Application is a SIMD sign-bit XOR
//! ([`crate::linalg::simd::apply_signs`]): for every non-NaN input,
//! `x ^ sign_bit` is exactly `x * ±1.0`, so the packed path is bit-for-bit
//! identical to the old dense-f32 diagonal multiply (enforced by tests
//! here and in `tests/simd_equivalence.rs`). The chain's global
//! `√n · n^{-k/2}` normalization is a *derived* constant (not a stored
//! parameter): it rides along as a uniform post-scale on the last
//! diagonal — `(±x) · s ≡ x · (±s)` exactly — or is pre-multiplied into
//! the last diagonal's entries when that diagonal is Gaussian.
//!
//! Dispatch rules (AVX2 / SSE2 / NEON / scalar, `TS_NO_SIMD=1` to pin
//! scalar) live in [`crate::linalg::simd`]; every level is bit-identical.

use super::Transform;
use crate::linalg::fwht::fwht;
use crate::linalg::simd;
use crate::linalg::vecops::scale_by;
use crate::linalg::Workspace;
use crate::util::rng::Rng;

/// Which distribution a diagonal's entries were drawn from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagKind {
    /// ±1 entries (one bit each).
    Rademacher,
    /// N(0,1) entries.
    Gaussian,
}

/// A ±1 diagonal packed into `u64` sign bitmasks: bit `i` of
/// `words()[i / 64]` (position `i % 64`) set means "flip the sign of
/// element `i`". 64 diagonal entries per stored word — the bit-matrix
/// compression the paper's discrete chains are prized for. Application is
/// a sign-bit XOR with a 32× smaller parameter stream; measured against
/// the dispatched f32 multiply it replaces (`diag_micro` in
/// BENCH_transform_throughput.json) the apply itself is ~at parity — the
/// packed layout is about footprint (and keeping the dense diagonal out
/// of cache next to the data), while the chain's FWHT sweeps dominate its
/// runtime.
#[derive(Clone, Debug)]
pub struct SignDiag {
    words: Vec<u64>,
    len: usize,
}

impl SignDiag {
    /// Pack the signs of `d` (bit set where `d[i]` is negative). The
    /// canonical constructor: building from `rng.rademacher_vec(n)` keeps
    /// the RNG stream identical to the historical dense-f32 construction,
    /// so seeds reproduce the exact same transforms.
    pub fn from_f32(d: &[f32]) -> SignDiag {
        let mut words = vec![0u64; d.len().div_ceil(64)];
        for (i, v) in d.iter().enumerate() {
            if v.is_sign_negative() {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        SignDiag { words, len: d.len() }
    }

    /// Fresh random ±1 diagonal (consumes the RNG exactly like
    /// `rng.rademacher_vec(n)`).
    pub fn random(n: usize, rng: &mut Rng) -> SignDiag {
        SignDiag::from_f32(&rng.rademacher_vec(n))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed sign words (bit `i%64` of word `i/64` = negate `x[i]`).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Actual storage footprint in bits (whole words, so `64 · ⌈n/64⌉`).
    pub fn storage_bits(&self) -> usize {
        self.words.len() * 64
    }

    /// Sign of entry `i` as an f32 sign-bit mask (`0` or `0x8000_0000`).
    #[inline]
    pub fn sign_mask(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        (((self.words[i / 64] >> (i % 64)) & 1) as u32) << 31
    }

    /// Entry `i` as ±1.0.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        f32::from_bits(1.0f32.to_bits() | self.sign_mask(i))
    }

    /// `x[i] = ±x[i]` — the SIMD sign-XOR diagonal application.
    #[inline]
    pub fn apply(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.len);
        simd::apply_signs(x, &self.words);
    }

    /// `x[i] = ±x[i] · s` — sign application with a fused uniform scale
    /// (bit-identical to multiplying by a dense diagonal of `±s`).
    #[inline]
    pub fn apply_scaled(&self, x: &mut [f32], s: f32) {
        debug_assert_eq!(x.len(), self.len);
        simd::apply_signs_scaled(x, &self.words, s);
    }

    /// Expand to a dense ±scale f32 diagonal (test / dense-reference path).
    pub fn to_f32_scaled(&self, scale: f32) -> Vec<f32> {
        (0..self.len)
            .map(|i| f32::from_bits(scale.to_bits() ^ self.sign_mask(i)))
            .collect()
    }
}

/// One `D_i` of the chain: packed sign bits for Rademacher draws, dense
/// floats for Gaussian ones.
enum Diag {
    /// ±1 signs packed 64-per-word with a uniform post-scale (`1.0` for
    /// inner diagonals; the folded `√n · n^{-k/2}` on the last one).
    Signs { signs: SignDiag, scale: f32 },
    /// Dense f32 entries (Gaussian; the global scale is pre-multiplied in
    /// when this is the last diagonal).
    Dense(Vec<f32>),
}

/// `√n · H D_k ··· H D_1` chain transform (square, `n` a power of two).
pub struct HdChain {
    n: usize,
    /// Diagonals in application order (`diags[0]` = `D_1`), with the global
    /// `√n · n^{-k/2}` normalization folded into the last one.
    diags: Vec<Diag>,
    /// Model-parameter bits: `n` per Rademacher diagonal, `32n` per
    /// Gaussian one (fixed at construction).
    bits: usize,
    name: &'static str,
}

impl HdChain {
    /// Generic chain with `k` spins; `kinds[i]` gives the distribution of
    /// `D_{i+1}`. Used directly by the spin-count ablation.
    pub fn with_kinds(n: usize, kinds: &[DiagKind], rng: &mut Rng, name: &'static str) -> HdChain {
        assert!(n.is_power_of_two(), "HdChain needs power-of-two n, got {n}");
        assert!(!kinds.is_empty());
        let mut diags: Vec<Diag> = kinds
            .iter()
            .map(|k| match k {
                DiagKind::Rademacher => Diag::Signs {
                    signs: SignDiag::random(n, rng),
                    scale: 1.0,
                },
                DiagKind::Gaussian => Diag::Dense(rng.gaussian_vec(n)),
            })
            .collect();
        let k = kinds.len() as i32;
        // √n * (n^{-1/2})^k , computed in f64 to avoid overflow at large n.
        let scale = ((n as f64).sqrt() * (n as f64).powf(-0.5 * k as f64)) as f32;
        // perf: scaling commutes with the linear FWHT chain, so fold the
        // global scalar into the *last* diagonal — saves one full pass
        // over the output per apply (§Perf L3 iteration 1). For a packed
        // last diagonal it becomes the uniform post-scale of the sign XOR.
        match diags.last_mut() {
            Some(Diag::Signs { scale: s, .. }) => *s = scale,
            Some(Diag::Dense(v)) => {
                for e in v.iter_mut() {
                    *e *= scale;
                }
            }
            None => unreachable!(),
        }
        let bits = kinds
            .iter()
            .map(|k| match k {
                DiagKind::Rademacher => n,
                DiagKind::Gaussian => 32 * n,
            })
            .sum();
        HdChain {
            n,
            diags,
            bits,
            name,
        }
    }

    /// The flagship `√n · HD3 HD2 HD1` (all-Rademacher, bit-only storage).
    pub fn hd3(n: usize, rng: &mut Rng) -> HdChain {
        HdChain::with_kinds(
            n,
            &[DiagKind::Rademacher; 3],
            rng,
            "hd3",
        )
    }

    /// `√n · HDg HD2 HD1` — last diagonal Gaussian.
    pub fn hdg(n: usize, rng: &mut Rng) -> HdChain {
        HdChain::with_kinds(
            n,
            &[DiagKind::Rademacher, DiagKind::Rademacher, DiagKind::Gaussian],
            rng,
            "hdg",
        )
    }

    /// All-Rademacher chain with `k` spins (`k = 3` is [`HdChain::hd3`]).
    pub fn spins(n: usize, k: usize, rng: &mut Rng) -> HdChain {
        let kinds = vec![DiagKind::Rademacher; k];
        let name: &'static str = match k {
            1 => "hd1",
            2 => "hd2",
            3 => "hd3",
            _ => "hdk",
        };
        HdChain::with_kinds(n, &kinds, rng, name)
    }

    /// Number of spins (HD factors).
    pub fn num_spins(&self) -> usize {
        self.diags.len()
    }

    /// Diagonal `i` expanded to dense f32 (with any folded scale applied) —
    /// the dense-reference / serialization expansion path. Not for the hot
    /// loop.
    pub fn diag_dense(&self, i: usize) -> Vec<f32> {
        match &self.diags[i] {
            Diag::Signs { signs, scale } => signs.to_f32_scaled(*scale),
            Diag::Dense(v) => v.clone(),
        }
    }

    /// Actual stored parameter footprint in bits: `64 · ⌈n/64⌉` per packed
    /// Rademacher diagonal (≈ `n`, the paper's bit-matrix claim), `32n` per
    /// Gaussian one. The folded normalization constant is derived from
    /// `(n, k)`, not stored. Contrast with [`Transform::param_bits`], which
    /// reports the model-theoretic count.
    pub fn stored_bits(&self) -> usize {
        self.diags
            .iter()
            .map(|d| match d {
                Diag::Signs { signs, .. } => signs.storage_bits(),
                Diag::Dense(v) => 32 * v.len(),
            })
            .sum()
    }

    /// Apply in place into `buf` (`buf.len() == n`), the alloc-free hot
    /// path: per spin, one diagonal pass (sign-XOR for packed, multiply
    /// for dense) then one FWHT.
    pub fn apply_in_place(&self, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.n);
        for d in &self.diags {
            match d {
                Diag::Signs { signs, scale } => {
                    if *scale == 1.0 {
                        signs.apply(buf);
                    } else {
                        signs.apply_scaled(buf, *scale);
                    }
                }
                Diag::Dense(v) => scale_by(buf, v),
            }
            fwht(buf);
        }
    }
}

impl Transform for HdChain {
    fn dim_in(&self) -> usize {
        self.n
    }

    fn dim_out(&self) -> usize {
        self.n
    }

    fn apply_into(&self, x: &[f32], out: &mut [f32], _ws: &mut Workspace) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        out.copy_from_slice(x);
        self.apply_in_place(out);
    }

    // NOTE: no `apply_batch_serial` override. The trait default (per-row
    // `apply_into`) is the measured-fastest organization for HD chains:
    // each row runs all `k` spins while L1-resident. The PR-1 spin-major
    // override (every spin swept across the whole sub-batch before the
    // next) was reverted after C-mirror calibration showed it 5–30% slower
    // at n >= 256 — three full-batch sweeps trade row-local L1 reuse for
    // repeated L2 streaming (PR 2, tools/bench_mirror.c).

    /// `k` spins of (diagonal pass + FWHT) per row.
    fn batch_work_per_row(&self) -> usize {
        let n = self.n.max(2);
        self.diags.len() * n * (n.ilog2() as usize + 1)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn param_bits(&self) -> usize {
        self.bits
    }

    fn stored_bits(&self) -> usize {
        HdChain::stored_bits(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fwht::hadamard_dense;
    use crate::linalg::vecops::norm2;
    use crate::util::prop::for_all;

    /// Dense reference: build the chain exactly as `apply` computes it —
    /// unnormalized H̃ per spin over the *stored* diagonals (the global
    /// √n·n^{-k/2} normalization is folded into the last stored diagonal,
    /// expanded here through [`HdChain::diag_dense`]).
    fn dense_reference(chain: &HdChain, n: usize) -> Vec<f32> {
        let h = hadamard_dense(n); // unnormalized ±1
        // start with identity
        let mut m: Vec<f32> = vec![0.0; n * n];
        for i in 0..n {
            m[i * n + i] = 1.0;
        }
        for di in 0..chain.num_spins() {
            let d = chain.diag_dense(di);
            // m = H̃ * D * m
            let mut scaled = m.clone();
            for i in 0..n {
                for j in 0..n {
                    scaled[i * n + j] = m[i * n + j] * d[i]; // D scales rows of m (i.e. D*m)
                }
            }
            let mut next = vec![0.0f32; n * n];
            for i in 0..n {
                for k in 0..n {
                    let hv = h[i * n + k];
                    for j in 0..n {
                        next[i * n + j] += hv * scaled[k * n + j];
                    }
                }
            }
            m = next;
        }
        m
    }

    #[test]
    fn matches_dense_reference() {
        for n in [2usize, 4, 16, 32] {
            let mut rng = Rng::new(31);
            let chain = HdChain::hd3(n, &mut rng);
            let dense = dense_reference(&chain, n);
            let mut rng2 = Rng::new(77);
            let x = rng2.gaussian_vec(n);
            let got = chain.apply(&x);
            for i in 0..n {
                let expect: f32 = (0..n).map(|j| dense[i * n + j] * x[j]).sum();
                assert!(
                    (got[i] - expect).abs() < 1e-3 * (1.0 + expect.abs()),
                    "n={n} i={i}: {} vs {expect}",
                    got[i]
                );
            }
        }
    }

    #[test]
    fn packed_diag_matches_dense_f32_reference_bitwise() {
        // The packed sign-XOR chain must reproduce the historical dense
        // Vec<f32>-diagonal implementation byte for byte: same seeds, same
        // RNG stream, the diagonal pass done by explicit f32 multiplies
        // against diag_dense().
        for_all(20, |g| {
            let n = g.pow2_in(1, 9);
            let seed = g.u64();
            let gaussian_last = g.bool();
            let chain = if gaussian_last {
                HdChain::hdg(n, &mut Rng::new(seed))
            } else {
                HdChain::hd3(n, &mut Rng::new(seed))
            };
            let x = g.gaussian_vec(n);
            let got = chain.apply(&x);
            // old-style evaluation: dense f32 diagonals + fwht per spin
            let mut old = x;
            for d in 0..chain.num_spins() {
                let dd = chain.diag_dense(d);
                for (v, s) in old.iter_mut().zip(&dd) {
                    *v *= *s;
                }
                crate::linalg::fwht::fwht(&mut old);
            }
            assert_eq!(got, old, "n={n} gaussian_last={gaussian_last}");
        });
    }

    #[test]
    fn sign_diag_round_trip_and_storage() {
        let mut rng = Rng::new(12);
        for n in [1usize, 63, 64, 65, 200] {
            let d = rng.rademacher_vec(n);
            let sd = SignDiag::from_f32(&d);
            assert_eq!(sd.len(), n);
            assert_eq!(sd.storage_bits(), n.div_ceil(64) * 64);
            for i in 0..n {
                assert_eq!(sd.get(i), d[i], "n={n} i={i}");
            }
            assert_eq!(sd.to_f32_scaled(1.0), d);
            // application == multiply, bitwise
            let x = rng.gaussian_vec(n);
            let mut a = x.clone();
            sd.apply(&mut a);
            let mut b = x;
            for (v, s) in b.iter_mut().zip(&d) {
                *v *= *s;
            }
            assert_eq!(a, b);
        }
    }

    #[test]
    fn norm_scaling_exact_for_discrete_chain() {
        // (HD)^k with H an isometry and D ±1 is an isometry, so the √n-scaled
        // chain maps unit vectors to norm exactly √n.
        for_all(24, |g| {
            let n = g.pow2_in(1, 9);
            let k = g.usize_in(1, 4);
            let chain = HdChain::spins(n, k, &mut Rng::new(g.u64()));
            let x = g.unit_vec(n);
            let y = chain.apply(&x);
            let expect = (n as f64).sqrt();
            assert!(
                (norm2(&y) - expect).abs() < 1e-2 * expect,
                "n={n} k={k}: ||y||={} want {expect}",
                norm2(&y)
            );
        });
    }

    #[test]
    fn hdg_has_gaussian_diag_storage() {
        let mut rng = Rng::new(3);
        let hd3 = HdChain::hd3(64, &mut rng);
        let hdg = HdChain::hdg(64, &mut rng);
        assert_eq!(hd3.param_bits(), 3 * 64);
        assert_eq!(hdg.param_bits(), 2 * 64 + 32 * 64);
    }

    #[test]
    fn stored_bits_reports_packed_footprint() {
        let mut rng = Rng::new(3);
        // n = 128: each Rademacher diagonal packs into two u64 words.
        let hd3 = HdChain::hd3(128, &mut rng);
        assert_eq!(hd3.stored_bits(), 3 * 128, "hd3 must store ~n bits/diag");
        let hdg = HdChain::hdg(128, &mut rng);
        assert_eq!(hdg.stored_bits(), 2 * 128 + 32 * 128);
        // the packed footprint is exactly 32x below the dense f32 layout
        // the diagonals expand to (diag_dense is that expansion)
        let dense_bits: usize = (0..hd3.num_spins())
            .map(|i| 32 * hd3.diag_dense(i).len())
            .sum();
        assert_eq!(32 * hd3.stored_bits(), dense_bits);
        // Transform-trait view agrees
        let t: &dyn crate::transform::Transform = &hd3;
        assert_eq!(t.stored_bits(), 3 * 128);
    }

    #[test]
    fn balancedness_of_hd1() {
        // Remark 1: HD1 is (log n, p)-balanced — after one spin a unit
        // vector's mass spreads out: ||HD1 x||_inf <= log(n)/sqrt(n) whp.
        let n = 1024usize;
        let mut failures = 0;
        for s in 0..50 {
            let chain = HdChain::spins(n, 1, &mut Rng::new(900 + s));
            // spike input: worst case for balancedness
            let mut x = vec![0.0f32; n];
            x[0] = 1.0;
            let y = chain.apply(&x);
            // chain output is √n-scaled; undo to compare against δ(n)/√n
            let maxabs = y.iter().fold(0.0f32, |a, v| a.max(v.abs())) / (n as f32).sqrt();
            let bound = (n as f32).ln() / (n as f32).sqrt();
            if maxabs > bound {
                failures += 1;
            }
        }
        assert!(failures <= 2, "balancedness failed {failures}/50 times");
    }

    #[test]
    fn apply_in_place_matches_apply() {
        let mut rng = Rng::new(4);
        let chain = HdChain::hd3(128, &mut rng);
        let x = rng.gaussian_vec(128);
        let a = chain.apply(&x);
        let mut b = x.clone();
        chain.apply_in_place(&mut b);
        assert_eq!(a, b);
    }
}
