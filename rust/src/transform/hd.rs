//! Hadamard–diagonal chains: `√n · H D_k ··· H D_2 H D_1`.
//!
//! The fully discrete `HD3 HD2 HD1` (k = 3, Rademacher diagonals) is the
//! paper's flagship construction — the fastest known cross-polytope LSH
//! variant [Andoni et al. 2015], storable in `3n` random *bits*. Theorem 5.2
//! gives it the same convex-set distributional guarantees as the Gaussian
//! matrix it replaces. `HDg HD2 HD1` swaps the last diagonal for Gaussian
//! entries (Lemma 1's second member).
//!
//! Each `H D` factor costs one elementwise scaling plus one FWHT — the whole
//! chain is `O(k · n log n)` with zero stored floats for the discrete case.

use super::Transform;
use crate::linalg::fwht::fwht;
use crate::linalg::vecops::scale_by;
use crate::linalg::Workspace;
use crate::util::rng::Rng;

/// Which distribution a diagonal's entries were drawn from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagKind {
    /// ±1 entries (one bit each).
    Rademacher,
    /// N(0,1) entries.
    Gaussian,
}

/// `√n · H D_k ··· H D_1` chain transform (square, `n` a power of two).
pub struct HdChain {
    n: usize,
    /// Diagonals in application order (`diags[0]` = `D_1`), with the global
    /// `√n · n^{-k/2}` normalization pre-folded into the last one.
    diags: Vec<Vec<f32>>,
    /// Stored-parameter bits: `n` per Rademacher diagonal, `32n` per
    /// Gaussian one (fixed at construction).
    bits: usize,
    name: &'static str,
}

impl HdChain {
    /// Generic chain with `k` spins; `kinds[i]` gives the distribution of
    /// `D_{i+1}`. Used directly by the spin-count ablation.
    pub fn with_kinds(n: usize, kinds: &[DiagKind], rng: &mut Rng, name: &'static str) -> HdChain {
        assert!(n.is_power_of_two(), "HdChain needs power-of-two n, got {n}");
        assert!(!kinds.is_empty());
        let mut diags: Vec<Vec<f32>> = kinds
            .iter()
            .map(|k| match k {
                DiagKind::Rademacher => rng.rademacher_vec(n),
                DiagKind::Gaussian => rng.gaussian_vec(n),
            })
            .collect();
        let k = kinds.len() as i32;
        // √n * (n^{-1/2})^k , computed in f64 to avoid overflow at large n.
        let scale = ((n as f64).sqrt() * (n as f64).powf(-0.5 * k as f64)) as f32;
        // perf: scaling commutes with the linear FWHT chain, so fold the
        // global scalar into the *last* diagonal — saves one full pass
        // over the output per apply (§Perf L3 iteration 1).
        if let Some(last) = diags.last_mut() {
            for v in last.iter_mut() {
                *v *= scale;
            }
        }
        let bits = kinds
            .iter()
            .map(|k| match k {
                DiagKind::Rademacher => n,
                DiagKind::Gaussian => 32 * n,
            })
            .sum();
        HdChain {
            n,
            diags,
            bits,
            name,
        }
    }

    /// The flagship `√n · HD3 HD2 HD1` (all-Rademacher, bit-only storage).
    pub fn hd3(n: usize, rng: &mut Rng) -> HdChain {
        HdChain::with_kinds(
            n,
            &[DiagKind::Rademacher; 3],
            rng,
            "hd3",
        )
    }

    /// `√n · HDg HD2 HD1` — last diagonal Gaussian.
    pub fn hdg(n: usize, rng: &mut Rng) -> HdChain {
        HdChain::with_kinds(
            n,
            &[DiagKind::Rademacher, DiagKind::Rademacher, DiagKind::Gaussian],
            rng,
            "hdg",
        )
    }

    /// All-Rademacher chain with `k` spins (`k = 3` is [`HdChain::hd3`]).
    pub fn spins(n: usize, k: usize, rng: &mut Rng) -> HdChain {
        let kinds = vec![DiagKind::Rademacher; k];
        let name: &'static str = match k {
            1 => "hd1",
            2 => "hd2",
            3 => "hd3",
            _ => "hdk",
        };
        HdChain::with_kinds(n, &kinds, rng, name)
    }

    /// Number of spins (HD factors).
    pub fn num_spins(&self) -> usize {
        self.diags.len()
    }

    /// Apply in place into `buf` (`buf.len() == n`), the alloc-free hot path.
    pub fn apply_in_place(&self, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.n);
        for d in &self.diags {
            scale_by(buf, d);
            fwht(buf);
        }
    }
}

impl Transform for HdChain {
    fn dim_in(&self) -> usize {
        self.n
    }

    fn dim_out(&self) -> usize {
        self.n
    }

    fn apply_into(&self, x: &[f32], out: &mut [f32], _ws: &mut Workspace) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.n);
        out.copy_from_slice(x);
        self.apply_in_place(out);
    }

    // NOTE: no `apply_batch_serial` override. The trait default (per-row
    // `apply_into`) is the measured-fastest organization for HD chains:
    // each row runs all `k` spins while L1-resident. The PR-1 spin-major
    // override (every spin swept across the whole sub-batch before the
    // next) was reverted after C-mirror calibration showed it 5–30% slower
    // at n >= 256 — three full-batch sweeps trade row-local L1 reuse for
    // repeated L2 streaming (PR 2, tools/bench_mirror.c).

    /// `k` spins of (scale + FWHT) per row.
    fn batch_work_per_row(&self) -> usize {
        let n = self.n.max(2);
        self.diags.len() * n * (n.ilog2() as usize + 1)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn param_bits(&self) -> usize {
        self.bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fwht::hadamard_dense;
    use crate::linalg::vecops::norm2;
    use crate::util::prop::for_all;

    /// Dense reference: build the chain exactly as `apply` computes it —
    /// unnormalized H̃ per spin over the *stored* diagonals (the global
    /// √n·n^{-k/2} normalization is folded into the last stored diagonal).
    fn dense_reference(chain: &HdChain, n: usize) -> Vec<f32> {
        let h = hadamard_dense(n); // unnormalized ±1
        // start with identity
        let mut m: Vec<f32> = vec![0.0; n * n];
        for i in 0..n {
            m[i * n + i] = 1.0;
        }
        for d in &chain.diags {
            // m = H̃ * D * m
            let mut scaled = m.clone();
            for i in 0..n {
                for j in 0..n {
                    scaled[i * n + j] = m[i * n + j] * d[i]; // D scales rows of m (i.e. D*m)
                }
            }
            let mut next = vec![0.0f32; n * n];
            for i in 0..n {
                for k in 0..n {
                    let hv = h[i * n + k];
                    for j in 0..n {
                        next[i * n + j] += hv * scaled[k * n + j];
                    }
                }
            }
            m = next;
        }
        m
    }

    #[test]
    fn matches_dense_reference() {
        for n in [2usize, 4, 16, 32] {
            let mut rng = Rng::new(31);
            let chain = HdChain::hd3(n, &mut rng);
            let dense = dense_reference(&chain, n);
            let mut rng2 = Rng::new(77);
            let x = rng2.gaussian_vec(n);
            let got = chain.apply(&x);
            for i in 0..n {
                let expect: f32 = (0..n).map(|j| dense[i * n + j] * x[j]).sum();
                assert!(
                    (got[i] - expect).abs() < 1e-3 * (1.0 + expect.abs()),
                    "n={n} i={i}: {} vs {expect}",
                    got[i]
                );
            }
        }
    }

    #[test]
    fn norm_scaling_exact_for_discrete_chain() {
        // (HD)^k with H an isometry and D ±1 is an isometry, so the √n-scaled
        // chain maps unit vectors to norm exactly √n.
        for_all(24, |g| {
            let n = g.pow2_in(1, 9);
            let k = g.usize_in(1, 4);
            let chain = HdChain::spins(n, k, &mut Rng::new(g.u64()));
            let x = g.unit_vec(n);
            let y = chain.apply(&x);
            let expect = (n as f64).sqrt();
            assert!(
                (norm2(&y) - expect).abs() < 1e-2 * expect,
                "n={n} k={k}: ||y||={} want {expect}",
                norm2(&y)
            );
        });
    }

    #[test]
    fn hdg_has_gaussian_diag_storage() {
        let mut rng = Rng::new(3);
        let hd3 = HdChain::hd3(64, &mut rng);
        let hdg = HdChain::hdg(64, &mut rng);
        assert_eq!(hd3.param_bits(), 3 * 64);
        assert_eq!(hdg.param_bits(), 2 * 64 + 32 * 64);
    }

    #[test]
    fn balancedness_of_hd1() {
        // Remark 1: HD1 is (log n, p)-balanced — after one spin a unit
        // vector's mass spreads out: ||HD1 x||_inf <= log(n)/sqrt(n) whp.
        let n = 1024usize;
        let mut failures = 0;
        for s in 0..50 {
            let chain = HdChain::spins(n, 1, &mut Rng::new(900 + s));
            // spike input: worst case for balancedness
            let mut x = vec![0.0f32; n];
            x[0] = 1.0;
            let y = chain.apply(&x);
            // chain output is √n-scaled; undo to compare against δ(n)/√n
            let maxabs = y.iter().fold(0.0f32, |a, v| a.max(v.abs())) / (n as f32).sqrt();
            let bound = (n as f32).ln() / (n as f32).sqrt();
            if maxabs > bound {
                failures += 1;
            }
        }
        assert!(failures <= 2, "balancedness failed {failures}/50 times");
    }

    #[test]
    fn apply_in_place_matches_apply() {
        let mut rng = Rng::new(4);
        let chain = HdChain::hd3(128, &mut rng);
        let x = rng.gaussian_vec(128);
        let a = chain.apply(&x);
        let mut b = x.clone();
        chain.apply_in_place(&mut b);
        assert_eq!(a, b);
    }
}
