//! Block stacking (paper §3.1): rectangular TripleSpin transforms.
//!
//! An `m x n` TripleSpin matrix (`m <= n`) is the first `m` rows of an
//! independently drawn square `n x n` member; a `k x n` matrix stacks
//! `ceil(k / m)` such blocks vertically, truncating the last. The block
//! height `m` tunes the "structuredness level": `m = n` is maximally
//! structured (one block), `m = 1` degenerates to fully independent rows.

use super::{make_square, Family, Transform};
use crate::linalg::Workspace;
use crate::util::rng::Rng;

/// `k x n` transform assembled from independent square blocks.
pub struct StackedTransform {
    family: Family,
    k: usize,
    n: usize,
    block_rows: usize,
    blocks: Vec<Box<dyn Transform>>,
    name: &'static str,
}

impl StackedTransform {
    /// `k` output rows over inputs of dim `n`, from blocks of `m <= n` rows
    /// each (each block an independent square transform truncated to `m`).
    pub fn new(family: Family, k: usize, n: usize, m: usize, rng: &mut Rng) -> StackedTransform {
        assert!(m >= 1 && m <= n, "block rows m={m} must be in 1..=n={n}");
        assert!(k >= 1);
        let num_blocks = k.div_ceil(m);
        let blocks: Vec<Box<dyn Transform>> = (0..num_blocks)
            .map(|_| make_square(family, n, &mut rng.fork()))
            .collect();
        let name = blocks[0].name();
        StackedTransform {
            family,
            k,
            n,
            block_rows: m,
            blocks,
            name,
        }
    }

    /// Convenience: maximally structured stacking (`m = n`).
    pub fn full_blocks(family: Family, k: usize, n: usize, rng: &mut Rng) -> StackedTransform {
        StackedTransform::new(family, k, n, n, rng)
    }

    pub fn family(&self) -> Family {
        self.family
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_rows(&self) -> usize {
        self.block_rows
    }
}

impl Transform for StackedTransform {
    fn dim_in(&self) -> usize {
        self.n
    }

    fn dim_out(&self) -> usize {
        self.k
    }

    fn apply_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(out.len(), self.k);
        // One reused square scratch row: each block writes its full output
        // there and only the kept (truncated) prefix is copied out — no
        // per-block allocation, no materialized n×n block results.
        // OVERWRITE: dirty checkout — every element is overwritten by the
        // block apply before the truncated prefix is copied out.
        let mut buf = ws.take_f32_uninit(self.n);
        let mut off = 0;
        for b in &self.blocks {
            b.apply_into(x, &mut buf, ws);
            let take = self.block_rows.min(self.k - off);
            out[off..off + take].copy_from_slice(&buf[..take]);
            off += take;
            if off == self.k {
                break;
            }
        }
        ws.put_f32(buf);
    }

    /// Batch kernel: iterate **blocks outer, rows inner**, so each square
    /// block's parameters stay hot while its batch kernel (row-resident
    /// pipeline, FFT scratch reuse) sweeps all rows; truncated prefixes are
    /// then scattered into the interleaved output rows.
    fn apply_batch_serial(&self, xs: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let n = self.n;
        let k = self.k;
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        debug_assert_eq!(out.len(), rows * k);
        // OVERWRITE: dirty checkout — each block's batch kernel overwrites
        // every row before the kept prefix is copied out.
        let mut buf = ws.take_f32_uninit(rows * n);
        let mut off = 0;
        for b in &self.blocks {
            b.apply_batch_serial(xs, &mut buf, ws);
            let take = self.block_rows.min(k - off);
            for (r, brow) in buf.chunks_exact(n).enumerate() {
                out[r * k + off..r * k + off + take].copy_from_slice(&brow[..take]);
            }
            off += take;
            if off == k {
                break;
            }
        }
        ws.put_f32(buf);
    }

    /// Every block's square kernel runs per row.
    fn batch_work_per_row(&self) -> usize {
        self.blocks.iter().map(|b| b.batch_work_per_row()).sum()
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn param_bits(&self) -> usize {
        self.blocks.iter().map(|b| b.param_bits()).sum()
    }

    fn stored_bits(&self) -> usize {
        self.blocks.iter().map(|b| b.stored_bits()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn output_dims() {
        for_all(16, |g| {
            let n = g.pow2_in(2, 7);
            let m = g.usize_in(1, n);
            let k = g.usize_in(1, 3 * n);
            let t = StackedTransform::new(Family::Hd3, k, n, m, &mut Rng::new(g.u64()));
            assert_eq!(t.dim_out(), k);
            assert_eq!(t.num_blocks(), k.div_ceil(m));
            let x = g.gaussian_vec(n);
            assert_eq!(t.apply(&x).len(), k);
        });
    }

    #[test]
    fn first_block_matches_square_truncation() {
        // The first m outputs must equal the first m rows of the first
        // square block (seeded through the same fork sequence).
        let n = 64;
        let m = 16;
        let k = 40;
        let seed = 1234u64;
        let t = StackedTransform::new(Family::Hd3, k, n, m, &mut Rng::new(seed));
        let sq = make_square(Family::Hd3, n, &mut Rng::new(seed).fork());
        let x = Rng::new(9).gaussian_vec(n);
        let full = sq.apply(&x);
        let stacked = t.apply(&x);
        assert_eq!(&stacked[..m], &full[..m]);
    }

    #[test]
    fn blocks_are_independent() {
        // different blocks come from independent draws: their outputs on the
        // same input must differ.
        let n = 32;
        let t = StackedTransform::new(Family::Hd3, 2 * n, n, n, &mut Rng::new(5));
        let x = Rng::new(6).unit_vec(n);
        let y = t.apply(&x);
        let (a, b) = (&y[..n], &y[n..]);
        assert_ne!(a, b);
    }

    #[test]
    fn k_larger_than_n_supported() {
        let n = 16;
        let k = 100;
        let t = StackedTransform::full_blocks(Family::Hdg, k, n, &mut Rng::new(7));
        assert_eq!(t.dim_out(), 100);
        assert_eq!(t.num_blocks(), 7); // ceil(100/16)
        let x = Rng::new(8).gaussian_vec(n);
        assert_eq!(t.apply(&x).len(), 100);
    }

    #[test]
    fn m1_is_fully_unstructured_rows() {
        // m = 1: every output row from its own block.
        let n = 8;
        let k = 5;
        let t = StackedTransform::new(Family::Circulant, k, n, 1, &mut Rng::new(11));
        assert_eq!(t.num_blocks(), 5);
    }

    #[test]
    fn param_bits_scales_with_blocks() {
        let n = 64;
        let mut rng = Rng::new(13);
        let one = StackedTransform::new(Family::Hd3, n, n, n, &mut rng).param_bits();
        let two = StackedTransform::new(Family::Hd3, 2 * n, n, n, &mut rng).param_bits();
        assert_eq!(two, 2 * one);
    }
}
