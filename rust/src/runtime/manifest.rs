//! The AOT artifact manifest written by `python/compile/aot.py`.

use crate::util::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};

/// Operations the compiled artifacts implement.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// `√n·HD3 HD2 HD1 x` — (b, n) f32 -> (b, n) f32.
    Transform,
    /// Gaussian-kernel RFF map — (b, n) f32 -> (b, 2n) f32.
    Rff,
    /// Cross-polytope hash ids — (b, n) f32 -> (b,) i32.
    CrossPolytope,
    /// Sign-quantized packed embedding `sign(√n·HD3 HD2 HD1 x)` —
    /// (b, n) f32 -> (b, ⌈n/64⌉) u64 words (native backend only; 32×
    /// smaller responses than the f32 transform lane).
    BinaryEmbed,
}

impl Op {
    pub fn parse(s: &str) -> Option<Op> {
        Some(match s {
            "transform" => Op::Transform,
            "rff" => Op::Rff,
            "crosspolytope" => Op::CrossPolytope,
            "binary_embed" => Op::BinaryEmbed,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::Transform => "transform",
            Op::Rff => "rff",
            Op::CrossPolytope => "crosspolytope",
            Op::BinaryEmbed => "binary_embed",
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One compiled artifact: an (op, n, batch) variant.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub op: Op,
    pub n: usize,
    pub batch: usize,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Parameter shapes, in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
    /// "f32" or "i32".
    pub output_dtype: String,
    /// Optional golden input/output vectors file.
    pub golden: Option<String>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

/// Error type for manifest loading / validation.
#[derive(Debug)]
pub struct ManifestError(pub String);

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

fn shape_list(j: &Json) -> Result<Vec<usize>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| ManifestError("shape is not an array".into()))?
        .iter()
        .map(|d| {
            d.as_usize()
                .ok_or_else(|| ManifestError(format!("bad dim {d:?}")))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| ManifestError(format!("read {}: {e}", path.display())))?;
        let doc = Json::parse(&text).map_err(|e| ManifestError(e.to_string()))?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| ManifestError("missing 'artifacts' array".into()))?;
        let mut out = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| -> Result<String, ManifestError> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| ManifestError(format!("missing string '{k}'")))
            };
            let get_usize = |k: &str| -> Result<usize, ManifestError> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| ManifestError(format!("missing int '{k}'")))
            };
            let op_s = get_str("op")?;
            let op = Op::parse(&op_s)
                .ok_or_else(|| ManifestError(format!("unknown op '{op_s}'")))?;
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| ManifestError("missing 'inputs'".into()))?
                .iter()
                .map(shape_list)
                .collect::<Result<Vec<_>, _>>()?;
            let spec = ArtifactSpec {
                name: get_str("name")?,
                op,
                n: get_usize("n")?,
                batch: get_usize("batch")?,
                file: get_str("file")?,
                inputs,
                output: shape_list(
                    a.get("output")
                        .ok_or_else(|| ManifestError("missing 'output'".into()))?,
                )?,
                output_dtype: get_str("output_dtype")?,
                golden: a.get("golden").and_then(|v| v.as_str()).map(str::to_string),
            };
            // structural validation
            if spec.inputs.is_empty() || spec.inputs[0] != vec![spec.batch, spec.n] {
                return Err(ManifestError(format!(
                    "{}: first input shape {:?} != [batch={}, n={}]",
                    spec.name, spec.inputs.first(), spec.batch, spec.n
                )));
            }
            if !dir.join(&spec.file).exists() {
                return Err(ManifestError(format!(
                    "{}: artifact file {} missing",
                    spec.name, spec.file
                )));
            }
            out.push(spec);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts: out,
        })
    }

    /// Find artifacts for (op, n), sorted by batch ascending.
    pub fn variants(&self, op: Op, n: usize) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| a.op == op && a.n == n)
            .collect();
        v.sort_by_key(|a| a.batch);
        v
    }

    /// Distinct (op, n) pairs available.
    pub fn lanes(&self) -> Vec<(Op, usize)> {
        let mut v: Vec<(Op, usize)> = self.artifacts.iter().map(|a| (a.op, a.n)).collect();
        v.sort_by_key(|(op, n)| (op.name(), *n));
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("ts_manifest_test1");
        write_fake_manifest(
            &dir,
            r#"{"version":1,"artifacts":[
                {"name":"transform_n64_b4","op":"transform","n":64,"batch":4,
                 "file":"t.hlo.txt","inputs":[[4,64],[64],[64],[64]],
                 "output":[4,64],"output_dtype":"f32"}]}"#,
        );
        std::fs::write(dir.join("t.hlo.txt"), "HloModule fake").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.op, Op::Transform);
        assert_eq!(a.n, 64);
        assert_eq!(a.batch, 4);
        assert_eq!(a.golden, None);
        assert_eq!(m.lanes(), vec![(Op::Transform, 64)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join("ts_manifest_test2");
        write_fake_manifest(
            &dir,
            r#"{"artifacts":[
                {"name":"x","op":"transform","n":64,"batch":4,
                 "file":"t.hlo.txt","inputs":[[9,9]],
                 "output":[4,64],"output_dtype":"f32"}]}"#,
        );
        std::fs::write(dir.join("t.hlo.txt"), "x").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("ts_manifest_test3");
        write_fake_manifest(
            &dir,
            r#"{"artifacts":[
                {"name":"x","op":"rff","n":64,"batch":4,
                 "file":"gone.hlo.txt","inputs":[[4,64]],
                 "output":[4,128],"output_dtype":"f32"}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn variants_sorted_by_batch() {
        let dir = std::env::temp_dir().join("ts_manifest_test4");
        write_fake_manifest(
            &dir,
            r#"{"artifacts":[
                {"name":"a","op":"transform","n":64,"batch":16,
                 "file":"a.hlo.txt","inputs":[[16,64]],"output":[16,64],"output_dtype":"f32"},
                {"name":"b","op":"transform","n":64,"batch":1,
                 "file":"b.hlo.txt","inputs":[[1,64]],"output":[1,64],"output_dtype":"f32"}]}"#,
        );
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.hlo.txt"), "x").unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variants(Op::Transform, 64);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].batch, 1);
        assert_eq!(v[1].batch, 16);
        assert!(m.variants(Op::Rff, 64).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn op_parse() {
        assert_eq!(Op::parse("transform"), Some(Op::Transform));
        assert_eq!(Op::parse("rff"), Some(Op::Rff));
        assert_eq!(Op::parse("crosspolytope"), Some(Op::CrossPolytope));
        assert_eq!(Op::parse("bogus"), None);
        assert_eq!(Op::Rff.to_string(), "rff");
    }

    #[test]
    fn real_manifest_if_built() {
        // when `make artifacts` has run, the real manifest must load
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).expect("real manifest must parse");
            assert!(!m.artifacts.is_empty());
            assert!(m
                .artifacts
                .iter()
                .any(|a| a.op == Op::Transform && a.n == 256));
        }
    }
}
