//! Persistent worker-pool runtime for batch execution.
//!
//! PR 1 sharded batch rows across `std::thread::scope` workers, which spawns
//! and joins OS threads on **every batch** — fine for one-shot sweeps, wrong
//! for steady-state serving where thread spawn latency (~10–50 µs) rivals
//! the kernel time of a small batch. [`WorkerPool`] replaces that with a
//! long-lived, lazily-started pool:
//!
//! * Worker threads are spawned **once**, on the first batch large enough to
//!   go parallel, and live for the pool's lifetime. Steady state performs
//!   zero thread spawns per batch.
//! * Each worker owns one pinned [`Workspace`] for its whole lifetime, so
//!   family scratch (FFT rows, padding buffers) is reused across every batch
//!   it ever shards — zero heap allocations per batch once warm.
//! * Dispatch is two `std::sync::mpsc::sync_channel` hops per worker (job
//!   down, ack back). Bounded channels preallocate their slot buffers at
//!   construction, so a dispatch allocates nothing.
//! * Serial batches (fewer than [`MIN_ROWS_PER_WORKER`] rows per would-be
//!   worker, or too little total work to amortize a wakeup) never touch the
//!   worker threads at all — they run on the caller thread against a
//!   thread-local serial workspace, so concurrent lane threads stay fully
//!   parallel with each other, and do not start the pool.
//! * Row distribution inside a batch ([`shard_rows`]) is work-stealing by
//!   **atomic chunk claim**: every engaged worker deterministically
//!   processes one seed chunk (keeping its pinned workspace warm on every
//!   batch), then workers grab further fixed-size row chunks off a shared
//!   counter until the batch drains — ragged per-row costs or a
//!   descheduled worker cost at most one chunk of tail latency instead of
//!   gating the whole batch behind a static split.
//!
//! Sizing comes from `TS_WORKERS` (`0` and `1` both mean "stay
//! single-threaded"; unset falls back to `available_parallelism` capped at
//! 8 — see [`crate::linalg::workspace::resolve_worker_count`]). Per-batch
//! counts are additionally capped by [`WorkerPool::workers_for`] so a batch
//! never fans out wider than its row count supports.
//!
//! The process-wide default pool is [`WorkerPool::global`]; components that
//! need a pinned worker count (tests, `NativeBackend::with_workers`) own a
//! private pool, whose threads are shut down and joined on drop.

use crate::linalg::workspace::{worker_count_from_env, Workspace, MIN_ROWS_PER_WORKER};
// Atomics come through the loom façade so the `--cfg loom` lane can model
// the chunk-claim counter (see `crate::loom_models`); normal builds get
// std atomics.
use crate::util::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Mutex, OnceLock};
use std::thread::{JoinHandle, ThreadId};

/// A borrowed batch task: invoked once per participating worker with the
/// worker's slot index and its pinned workspace.
type Task<'a> = &'a (dyn Fn(usize, &mut Workspace) + Sync);

/// The `'static`-erased form that crosses the channel. Sound because
/// [`WorkerPool::run`] blocks until every dispatched worker has acked, so
/// the borrow outlives all uses.
type TaskRef = &'static (dyn Fn(usize, &mut Workspace) + Sync);

struct Job {
    task: TaskRef,
}

/// Channel ends the submitting side holds; one mutex serializes whole
/// batches (submit + drain), which also keeps ack accounting trivially
/// correct under concurrent callers. An ack is `None` for success or
/// `Some(panic message)` — carrying the message (instead of a bare bool)
/// lets `run()`'s propagated panic say *what* failed inside the worker,
/// which is what lane supervisors log when a shard kills a lane. Success
/// acks are still allocation-free (`None` carries nothing).
struct ExecState {
    job_txs: Vec<SyncSender<Job>>,
    done_rx: Receiver<Option<String>>,
}

struct PoolInner {
    exec: Mutex<ExecState>,
    thread_ids: Vec<ThreadId>,
    handles: Vec<JoinHandle<()>>,
}

/// Default for [`WorkerPool::min_work_per_worker`]: the estimated work (in
/// ~f32-butterfly-op units, see [`crate::transform::Transform::batch_work_per_row`])
/// a worker must receive before fanning a batch out is worth a wakeup.
/// Calibrated with `tools/bench_mirror.c` on the 2-vCPU authoring box,
/// where a pool round-trip costs ~0.2 ms: shards below ~2 ms of work
/// measured slower pooled than serial there. Deliberately conservative for
/// larger machines (their wakeups are cheaper, but a sub-millisecond batch
/// rarely needs more cores); override with `TS_MIN_WORK` or
/// [`WorkerPool::with_min_work`].
pub const DEFAULT_MIN_WORK_PER_WORKER: usize = 1 << 22;

/// Long-lived batch-execution worker pool. See the module docs.
pub struct WorkerPool {
    size: usize,
    /// Work gate for [`WorkerPool::workers_for_work`]; 0 disables the gate
    /// (row-count rule only).
    min_work_per_worker: usize,
    inner: OnceLock<PoolInner>,
}

impl WorkerPool {
    /// Pool with a pinned worker count (clamped to >= 1) and the default
    /// work gate. Threads are not spawned until the first parallel
    /// [`WorkerPool::run`].
    pub fn new(size: usize) -> WorkerPool {
        WorkerPool::with_min_work(size, DEFAULT_MIN_WORK_PER_WORKER)
    }

    /// Pool with an explicit work gate (`0` disables it — every batch that
    /// clears the row-count floor fans out; used by the bit-parity tests
    /// to force the parallel path on small shapes).
    pub fn with_min_work(size: usize, min_work_per_worker: usize) -> WorkerPool {
        WorkerPool {
            size: size.max(1),
            min_work_per_worker,
            inner: OnceLock::new(),
        }
    }

    /// Pool sized by `TS_WORKERS` / machine parallelism, work gate from
    /// `TS_MIN_WORK` (defaults to [`DEFAULT_MIN_WORK_PER_WORKER`]).
    pub fn from_env() -> WorkerPool {
        let min_work = std::env::var("TS_MIN_WORK")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_MIN_WORK_PER_WORKER);
        WorkerPool::with_min_work(worker_count_from_env(), min_work)
    }

    /// The process-wide shared pool (lazily constructed, never dropped).
    /// This is what the transform trait path, feature maps, LSH index, JLT
    /// and Newton sketch all execute on, so steady-state serving keeps one
    /// set of warm workers regardless of which subsystem a request hits.
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(WorkerPool::from_env)
    }

    /// Maximum workers this pool will ever run (the spawn count).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Hardened per-batch worker resolution: never more than the pool size,
    /// never so many that a worker gets fewer than [`MIN_ROWS_PER_WORKER`]
    /// rows, and always at least 1 (the serial path). `TS_WORKERS=0`,
    /// `TS_WORKERS` larger than the row count, and tiny batches all degrade
    /// to 1 here instead of spawning idle workers or panicking.
    pub fn workers_for(&self, rows: usize) -> usize {
        self.size.min(rows / MIN_ROWS_PER_WORKER).max(1)
    }

    /// [`WorkerPool::workers_for`] plus the work gate: a batch whose total
    /// estimated work (`rows * work_per_row`, in the units of
    /// [`crate::transform::Transform::batch_work_per_row`]) cannot give
    /// every engaged worker at least [`WorkerPool::min_work_per_worker`]
    /// stays serial — waking a worker for less costs more than it saves.
    pub fn workers_for_work(&self, rows: usize, work_per_row: usize) -> usize {
        let by_rows = self.workers_for(rows);
        if self.min_work_per_worker == 0 {
            return by_rows;
        }
        let by_work = rows
            .saturating_mul(work_per_row)
            .checked_div(self.min_work_per_worker)
            .unwrap_or(usize::MAX);
        by_rows.min(by_work).max(1)
    }

    /// Whether the worker threads have been spawned yet. Serial-only
    /// workloads keep this `false` forever.
    pub fn started(&self) -> bool {
        self.inner.get().is_some()
    }

    /// ThreadIds of the worker threads in slot order, spawning them if
    /// needed. Stable for the pool's lifetime — the regression surface for
    /// "no thread is spawned per batch".
    pub fn thread_ids(&self) -> Vec<ThreadId> {
        self.inner().thread_ids.clone()
    }

    /// Run `f` on the caller thread with a **thread-local** serial
    /// workspace. Per-thread (not per-pool-mutex) scratch keeps concurrent
    /// callers — e.g. several coordinator lane threads whose batches all
    /// fall under the work gate — fully parallel: each lane thread warms
    /// and reuses its own workspace, and nobody blocks on a shared lock
    /// for the duration of a kernel. Nested use (a serial task that itself
    /// enters the serial path) falls back to fresh scratch instead of
    /// aliasing the outer borrow.
    pub fn with_serial_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        thread_local! {
            static SERIAL_WS: std::cell::RefCell<Workspace> =
                std::cell::RefCell::new(Workspace::new());
        }
        SERIAL_WS.with(|cell| match cell.try_borrow_mut() {
            Ok(mut ws) => f(&mut ws),
            Err(_) => f(&mut Workspace::new()),
        })
    }

    /// Execute `task` on `workers` pool threads (slot indices
    /// `0..workers`), blocking until all of them finish. `workers <= 1`
    /// runs on the caller thread and never starts the pool. Allocation-free
    /// once the pool is warm.
    ///
    /// Panics if a worker task panics or a worker thread is gone.
    pub fn run(&self, workers: usize, task: Task<'_>) {
        if workers <= 1 {
            self.with_serial_workspace(|ws| task(0, ws));
            return;
        }
        let workers = workers.min(self.size);
        let inner = self.inner();
        // SAFETY: the borrow is erased to 'static only for the duration of
        // this call; the ack-drain below guarantees no worker touches the
        // task after `run` returns (see the send-failure path, which still
        // drains every ack for a successfully dispatched job).
        let task: TaskRef = unsafe { std::mem::transmute::<Task<'_>, TaskRef>(task) };
        let exec = inner
            .exec
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let mut dispatched = 0usize;
        let mut worker_gone = false;
        for tx in &exec.job_txs[..workers] {
            if tx.send(Job { task }).is_err() {
                worker_gone = true;
                break;
            }
            dispatched += 1;
        }
        let mut task_panic: Option<String> = None;
        for _ in 0..dispatched {
            match exec.done_rx.recv() {
                Ok(ack) => {
                    if task_panic.is_none() {
                        task_panic = ack; // keep the first panic message
                    }
                }
                // Err: every worker is gone, so no outstanding borrows.
                Err(_) => {
                    worker_gone = true;
                    break;
                }
            }
        }
        drop(exec);
        assert!(!worker_gone, "worker pool: a worker thread died");
        if let Some(msg) = task_panic {
            panic!("worker pool: a worker task panicked: {msg}");
        }
    }

    fn inner(&self) -> &PoolInner {
        self.inner.get_or_init(|| {
            let (done_tx, done_rx) = sync_channel::<Option<String>>(self.size);
            let mut job_txs = Vec::with_capacity(self.size);
            let mut handles = Vec::with_capacity(self.size);
            for i in 0..self.size {
                // capacity 1: at most one in-flight job per worker (run()
                // acks before the next dispatch), and a bounded channel
                // preallocates its slot — no allocation per send.
                let (tx, rx) = sync_channel::<Job>(1);
                let ack = done_tx.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("ts-worker-{i}"))
                    .spawn(move || worker_loop(i, rx, ack))
                    .expect("spawn worker-pool thread");
                job_txs.push(tx);
                handles.push(handle);
            }
            let thread_ids = handles.iter().map(|h| h.thread().id()).collect();
            PoolInner {
                exec: Mutex::new(ExecState { job_txs, done_rx }),
                thread_ids,
                handles,
            }
        })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            // dropping the job senders ends every worker's recv loop
            drop(inner.exec);
            for h in inner.handles {
                let _ = h.join();
            }
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("size", &self.size)
            .field("started", &self.started())
            .finish()
    }
}

fn worker_loop(index: usize, rx: Receiver<Job>, ack: SyncSender<Option<String>>) {
    // The pinned workspace: lives exactly as long as the worker thread, so
    // scratch warmed by one batch is reused by every later batch.
    let mut ws = Workspace::new();
    while let Ok(job) = rx.recv() {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (job.task)(index, &mut ws);
        }))
        .err()
        .map(|p| crate::util::panic_message(&*p));
        if ack.send(outcome).is_err() {
            return; // pool dropped mid-ack; nothing left to do
        }
    }
}

/// Rows per claimed chunk: aim for several chunks per engaged worker so a
/// slow worker (cache-cold shard, noisy-neighbor core, ragged per-row
/// cost) gates at most one chunk instead of a whole static share.
const CHUNKS_PER_WORKER: usize = 4;

/// Shard `rows` rows across the pool: `task(lo, hi, slot, ws)` is invoked
/// with disjoint, covering `lo..hi` row ranges. `work_per_row` is the
/// caller's per-row cost estimate (see
/// [`crate::transform::Transform::batch_work_per_row`]) feeding the work
/// gate. The standard row-parallel driver used by the transform trait path
/// and the native backend; callers supply the (unsafe, range-disjoint)
/// buffer slicing.
///
/// Distribution is **work-stealing by chunk claim**, not a static split:
/// each engaged worker first processes one statically assigned seed chunk
/// (chunk `slot` — this keeps warm-up deterministic: every engaged
/// worker's pinned workspace is touched on every batch, so "zero
/// allocations after one warm batch" cannot depend on who wins a race),
/// then grabs further fixed-size chunks off a shared atomic counter until
/// the batch is drained. A slow or descheduled worker therefore gates at
/// most its one seed chunk — the others claim the rows it would have been
/// assigned under a static split. A worker may invoke `task` several times
/// (ranges are still disjoint and covering, and results are per-row, so
/// output bytes are identical to any other split). The
/// [`WorkerPool::workers_for_work`] gate is unchanged: sub-threshold
/// batches run serially as a single `task(0, rows, 0, ..)`.
pub fn shard_rows(
    pool: &WorkerPool,
    rows: usize,
    work_per_row: usize,
    task: &(dyn Fn(usize, usize, usize, &mut Workspace) + Sync),
) {
    if rows == 0 {
        return;
    }
    let workers = pool.workers_for_work(rows, work_per_row);
    if workers <= 1 {
        pool.with_serial_workspace(|ws| task(0, rows, 0, ws));
        return;
    }
    let chunk = rows.div_ceil(workers * CHUNKS_PER_WORKER).max(1);
    // chunks [0, workers) are seeds (one per engaged worker, deterministic);
    // the claim counter hands out the rest
    let seeded = (workers * chunk).min(rows);
    let next = AtomicUsize::new(seeded);
    pool.run(workers, &|slot, ws| {
        let lo = slot * chunk;
        if lo < rows {
            task(lo, rows.min(lo + chunk), slot, ws);
        }
        claim_chunks(&next, rows, chunk, |lo, hi| task(lo, hi, slot, ws));
    });
}

/// The chunk-claim loop at the heart of [`shard_rows`]: repeatedly claim
/// `chunk`-sized ranges off the shared counter until `rows` is drained,
/// invoking `claim(lo, hi)` for each claimed range. Factored out — and
/// routed through the loom atomics façade — so the `--cfg loom` CI lane
/// can exhaustively verify that concurrent claimants produce disjoint,
/// covering ranges (`crate::loom_models`), against the production loop
/// rather than a reimplementation.
pub(crate) fn claim_chunks(
    next: &AtomicUsize,
    rows: usize,
    chunk: usize,
    mut claim: impl FnMut(usize, usize),
) {
    loop {
        // ORDERING: Relaxed — fetch_add's RMW atomicity alone makes claimed
        // ranges disjoint and covering; the counter publishes no other
        // memory (row buffers are handed to workers by `pool.run`'s channel
        // send/ack, which synchronize), so no release/acquire is needed.
        let lo = next.fetch_add(chunk, Ordering::Relaxed);
        if lo >= rows {
            break;
        }
        claim(lo, rows.min(lo + chunk));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_run_never_starts_threads() {
        let pool = WorkerPool::new(4);
        let hit = AtomicUsize::new(0);
        pool.run(1, &|i, _ws| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
        assert!(!pool.started(), "workers <= 1 must not spawn threads");
    }

    #[test]
    fn parallel_run_covers_every_slot_once() {
        let pool = WorkerPool::new(3);
        for _ in 0..5 {
            let hits = [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)];
            pool.run(3, &|i, _ws| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::SeqCst), 1);
            }
        }
        assert!(pool.started());
        assert_eq!(pool.thread_ids().len(), 3);
    }

    #[test]
    fn thread_ids_stable_across_batches() {
        let pool = WorkerPool::new(2);
        pool.run(2, &|_i, _ws| {});
        let ids = pool.thread_ids();
        for _ in 0..10 {
            pool.run(2, &|_i, _ws| {});
        }
        assert_eq!(pool.thread_ids(), ids, "no worker may be respawned per batch");
    }

    #[test]
    fn workspaces_are_pinned_per_worker() {
        // A buffer put into slot 1's workspace during one batch must come
        // back (same allocation) in the next batch on the same slot.
        let pool = WorkerPool::new(2);
        let ptrs = Mutex::new([0usize; 2]);
        pool.run(2, &|i, ws| {
            let buf = ws.take_f32(64);
            ptrs.lock().unwrap()[i] = buf.as_ptr() as usize;
            ws.put_f32(buf);
        });
        let first = *ptrs.lock().unwrap();
        pool.run(2, &|i, ws| {
            let buf = ws.take_f32(64);
            assert_eq!(
                buf.as_ptr() as usize,
                ptrs.lock().unwrap()[i],
                "slot {i} must reuse its pinned workspace allocation"
            );
            ws.put_f32(buf);
        });
        assert_ne!(first[0], first[1], "slots own distinct workspaces");
    }

    #[test]
    fn workers_for_hardening() {
        let pool = WorkerPool::new(4);
        // tiny batches stay serial
        assert_eq!(pool.workers_for(0), 1);
        assert_eq!(pool.workers_for(1), 1);
        assert_eq!(pool.workers_for(MIN_ROWS_PER_WORKER - 1), 1);
        // one worker's worth of rows: still serial (no point dispatching)
        assert_eq!(pool.workers_for(MIN_ROWS_PER_WORKER), 1);
        // enough rows for 2 but not 3 full shares
        assert_eq!(pool.workers_for(2 * MIN_ROWS_PER_WORKER), 2);
        // huge batches cap at the pool size
        assert_eq!(pool.workers_for(10_000), 4);
        // pool size larger than any batch's row budget degrades gracefully
        let wide = WorkerPool::new(64);
        assert_eq!(wide.workers_for(2 * MIN_ROWS_PER_WORKER), 2);
        // size 0 clamps to 1
        assert_eq!(WorkerPool::new(0).size(), 1);
    }

    #[test]
    fn run_caps_workers_at_pool_size() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_i, _ws| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn shard_rows_is_disjoint_and_covering() {
        // gate disabled: every row-count-eligible batch must fan out
        let pool = WorkerPool::with_min_work(3, 0);
        for rows in [1usize, 7, 8, 16, 17, 24, 100] {
            let marks = Mutex::new(vec![0u8; rows]);
            shard_rows(&pool, rows, 1, &|lo, hi, _slot, _ws| {
                let mut m = marks.lock().unwrap();
                for r in lo..hi {
                    m[r] += 1;
                }
            });
            let m = marks.lock().unwrap();
            assert!(m.iter().all(|c| *c == 1), "rows={rows}: {m:?}");
        }
    }

    #[test]
    fn shard_rows_chunks_dynamically() {
        // with the chunk-claim counter a large batch must be split into
        // more ranges than workers (so there is something to steal), while
        // every row is still covered exactly once.
        let pool = WorkerPool::with_min_work(2, 0);
        let rows = 64;
        let marks = Mutex::new(vec![0u8; rows]);
        let invocations = AtomicUsize::new(0);
        shard_rows(&pool, rows, 1, &|lo, hi, slot, _ws| {
            assert!(slot < 2);
            invocations.fetch_add(1, Ordering::SeqCst);
            let mut m = marks.lock().unwrap();
            for r in lo..hi {
                m[r] += 1;
            }
        });
        assert!(marks.lock().unwrap().iter().all(|c| *c == 1));
        assert!(
            invocations.load(Ordering::SeqCst) > 2,
            "chunk claiming must produce more ranges than workers"
        );
    }

    #[test]
    fn ragged_shards_are_stolen_from_a_stalled_worker() {
        // Deliberately imbalanced per-row cost: whichever worker claims the
        // chunk containing row 0 BLOCKS until every other chunk has been
        // claimed — with the old static split the batch could never finish
        // (half the rows would sit behind the stalled worker). Under chunk
        // claiming the other worker drains the counter, the stalled worker
        // unblocks, and the batch completes with every row covered once.
        let pool = WorkerPool::with_min_work(2, 0);
        let rows = 64;
        let marks = Mutex::new(vec![0u8; rows]);
        let claimed = AtomicUsize::new(0);
        shard_rows(&pool, rows, 1, &|lo, hi, _slot, _ws| {
            claimed.fetch_add(hi - lo, Ordering::SeqCst);
            if lo == 0 {
                // the "slow" shard: wait until the rest of the batch has
                // been claimed by someone else (bounded, so a regression
                // fails loudly instead of hanging)
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                while claimed.load(Ordering::SeqCst) < rows {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "no other worker stole the remaining chunks"
                    );
                    std::thread::yield_now();
                }
            }
            let mut m = marks.lock().unwrap();
            for r in lo..hi {
                m[r] += 1;
            }
        });
        let m = marks.lock().unwrap();
        assert!(m.iter().all(|c| *c == 1), "{m:?}");
    }

    #[test]
    fn work_gate_keeps_cheap_batches_serial() {
        let pool = WorkerPool::with_min_work(4, 1000);
        // plenty of rows, but 10 units each: 320 units total < 1000/worker
        assert_eq!(pool.workers_for_work(32, 10), 1);
        // 2000 units total: one extra worker's worth
        assert_eq!(pool.workers_for_work(32, 63), 2);
        // heavy rows: row-count floor still caps the fan-out
        assert_eq!(pool.workers_for_work(16, 1_000_000), 2);
        assert_eq!(pool.workers_for_work(7, 1_000_000), 1);
        // gate disabled -> row rule only
        let ungated = WorkerPool::with_min_work(4, 0);
        assert_eq!(ungated.workers_for_work(32, 1), 4);
        // overflow-proof
        assert_eq!(pool.workers_for_work(usize::MAX, usize::MAX), 4);
    }

    #[test]
    fn borrowed_state_is_visible_and_complete() {
        // the whole point of the transmute: workers mutate caller-borrowed
        // buffers, and run() returns only after every write landed.
        let pool = WorkerPool::new(4);
        let mut data = vec![0u32; 64];
        {
            let ptr = data.as_mut_ptr() as usize;
            pool.run(4, &|i, _ws| {
                // SAFETY: each worker slot writes a disjoint 16-element
                // window of the 64-element Vec, which outlives the call.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut((ptr as *mut u32).add(i * 16), 16)
                };
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 16 + j) as u32;
                }
            });
        }
        for (j, v) in data.iter().enumerate() {
            assert_eq!(*v, j as u32);
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|i, _ws| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the caller");
        // the propagated panic carries the worker task's own message
        let msg = crate::util::panic_message(&*r.unwrap_err());
        assert!(msg.contains("boom"), "panic message lost: {msg}");
        // the pool still works afterwards
        let hits = AtomicUsize::new(0);
        pool.run(2, &|_i, _ws| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(WorkerPool::global().size() >= 1);
    }
}
