//! Thread-hosted runtime service.
//!
//! The `xla` crate's PJRT handles are `Rc`-based and must not cross
//! threads. [`RuntimeService::spawn`] starts one dedicated thread that owns
//! the [`Executor`]; [`RuntimeHandle`] is a cheap, cloneable, `Send + Sync`
//! front the coordinator's workers use to execute artifacts.
//!
//! The service thread is a single point of failure shared by every lane of
//! a PJRT-backed coordinator, so executor calls are panic-isolated: a panic
//! inside `run`/`verify_golden` is caught and returned to the caller as an
//! [`ExecError`] instead of killing the thread (which would turn one bad
//! request into `runtime thread gone` for every lane, permanently).

use super::executor::{ExecError, Executor, Output};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Cmd {
    Run {
        name: String,
        /// Shared buffers: callers with long-lived parameters (the
        /// coordinator backends) pass `Arc` clones so nothing is deep-copied
        /// per request; one-shot callers wrap owned vectors.
        inputs: Vec<Arc<Vec<f32>>>,
        reply: mpsc::Sender<Result<Output, ExecError>>,
    },
    Names {
        reply: mpsc::Sender<Vec<String>>,
    },
    VerifyGolden {
        name: String,
        reply: mpsc::Sender<Result<Option<(f64, usize)>, ExecError>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Arc<Mutex<mpsc::Sender<Cmd>>>,
}

impl RuntimeHandle {
    fn send(&self, cmd: Cmd) -> Result<(), ExecError> {
        self.tx
            .lock()
            .map_err(|_| ExecError("runtime handle poisoned".into()))?
            .send(cmd)
            .map_err(|_| ExecError("runtime thread gone".into()))
    }

    /// Execute an artifact by name (blocking), taking ownership of the
    /// input buffers. Thin wrapper over [`RuntimeHandle::run_shared`].
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Output, ExecError> {
        self.run_shared(name, inputs.into_iter().map(Arc::new).collect())
    }

    /// Execute an artifact by name (blocking) over shared input buffers:
    /// cached parameters cross the thread boundary as refcount bumps, not
    /// deep copies.
    pub fn run_shared(
        &self,
        name: &str,
        inputs: Vec<Arc<Vec<f32>>>,
    ) -> Result<Output, ExecError> {
        let (reply, rx) = mpsc::channel();
        self.send(Cmd::Run {
            name: name.to_string(),
            inputs,
            reply,
        })?;
        rx.recv()
            .map_err(|_| ExecError("runtime thread dropped reply".into()))?
    }

    /// Names of all loaded artifacts.
    pub fn names(&self) -> Result<Vec<String>, ExecError> {
        let (reply, rx) = mpsc::channel();
        self.send(Cmd::Names { reply })?;
        rx.recv().map_err(|_| ExecError("runtime thread gone".into()))
    }

    /// Verify an artifact against its golden vectors.
    pub fn verify_golden(&self, name: &str) -> Result<Option<(f64, usize)>, ExecError> {
        let (reply, rx) = mpsc::channel();
        self.send(Cmd::VerifyGolden {
            name: name.to_string(),
            reply,
        })?;
        rx.recv()
            .map_err(|_| ExecError("runtime thread dropped reply".into()))?
    }
}

/// Run one executor call with panic isolation: a panicking artifact
/// surfaces as an `ExecError` on that request's reply channel while the
/// service thread (and every other lane's requests) keeps going.
fn isolated<T>(f: impl FnOnce() -> Result<T, ExecError>) -> Result<T, ExecError> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).unwrap_or_else(|p| {
        Err(ExecError(format!(
            "executor panicked: {}",
            crate::util::panic_message(&*p)
        )))
    })
}

/// The running service (join on drop via [`RuntimeService::shutdown`]).
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Spawn the runtime thread; blocks until artifacts are loaded and
    /// compiled (so startup errors surface immediately).
    pub fn spawn(artifact_dir: PathBuf) -> Result<RuntimeService, ExecError> {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), ExecError>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let exec = match Executor::load_dir(&artifact_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Run {
                            name,
                            inputs,
                            reply,
                        } => {
                            let refs: Vec<&[f32]> =
                                inputs.iter().map(|v| v.as_slice()).collect();
                            let _ = reply.send(isolated(|| exec.run(&name, &refs)));
                        }
                        Cmd::Names { reply } => {
                            let _ = reply.send(
                                exec.names().into_iter().map(str::to_string).collect(),
                            );
                        }
                        Cmd::VerifyGolden { name, reply } => {
                            let _ = reply.send(isolated(|| exec.verify_golden(&name)));
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })
            .map_err(|e| ExecError(format!("spawn runtime thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| ExecError("runtime thread died during load".into()))??;
        Ok(RuntimeService {
            handle: RuntimeHandle {
                tx: Arc::new(Mutex::new(tx)),
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    /// Stop the runtime thread and wait for it.
    pub fn shutdown(mut self) {
        let _ = self.handle.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.handle.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_fails_cleanly_on_missing_dir() {
        let r = RuntimeService::spawn(PathBuf::from("/nonexistent/artifacts"));
        assert!(r.is_err());
    }
}
