//! PJRT executor: loads HLO-text artifacts and runs them on the CPU client.
//!
//! The `xla` crate's handles are `Rc`-based (not `Send`), so all PJRT state
//! lives on whatever thread constructs [`Executor`]; cross-thread access
//! goes through [`super::service::RuntimeHandle`].
//!
//! **Offline build note:** the `xla` crate is not part of the offline
//! vendor set, so PJRT execution is stubbed out: manifest parsing, input
//! validation and the whole `Executor` surface compile and behave normally,
//! but loading a non-empty artifact directory fails with a clear error and
//! the native backend remains the execution path. Vendored `xla` back in,
//! [`PjrtExecutable`] is the single seam to reconnect.

use super::manifest::{ArtifactSpec, Manifest};
use crate::util::json::Json;
use std::collections::HashMap;
use std::fmt;
use std::path::Path;

/// Execution output: f32 tensor, i32 tensor (crosspolytope ids), or
/// packed bit words (binary embeddings — `⌈n/64⌉` `u64` words per row,
/// bit `i % 64` of word `i / 64` = projection coordinate `i` negative).
#[derive(Clone, Debug, PartialEq)]
pub enum Output {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Bits(Vec<u64>),
}

impl Output {
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Output::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Output::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bits(&self) -> Option<&[u64]> {
        match self {
            Output::Bits(v) => Some(v),
            _ => None,
        }
    }
}

/// Executor error.
#[derive(Debug)]
pub struct ExecError(pub String);

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "executor error: {}", self.0)
    }
}

impl std::error::Error for ExecError {}

fn xerr<E: fmt::Display>(ctx: &str) -> impl FnOnce(E) -> ExecError + '_ {
    move |e| ExecError(format!("{ctx}: {e}"))
}

/// Stand-in for `xla::PjRtLoadedExecutable` while the `xla` crate is absent
/// from the offline vendor set. Never constructed — [`Executor::load_dir`]
/// refuses non-empty artifact directories — so [`Executor::run`] can only
/// ever report the stub error through it.
#[allow(dead_code)] // constructed only once the real `xla` crate returns
struct PjrtExecutable;

impl PjrtExecutable {
    fn execute(&self, name: &str) -> Result<Output, ExecError> {
        Err(ExecError(format!(
            "cannot execute '{name}': PJRT support is not compiled into \
             this build (the `xla` crate is absent from the offline vendor \
             set) — use the native backend"
        )))
    }
}

struct Loaded {
    spec: ArtifactSpec,
    exe: PjrtExecutable,
}

/// Owns the PJRT client and all compiled executables.
pub struct Executor {
    models: HashMap<String, Loaded>,
    manifest: Manifest,
}

impl Executor {
    /// Load every artifact in `<dir>/manifest.json` and compile it on the
    /// PJRT CPU client. In this offline build, artifact compilation is
    /// unavailable: an empty manifest loads fine (so `info` and the service
    /// plumbing keep working), a non-empty one is refused up front.
    pub fn load_dir(dir: &Path) -> Result<Executor, ExecError> {
        let manifest = Manifest::load(dir).map_err(|e| ExecError(e.to_string()))?;
        if let Some(spec) = manifest.artifacts.first() {
            return Err(ExecError(format!(
                "cannot compile artifact '{}': PJRT support is not compiled \
                 into this build (the `xla` crate is absent from the offline \
                 vendor set) — use the native backend",
                spec.name
            )));
        }
        Ok(Executor {
            models: HashMap::new(),
            manifest,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.models.get(name).map(|l| &l.spec)
    }

    /// Execute an artifact by name. `inputs` are flat f32 buffers matching
    /// the manifest's parameter shapes (validated here).
    pub fn run(&self, name: &str, inputs: &[&[f32]]) -> Result<Output, ExecError> {
        let loaded = self
            .models
            .get(name)
            .ok_or_else(|| ExecError(format!("unknown artifact '{name}'")))?;
        let spec = &loaded.spec;
        if inputs.len() != spec.inputs.len() {
            return Err(ExecError(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (buf, shape) in inputs.iter().zip(&spec.inputs) {
            let numel: usize = shape.iter().product();
            if buf.len() != numel {
                return Err(ExecError(format!(
                    "{name}: input numel {} != shape {:?}",
                    buf.len(),
                    shape
                )));
            }
        }
        loaded.exe.execute(name)
    }

    /// Run the artifact's golden vectors (if present): returns
    /// `(max_abs_err, numel)` between PJRT output and the Python-side
    /// golden output. Used by integration tests and `triplespin verify`.
    pub fn verify_golden(&self, name: &str) -> Result<Option<(f64, usize)>, ExecError> {
        let spec = self
            .spec(name)
            .ok_or_else(|| ExecError(format!("unknown artifact '{name}'")))?
            .clone();
        let Some(golden_file) = &spec.golden else {
            return Ok(None);
        };
        let text = std::fs::read_to_string(self.manifest.dir.join(golden_file))
            .map_err(xerr("read golden"))?;
        let doc = Json::parse(&text).map_err(xerr("parse golden"))?;
        let inputs: Vec<Vec<f32>> = doc
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| ExecError("golden: missing inputs".into()))?
            .iter()
            .map(|arr| {
                arr.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(f64::NAN) as f32)
                    .collect()
            })
            .collect();
        let want: Vec<f64> = doc
            .get("output")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| ExecError("golden: missing output".into()))?
            .iter()
            .map(|x| x.as_f64().unwrap_or(f64::NAN))
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let got = self.run(name, &refs)?;
        let got_f64: Vec<f64> = match &got {
            Output::F32(v) => v.iter().map(|x| *x as f64).collect(),
            Output::I32(v) => v.iter().map(|x| *x as f64).collect(),
            // no compiled artifact emits packed words today; compare bits
            // as integers if one ever does
            Output::Bits(v) => v.iter().map(|x| *x as f64).collect(),
        };
        if got_f64.len() != want.len() {
            return Err(ExecError(format!(
                "{name}: golden output numel {} != got {}",
                want.len(),
                got_f64.len()
            )));
        }
        let max_err = got_f64
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        Ok(Some((max_err, want.len())))
    }
}

// NOTE: no unit tests here — Executor needs real artifacts; covered by
// rust/tests/runtime_integration.rs (runs after `make artifacts`).
