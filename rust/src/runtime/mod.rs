//! Execution runtime: the persistent batch worker pool, plus the PJRT
//! artifact path (`artifacts/*.hlo.txt` lowered by
//! `python/compile/aot.py`).
//!
//! * [`pool`] — long-lived worker pool with pinned per-worker workspaces;
//!   every batch consumer (transform trait path, native backend, feature
//!   maps, LSH, JLT, Newton sketch) shards rows through it.
//! * [`manifest`] — parses/validates `artifacts/manifest.json`.
//! * [`executor`] — PJRT CPU client + compiled executables (single thread).
//! * [`service`] — thread-hosted executor with a `Send + Sync` handle.

pub mod executor;
pub mod manifest;
pub mod pool;
pub mod service;

pub use executor::{ExecError, Executor, Output};
pub use manifest::{ArtifactSpec, Manifest, Op};
pub use pool::WorkerPool;
pub use service::{RuntimeHandle, RuntimeService};
