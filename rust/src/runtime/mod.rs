//! PJRT runtime: load `artifacts/*.hlo.txt` (lowered by
//! `python/compile/aot.py`) and execute them from the Rust request path.
//!
//! * [`manifest`] — parses/validates `artifacts/manifest.json`.
//! * [`executor`] — PJRT CPU client + compiled executables (single thread).
//! * [`service`] — thread-hosted executor with a `Send + Sync` handle.

pub mod executor;
pub mod manifest;
pub mod service;

pub use executor::{ExecError, Executor, Output};
pub use manifest::{ArtifactSpec, Manifest, Op};
pub use service::{RuntimeHandle, RuntimeService};
