//! Binary embeddings: sign-quantized structured projections packed into
//! bit matrices (the paper's "certain models … apply only bit matrices"
//! compressibility claim, built out per "Binary embeddings with structured
//! hashed projections" [Choromanska et al.] and the ternary/1-bit feature
//! maps of Tiomoko Ali & Liao).
//!
//! The pipeline is `code(x) = sign(G_struct x)` with `G_struct` any
//! [`Transform`] family: the projection keeps angular geometry (per-bit
//! flip probability between two inputs is exactly `θ/π`, the SimHash
//! identity), so packed codes support Hamming-distance search and 1-bit
//! kernel estimates at 1/32 the bytes of the f32 feature vector.
//!
//! ## Packed word layout
//!
//! A code of `k = dim_out()` bits occupies `⌈k/64⌉` `u64` words: bit
//! `i % 64` of word `i / 64` is set iff projection coordinate `i` is
//! **sign-negative** (`f32::is_sign_negative`, i.e. the raw IEEE sign
//! bit — the same "bit set = negative" convention as
//! [`crate::transform::SignDiag`], and exactly what the x86 `movemask`
//! kernels extract, so every SIMD tier packs identical words). Trailing
//! bits of the last word are always zero, which keeps bucket keys and
//! Hamming distances well-defined. Rows of a [`BitMatrix`] are contiguous
//! at a stride of `words_per_row` words.
//!
//! Quantization runs **fused into the last transform stage**: the batch
//! path shards rows over the persistent [`WorkerPool`], and each worker
//! projects its row block into scratch drawn from its pinned
//! [`Workspace`] and immediately packs the signs — the f32 projection of
//! the whole batch is never materialized. Distances are popcounts over
//! the XOR stream ([`simd::hamming`], AVX2 `vpshufb`+`vpsadbw` with a
//! bit-identical scalar lane).
//!
//! ## Footprint accounting
//!
//! [`Transform::stored_bits`] already reports the *parameter* footprint
//! (~`3n` bits for the fully discrete chain). [`BinaryEmbedding::output_bits`]
//! reports the *per-embedding output* footprint: `k` bits vs `32k` for the
//! f32 vector — the 32× response compression the serving layer's
//! `binary_embed` lane ships.

use crate::linalg::simd;
use crate::linalg::Workspace;
use crate::runtime::pool::{shard_rows, WorkerPool};
use crate::transform::{make_square, Family, Transform};
use crate::util::rng::Rng;

/// A packed bit vector (one binary embedding): `bits` valid bits in
/// `⌈bits/64⌉` words, trailing bits zero.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    bits: usize,
}

impl BitVec {
    /// All-zero code of `bits` bits.
    pub fn zeros(bits: usize) -> BitVec {
        BitVec {
            words: vec![0u64; bits.div_ceil(64)],
            bits,
        }
    }

    /// Sign-quantize a float vector: bit `i` set iff `y[i]` is
    /// sign-negative (see the module docs for the exact convention).
    pub fn from_signs(y: &[f32]) -> BitVec {
        let mut v = BitVec::zeros(y.len());
        simd::pack_signs(y, &mut v.words);
        v
    }

    /// Wrap already-packed words as a `bits`-bit code. Trailing bits of
    /// the last word are cleared so distances stay well-defined.
    pub fn from_words(mut words: Vec<u64>, bits: usize) -> BitVec {
        assert_eq!(words.len(), bits.div_ceil(64));
        if bits % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last &= (1u64 << (bits % 64)) - 1;
            }
        }
        BitVec { words, bits }
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// The packed words (read-only; trailing bits guaranteed zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Bit `i` as a bool (`true` = the projection coordinate was negative).
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.bits);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Bytes this code occupies in memory (whole words).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Hamming distance to another code of the same width.
    pub fn hamming(&self, other: &BitVec) -> u64 {
        assert_eq!(self.bits, other.bits, "code widths differ");
        simd::hamming(&self.words, &other.words)
    }
}

/// A row-major matrix of packed codes: `rows` codes of `bits` bits each,
/// one row every `words_per_row()` words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMatrix {
    words: Vec<u64>,
    rows: usize,
    bits: usize,
    words_per_row: usize,
}

impl BitMatrix {
    pub fn zeros(rows: usize, bits: usize) -> BitMatrix {
        let words_per_row = bits.div_ceil(64);
        BitMatrix {
            words: vec![0u64; rows * words_per_row],
            rows,
            bits,
            words_per_row,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Code `r` as its packed words.
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [u64] {
        &mut self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// The whole packed buffer (row-major, `rows * words_per_row` words).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Hamming distance between row `r` and an external code's words.
    pub fn hamming_to(&self, r: usize, code: &[u64]) -> u64 {
        simd::hamming(self.row(r), code)
    }

    /// Total bytes of the packed matrix.
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Angular-similarity estimate from a Hamming distance over `bits`-bit
/// codes: `1 - 2·d_H/k`. For sign codes of the same random projection this
/// equals the dense angular sign-feature estimate `Φ(x)ᵀΦ(y)` (each
/// agreeing bit contributes `+1/k`, each differing bit `-1/k`), and its
/// expectation is the exact angular kernel `1 - 2θ/π`.
pub fn angular_estimate(hamming: u64, bits: usize) -> f64 {
    assert!(bits > 0);
    1.0 - 2.0 * hamming as f64 / bits as f64
}

/// Sign-quantize one (possibly short, zero-padded) input through `t` into
/// packed words (`out.len() == t.dim_out().div_ceil(64)`), all scratch
/// drawn from `ws`. The single fused project+pack primitive every binary
/// code producer ([`BinaryEmbedding`], the `kernels` 1-bit feature path)
/// routes through: the projection lives only in workspace scratch, `out`
/// receives nothing but sign bits.
pub fn pack_projection_into(t: &dyn Transform, x: &[f32], out: &mut [u64], ws: &mut Workspace) {
    let k = t.dim_out();
    debug_assert_eq!(out.len(), k.div_ceil(64));
    let mut proj = ws.take_f32_uninit(k); // OVERWRITE: fully overwritten
    t.apply_padded_into(x, &mut proj, ws);
    simd::pack_signs(&proj, out);
    ws.put_f32(proj);
}

/// Batch counterpart of [`pack_projection_into`]: `xs` holds row-major
/// inputs of `t.dim_in()` (already padded), `out` one packed code row per
/// input. Rows shard over the persistent [`WorkerPool`]; each worker
/// projects its row block through the family's serial batch kernel into
/// its pinned workspace and packs the signs in place — the sign pass is
/// fused into the last transform stage, so the batch's f32 projection is
/// never materialized. Bit-identical per row to the single-input path.
/// This is the one audited unsafe row-sharding for binary codes.
pub fn pack_projection_batch_into(
    t: &dyn Transform,
    xs: &[f32],
    out: &mut BitMatrix,
    pool: &WorkerPool,
) {
    let n = t.dim_in();
    debug_assert_eq!(xs.len() % n.max(1), 0);
    let rows = if n == 0 { 0 } else { xs.len() / n };
    let k = t.dim_out();
    assert_eq!(out.rows(), rows);
    assert_eq!(out.bits(), k);
    if rows == 0 {
        return;
    }
    let wpr = out.words_per_row();
    let out_ptr = out.words_mut().as_mut_ptr() as usize;
    // pack cost is ~k/32 of the projection's — batch_work_per_row alone is
    // the right gate estimate
    let work = t.batch_work_per_row();
    shard_rows(pool, rows, work, &|lo, hi, _slot, ws| {
        let block = hi - lo;
        let mut proj = ws.take_f32_uninit(block * k); // OVERWRITE: fully overwritten
        t.apply_batch_serial(&xs[lo * n..hi * n], &mut proj, ws);
        // SAFETY: shard_rows hands out disjoint, covering row ranges and
        // blocks until every worker acked — no aliasing, no write outlives
        // this call.
        let oc = unsafe {
            std::slice::from_raw_parts_mut((out_ptr as *mut u64).add(lo * wpr), block * wpr)
        };
        for (prow, orow) in proj.chunks_exact(k).zip(oc.chunks_exact_mut(wpr)) {
            simd::pack_signs(prow, orow);
        }
        ws.put_f32(proj);
    });
}

/// A binary embedding: `code(x) = sign(G_struct x)` packed into `u64`
/// words. Wraps any [`Transform`]; the code width is the transform's
/// `dim_out()`.
pub struct BinaryEmbedding {
    transform: Box<dyn Transform>,
}

impl BinaryEmbedding {
    pub fn new(transform: Box<dyn Transform>) -> BinaryEmbedding {
        BinaryEmbedding { transform }
    }

    /// Square construction of the given family (`n` bits out for `n` in).
    pub fn with_family(family: Family, n: usize, rng: &mut Rng) -> BinaryEmbedding {
        BinaryEmbedding {
            transform: make_square(family, n, rng),
        }
    }

    /// Input dimensionality (shorter inputs are zero-padded).
    pub fn dim_in(&self) -> usize {
        self.transform.dim_in()
    }

    /// Code width in bits (= the transform's output dimensionality).
    pub fn code_bits(&self) -> usize {
        self.transform.dim_out()
    }

    /// Packed words per code (`⌈code_bits/64⌉`).
    pub fn words_per_code(&self) -> usize {
        self.code_bits().div_ceil(64)
    }

    /// Per-embedding output footprint in bits — the serving-response size.
    /// The f32 vector this code replaces costs `32 · code_bits()` bits.
    pub fn output_bits(&self) -> usize {
        self.words_per_code() * 64
    }

    /// Parameter footprint of the wrapped transform (see
    /// [`Transform::stored_bits`]); with a discrete family the whole model
    /// is bits end to end — parameters and outputs.
    pub fn stored_bits(&self) -> usize {
        self.transform.stored_bits()
    }

    /// The wrapped transform.
    pub fn transform(&self) -> &dyn Transform {
        self.transform.as_ref()
    }

    /// Embed one (possibly short) input into `out` packed words
    /// (`out.len() == words_per_code()`), all scratch drawn from `ws` —
    /// the zero-allocation path (see [`pack_projection_into`]).
    pub fn embed_into(&self, x: &[f32], out: &mut [u64], ws: &mut Workspace) {
        pack_projection_into(self.transform.as_ref(), x, out, ws);
    }

    /// Embed one input. Thin allocating wrapper over
    /// [`BinaryEmbedding::embed_into`].
    pub fn embed(&self, x: &[f32]) -> BitVec {
        let mut v = BitVec::zeros(self.code_bits());
        let mut ws = Workspace::new();
        self.embed_into(x, &mut v.words, &mut ws);
        v
    }

    /// Batch embed: `xs` holds `rows` row-major inputs of `dim_in()`
    /// (already padded), `out` receives `rows` packed codes — the fused
    /// pool-sharded path (see [`pack_projection_batch_into`]).
    /// Bit-identical per row to [`BinaryEmbedding::embed_into`].
    pub fn embed_batch_into(&self, xs: &[f32], out: &mut BitMatrix, pool: &WorkerPool) {
        pack_projection_batch_into(self.transform.as_ref(), xs, out, pool);
    }

    /// Allocating wrapper over [`BinaryEmbedding::embed_batch_into`] on the
    /// process-wide pool.
    pub fn embed_batch(&self, xs: &[f32]) -> BitMatrix {
        let n = self.transform.dim_in();
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        let mut out = BitMatrix::zeros(rows, self.code_bits());
        self.embed_batch_into(xs, &mut out, WorkerPool::global());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::make;

    const ALL_FAMILIES: [Family; 7] = [
        Family::Dense,
        Family::Hd3,
        Family::Hdg,
        Family::Circulant,
        Family::Toeplitz,
        Family::Hankel,
        Family::SkewCirculant,
    ];

    /// The naive contract: packed embed == sign(dense apply), bit for bit.
    fn naive_code(t: &dyn Transform, x: &[f32]) -> BitVec {
        let n = t.dim_in();
        let mut padded = vec![0.0f32; n];
        padded[..x.len()].copy_from_slice(x);
        let y = t.apply(&padded);
        let mut v = BitVec::zeros(y.len());
        for (i, val) in y.iter().enumerate() {
            if val.is_sign_negative() {
                v.words[i / 64] |= 1 << (i % 64);
            }
        }
        v
    }

    #[test]
    fn embed_matches_naive_sign_of_dense_apply() {
        for fam in ALL_FAMILIES {
            for n in [16usize, 64, 128] {
                let emb = BinaryEmbedding::with_family(fam, n, &mut Rng::new(5 + n as u64));
                let x = Rng::new(9).gaussian_vec(n);
                let got = emb.embed(&x);
                let want = naive_code(emb.transform(), &x);
                assert_eq!(got, want, "{fam:?} n={n}");
                assert_eq!(got.bits(), n);
            }
        }
    }

    #[test]
    fn embed_batch_matches_single_rowwise() {
        let n = 64;
        for fam in [Family::Hd3, Family::Toeplitz] {
            // stacked/truncated shape too: 96-bit codes from 64-dim inputs
            let t = make(fam, 96, n, 32, &mut Rng::new(11));
            let emb = BinaryEmbedding::new(t);
            let rows = 40;
            let xs = Rng::new(12).gaussian_vec(rows * n);
            let pool = WorkerPool::with_min_work(4, 0); // force the parallel path
            let mut batch = BitMatrix::zeros(rows, emb.code_bits());
            // twice through the same pool: reused pinned workspaces stay clean
            for _ in 0..2 {
                emb.embed_batch_into(&xs, &mut batch, &pool);
                for (r, row) in xs.chunks_exact(n).enumerate() {
                    let single = emb.embed(row);
                    assert_eq!(batch.row(r), single.words(), "{fam:?} row {r}");
                }
            }
        }
    }

    #[test]
    fn hamming_and_angular_estimate() {
        let a = BitVec::from_signs(&[1.0, -1.0, 1.0, -1.0]);
        let b = BitVec::from_signs(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
        assert_eq!(angular_estimate(a.hamming(&b), 4), 0.0);
        assert_eq!(angular_estimate(0, 4), 1.0);
        assert_eq!(angular_estimate(4, 4), -1.0);
    }

    #[test]
    fn antipodal_codes_are_complementary() {
        // sign(G(-x)) = ¬sign(G x) for sign-symmetric outputs: Hamming
        // distance between x and -x codes is the full code width.
        let n = 128;
        let emb = BinaryEmbedding::with_family(Family::Hd3, n, &mut Rng::new(3));
        let x = Rng::new(4).unit_vec(n);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        assert_eq!(emb.embed(&x).hamming(&emb.embed(&neg)), n as u64);
    }

    #[test]
    fn footprint_accounting_is_32x() {
        let n = 256;
        let emb = BinaryEmbedding::with_family(Family::Hd3, n, &mut Rng::new(7));
        assert_eq!(emb.code_bits(), n);
        assert_eq!(emb.words_per_code(), 4);
        assert_eq!(emb.output_bits(), n);
        // 32x smaller than the f32 output it replaces: the f32 lane ships
        // 32 bits per coordinate, the packed lane 1
        assert_eq!((32 * emb.code_bits()) / emb.output_bits(), 32);
        let ones = vec![1.0f32; n];
        // 32 bytes packed vs 4n bytes of f32
        assert_eq!(emb.embed(&ones).storage_bytes() * 32, 4 * n);
        // parameters are bits too for the discrete chain
        assert_eq!(emb.stored_bits(), 3 * n);
    }

    #[test]
    fn bitmatrix_layout() {
        let mut m = BitMatrix::zeros(3, 100);
        assert_eq!(m.words_per_row(), 2);
        assert_eq!(m.storage_bytes(), 3 * 2 * 8);
        m.row_mut(1)[0] = 0b1011;
        assert_eq!(m.row(0), &[0, 0]);
        assert_eq!(m.row(1), &[0b1011, 0]);
        assert_eq!(m.hamming_to(1, &[0b1000, 0]), 2);
        let empty = BitMatrix::zeros(0, 64);
        assert!(empty.is_empty());
    }
}
