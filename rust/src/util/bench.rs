//! Mini benchmark harness (criterion is not in the offline vendor set).
//!
//! Each bench binary sets `harness = false` in `Cargo.toml` and drives this
//! module directly. The harness does warmup, adaptively picks an iteration
//! count targeting a fixed measurement window, and reports mean / p50 / p95
//! per-iteration times. Results can also be collected programmatically so a
//! bench binary can print paper-style tables (e.g. Table 1's speedup rows).

use std::time::{Duration, Instant};

/// A single measurement summary, per-iteration times in nanoseconds.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Summary {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark options.
#[derive(Clone, Copy, Debug)]
pub struct Opts {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Max number of timed samples (batches).
    pub max_samples: usize,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_samples: 50,
        }
    }
}

/// Quick options for expensive end-to-end benches.
pub fn quick() -> Opts {
    Opts {
        warmup: Duration::from_millis(50),
        measure: Duration::from_millis(300),
        max_samples: 20,
    }
}

/// Time `f` under `opts`, returning a summary. `f` is invoked repeatedly;
/// use `std::hint::black_box` inside to defeat dead-code elimination.
pub fn bench<F: FnMut()>(name: &str, opts: Opts, mut f: F) -> Summary {
    // Warmup and estimate per-call cost.
    let wu_start = Instant::now();
    let mut calls = 0u64;
    while wu_start.elapsed() < opts.warmup || calls == 0 {
        f();
        calls += 1;
        if calls > 1_000_000 {
            break;
        }
    }
    let per_call = wu_start.elapsed().as_nanos() as f64 / calls as f64;

    // Choose batch size so each sample is ~measure/max_samples.
    let sample_target_ns = opts.measure.as_nanos() as f64 / opts.max_samples as f64;
    let batch = ((sample_target_ns / per_call.max(1.0)).ceil() as usize).max(1);

    let mut samples: Vec<f64> = Vec::with_capacity(opts.max_samples);
    let m_start = Instant::now();
    while m_start.elapsed() < opts.measure && samples.len() < opts.max_samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    if samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }

    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    Summary {
        name: name.to_string(),
        iters: samples.len() * batch,
        mean_ns: mean,
        p50_ns: p(0.5),
        p95_ns: p(0.95),
        min_ns: samples[0],
    }
}

/// Bench and print a one-line report.
pub fn run<F: FnMut()>(name: &str, opts: Opts, f: F) -> Summary {
    let s = bench(name, opts, f);
    println!(
        "{:<44} {:>12}/iter  p50 {:>12}  p95 {:>12}  ({} iters)",
        s.name,
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p95_ns),
        s.iters
    );
    s
}

/// Print a markdown-style table: rows of (label, values per column).
pub fn print_table(title: &str, columns: &[String], rows: &[(String, Vec<String>)]) {
    println!("\n== {title} ==");
    print!("{:<36}", "");
    for c in columns {
        print!(" {c:>10}");
    }
    println!();
    for (label, vals) in rows {
        print!("{label:<36}");
        for v in vals {
            print!(" {v:>10}");
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = Opts {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_samples: 10,
        };
        let mut x = 0u64;
        let s = bench("noop-ish", opts, || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(s.iters > 0);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns + 1.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
