//! Minimal JSON parser + emitter.
//!
//! The offline vendor set has no `serde`/`serde_json`; the library needs
//! JSON in exactly three places — the AOT artifact manifest written by
//! `python/compile/aot.py`, coordinator configuration files, and metrics
//! dumps — so a small, strict implementation is plenty.
//!
//! Supported: objects, arrays, strings (with \uXXXX escapes), numbers,
//! booleans, null. Numbers are stored as f64 (the manifest only carries
//! shapes and names; precision is not a concern).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    /// Emit compact JSON.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // handle surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x", "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
        // surrogate pair (emoji)
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn emit_round_trip() {
        let doc = r#"{"arr":[1,2.5,null,true],"s":"a\"b\\c\nd","z":{"k":-7}}"#;
        let v = Json::parse(doc).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn helpers() {
        let v = Json::parse(r#"{"n": 5, "f": 5.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("missing"), None);
    }
}
