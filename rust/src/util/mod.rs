//! Dependency-free utilities: seeded RNG, JSON, bench + property harnesses.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
