//! Dependency-free utilities: seeded RNG, JSON, bench + property harnesses.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod signal;
pub(crate) mod sync;

/// Best-effort text of a caught panic payload. `panic!("...")` and
/// `panic!("{x}")` produce `&str` / `String` payloads; anything else (a
/// custom `panic_any` value) collapses to a placeholder so fault reports
/// never lose the *fact* of the panic even when its payload is opaque.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
