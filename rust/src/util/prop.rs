//! Tiny property-testing harness (proptest is not in the offline vendor set).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! harness runs it for `cases` different seeds; on panic it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath linker flags
//! use triplespin::util::prop::{for_all, Gen};
//! for_all(64, |g: &mut Gen| {
//!     let n = g.usize_in(1, 32);
//!     let v = g.vec_f32(n, -1.0, 1.0);
//!     let sum: f32 = v.iter().sum();
//!     assert!(sum.abs() <= v.len() as f32);
//! });
//! ```
//!
//! No shrinking — failing inputs here are small by construction (dims are
//! drawn from bounded ranges), and the seed makes reproduction trivial.

use crate::util::rng::Rng;

/// Seeded generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// The seed for this case (for error reporting / replay).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            seed,
        }
    }

    /// usize uniform in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// A power of two in [2^lo_exp, 2^hi_exp].
    pub fn pow2_in(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        1usize << self.usize_in(lo_exp as usize, hi_exp as usize)
    }

    /// f32 uniform in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform_f32() * (hi - lo)
    }

    /// Vector of f32 uniform in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of standard Gaussians.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.gaussian_vec(n)
    }

    /// Unit-norm vector.
    pub fn unit_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.unit_vec(n)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }
}

/// Run `prop` for `cases` seeded cases. Panics (with the failing seed in the
/// message) if any case panics.
pub fn for_all<F: FnMut(&mut Gen) + std::panic::UnwindSafe + Copy>(cases: u64, prop: F) {
    for_all_seeded(0xC0FFEE, cases, prop)
}

/// Like [`for_all`] but with an explicit base seed (use to replay).
pub fn for_all_seeded<F: FnMut(&mut Gen) + std::panic::UnwindSafe + Copy>(
    base_seed: u64,
    cases: u64,
    prop: F,
) {
    // Miri's interpreter is orders of magnitude slower than native code,
    // and UB detection needs every *path* exercised, not statistical
    // coverage — two seeded cases per property keep the Miri CI lane
    // under a minute while native runs keep the full count.
    let cases = if cfg!(miri) { cases.min(2) } else { cases };
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(move || {
            let mut g = Gen::new(seed);
            let mut p = prop;
            p(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        for_all(32, |g| {
            let n = g.usize_in(1, 10);
            let v = g.vec_f32(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|x| (0.0..1.0).contains(x)));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        for_all(32, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 90, "x was {x}");
        });
    }

    #[test]
    fn pow2_in_range() {
        for_all(32, |g| {
            let n = g.pow2_in(2, 8);
            assert!(n.is_power_of_two());
            assert!((4..=256).contains(&n));
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<u64> = Vec::new();
        for_all_seeded(42, 8, |_g| {});
        // Generators with the same seed produce the same values.
        let mut g1 = Gen::new(7);
        let mut g2 = Gen::new(7);
        for _ in 0..16 {
            first.push(g1.u64());
        }
        for v in &first {
            assert_eq!(*v, g2.u64());
        }
    }
}
