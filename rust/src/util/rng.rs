//! Deterministic, dependency-free random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the small
//! amount of randomness the TripleSpin library needs ourselves:
//!
//! * [`Rng`] — xoshiro256++ seeded through SplitMix64. Fast, well-tested
//!   statistical quality, 2^256-1 period, trivially reproducible.
//! * Gaussian sampling via the Marsaglia polar method (exact, no table).
//! * Rademacher (±1), uniform ranges, and sub-Gaussian helpers used by the
//!   TripleSpin constructions (Condition 2 of the paper, §3).
//!
//! Every randomized object in the library takes an explicit seed so that
//! experiments, tests and benches are bit-reproducible.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the polar Gaussian transform
    spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Two generators built from the
    /// same seed produce identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child generator (used to hand sub-streams to
    /// blocks / threads without sharing state).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) (n > 0), via Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal sample (Marsaglia polar method, cached spare).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Standard normal f32.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Rademacher sample: ±1 with equal probability.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a vector with i.i.d. standard Gaussians.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian_f32()).collect()
    }

    /// Fill a vector with i.i.d. Rademacher ±1 entries (the diagonal of the
    /// paper's `D_i` matrices).
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rademacher()).collect()
    }

    /// A unit vector uniform on the sphere S^{n-1}.
    pub fn unit_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.gaussian_vec(n);
        let norm = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32;
        if norm > 0.0 {
            for x in &mut v {
                *x /= norm;
            }
        }
        v
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut s1, mut s2, mut s4) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.gaussian();
            s1 += g;
            s2 += g * g;
            s4 += g * g * g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64;
        let kurt = s4 / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis={kurt}");
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let sum: f32 = (0..n).map(|_| r.rademacher()).sum();
        assert!(sum.abs() < 300.0, "sum={sum}");
        let v = r.rademacher_vec(16);
        assert!(v.iter().all(|x| *x == 1.0 || *x == -1.0));
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn unit_vec_has_unit_norm() {
        let mut r = Rng::new(8);
        for n in [2, 17, 128] {
            let v = r.unit_vec(n);
            let norm: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|x| *x));
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(10);
        let mut b = a.fork();
        let matches = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(matches < 2);
    }
}
