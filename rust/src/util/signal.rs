//! Minimal SIGINT/SIGTERM latch for graceful drain — no external crates.
//!
//! The serve CLI needs exactly one thing from POSIX signals: a boolean
//! that flips when the process is asked to stop, so the main loop can run
//! a graceful drain instead of dying mid-request. A full signal crate is
//! overkill for that, so this module declares `signal(2)` itself and
//! installs a handler that does the only thing an async-signal-safe
//! handler may do with shared state: a relaxed atomic store.
//!
//! On non-Unix targets the latch exists but never flips (the serve loop
//! still exits on coordinator shutdown paths).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

/// Set by the signal handler; polled by the serve loop.
static TERMINATE: AtomicBool = AtomicBool::new(false);

static INSTALL: Once = Once::new();

#[cfg(unix)]
mod imp {
    use super::{Ordering, TERMINATE};

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        // POSIX signal(2). Return value is the previous handler (or
        // SIG_ERR == usize::MAX); we install fire-and-forget and ignore it.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    /// The handler body is a single relaxed store on a static atomic —
    /// async-signal-safe (no allocation, no locks, no formatting).
    extern "C" fn mark(_signum: i32) {
        // ORDERING: Relaxed — one-way latch; the polling loop only needs
        // to eventually observe `true`, and acts on no other memory
        // published by the handler.
        TERMINATE.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        // SAFETY: `signal` is the POSIX C function with the declared
        // signature; `mark` is an `extern "C" fn(i32)` that is
        // async-signal-safe (single relaxed atomic store, touches nothing
        // else). Replacing the default SIGINT/SIGTERM dispositions for the
        // whole process is the intended effect, and this runs behind a
        // `Once` so handlers are installed exactly once.
        unsafe {
            signal(SIGINT, mark);
            signal(SIGTERM, mark);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install SIGINT/SIGTERM handlers (once; later calls are no-ops) and
/// return the termination latch. The latch is `true` after the process
/// has been asked to stop.
pub fn termination_latch() -> &'static AtomicBool {
    INSTALL.call_once(imp::install);
    &TERMINATE
}

#[cfg(test)]
mod tests {
    use super::*;

    // single test: the latch is process-global state, so "starts clear"
    // and "flips on SIGTERM" must be checked in one sequenced body rather
    // than racing across the parallel test harness
    #[test]
    fn latch_starts_clear_installs_once_and_flips_on_sigterm() {
        let latch = termination_latch();
        // ORDERING: Relaxed — test-only read of the latch.
        assert!(!latch.load(Ordering::Relaxed));
        // idempotent: second call returns the same static
        let again = termination_latch();
        assert!(std::ptr::eq(latch, again));
        #[cfg(unix)]
        {
            extern "C" {
                fn raise(signum: i32) -> i32;
            }
            // SAFETY: `raise` is the POSIX C function; delivering SIGTERM
            // to ourselves is safe here precisely because
            // `termination_latch` above replaced the (fatal) default
            // disposition with `mark`, and raise() runs the handler on
            // this thread before returning.
            unsafe {
                raise(15);
            }
            // ORDERING: Relaxed — one-way flag; signal delivery on the
            // same thread is sequenced before this load.
            assert!(latch.load(Ordering::Relaxed));
        }
    }
}
