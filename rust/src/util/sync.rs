//! Atomics façade for the two lock-free hot spots that have loom models.
//!
//! Compiled with `--cfg loom` (the `loom` CI lane: `RUSTFLAGS="--cfg loom"
//! cargo test --lib loom`), this re-exports [`loom::sync::atomic`] so the
//! exhaustive interleaving models in [`crate::loom_models`] drive the
//! *production* breaker and chunk-claim code, not reimplementations. In a
//! normal build it is exactly [`std::sync::atomic`] — zero overhead.
//!
//! Only `coordinator::breaker` and `runtime::pool::claim_chunks` import
//! through this façade. The dispatch caches (`linalg::simd::LEVEL`,
//! `linalg::fft::VARIANT`) deliberately do not: they are `static`s needing
//! `const` construction, which loom's atomics do not provide — and as
//! idempotent same-value caches they have no interleaving state space
//! worth modeling.

#[cfg(loom)]
pub(crate) use loom::sync::atomic;
#[cfg(not(loom))]
pub(crate) use std::sync::atomic;
