//! Exact and sketched Newton iterations (Figure 3).
//!
//! Per iteration: form the Hessian square root `B = W^{1/2}A ∈ R^{n×d}`,
//! sketch it to `S B ∈ R^{m×d}`, solve `((SB)ᵀ(SB) + ridge·I) Δ = -∇f`, and
//! take a backtracking-line-search step. `S` is either exact (no sketch),
//! i.i.d. Gaussian `N(0, 1/m)`, or a TripleSpin transform row-block scaled
//! by `1/√m` — all isotropic (`E[SᵀS] = I`), which is what the Newton-sketch
//! guarantees need.

use super::logistic::{gram_t, LogisticProblem};
use crate::linalg::dense::solve_spd;
use crate::linalg::fwht::next_pow2;
use crate::linalg::Mat;
use crate::runtime::WorkerPool;
use crate::transform::{make, Family, Transform};
use crate::util::rng::Rng;

/// Sketch selection for one Newton run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    /// No sketch: exact Newton (`S = I`).
    Exact,
    /// Dense i.i.d. Gaussian sketch.
    Gaussian,
    /// TripleSpin sketch of the given family.
    Struct(Family),
}

impl SketchKind {
    pub fn label(&self) -> String {
        match self {
            SketchKind::Exact => "exact Newton".into(),
            SketchKind::Gaussian => "Gaussian sketch".into(),
            SketchKind::Struct(f) => format!("{} sketch", f.label()),
        }
    }
}

/// Options for a Newton / Newton-sketch run.
#[derive(Clone, Copy, Debug)]
pub struct NewtonOptions {
    /// Sketch dimension m (rows of S). Ignored for `Exact`.
    pub sketch_rows: usize,
    pub max_iters: usize,
    /// Armijo backtracking parameters.
    pub ls_alpha: f64,
    pub ls_beta: f64,
    pub seed: u64,
}

impl Default for NewtonOptions {
    fn default() -> Self {
        NewtonOptions {
            sketch_rows: 256,
            max_iters: 30,
            ls_alpha: 0.1,
            ls_beta: 0.5,
            seed: 1,
        }
    }
}

/// Per-iteration trace of a run.
#[derive(Clone, Debug)]
pub struct Trace {
    /// f(x_t) per iteration (index 0 = initial point).
    pub values: Vec<f64>,
    /// Final iterate.
    pub x: Vec<f64>,
}

impl Trace {
    /// Optimality gaps `f(x_t) - f_star` (clamped at 1e-16 for log plots).
    pub fn gaps(&self, f_star: f64) -> Vec<f64> {
        self.values.iter().map(|v| (v - f_star).max(1e-16)).collect()
    }
}

/// Apply a sketch to the Hessian square root `B ∈ R^{n×d}`, producing
/// `S B ∈ R^{m×d}`. For structured sketches columns of `B` are zero-padded
/// to the next power of two.
pub fn sketch_apply(kind: SketchKind, b: &Mat, m: usize, rng: &mut Rng) -> Mat {
    let (n, d) = (b.rows, b.cols);
    match kind {
        SketchKind::Exact => b.clone(),
        SketchKind::Gaussian => {
            // S ∈ R^{m×n}, entries N(0, 1/m): SB computed as m dot products
            // per column — O(mnd), the cost the paper wants to beat.
            let s = Mat::gaussian(m, n, rng);
            let scale = (1.0 / m as f64).sqrt() as f32;
            let mut out = Mat::zeros(m, d);
            // (S B)[i][j] = Σ_k S[i][k] B[k][j]
            for i in 0..m {
                let srow = s.row(i);
                for k in 0..n {
                    let sv = srow[k] * scale;
                    if sv == 0.0 {
                        continue;
                    }
                    let brow = b.row(k);
                    let orow = &mut out.data[i * d..(i + 1) * d];
                    for j in 0..d {
                        orow[j] += sv * brow[j];
                    }
                }
            }
            out
        }
        SketchKind::Struct(f) => {
            let np = next_pow2(n);
            let t: Box<dyn Transform> = make(f, m, np, np.min(m.max(1)), rng);
            let scale = (1.0 / m as f64).sqrt() as f32;
            // batch-first: the d columns of B become the d rows of one
            // zero-padded batch, sketched in a single sweep over the
            // process-wide persistent worker pool — O(d · n log n) with no
            // per-column allocation and no per-call thread spawns.
            let mut cols = vec![0.0f32; d * np];
            for j in 0..d {
                for i in 0..n {
                    cols[j * np + i] = b.at(i, j);
                }
            }
            let mut proj = vec![0.0f32; d * m];
            t.apply_batch_into(&cols, &mut proj, WorkerPool::global());
            let mut out = Mat::zeros(m, d);
            for j in 0..d {
                for i in 0..m {
                    out.data[i * d + j] = proj[j * m + i] * scale;
                }
            }
            out
        }
    }
}

/// Run (sketched) Newton on a logistic-regression problem from `x0 = 0`.
pub fn newton_solve(p: &LogisticProblem, kind: SketchKind, opts: NewtonOptions) -> Trace {
    let d = p.d();
    let mut x = vec![0.0f64; d];
    let mut values = vec![p.value(&x)];
    let mut rng = Rng::new(opts.seed);

    for _ in 0..opts.max_iters {
        let g = p.grad(&x);
        let b = p.hessian_sqrt(&x);
        let sb = sketch_apply(kind, &b, opts.sketch_rows, &mut rng);
        let h = gram_t(&sb, p.ridge.max(1e-10));
        let neg_g: Vec<f64> = g.iter().map(|v| -v).collect();
        let delta = match solve_spd(&h, &neg_g, d) {
            Some(dd) => dd,
            None => break, // sketched Hessian degenerate; stop
        };
        // Armijo backtracking on f along delta
        let g_dot_d: f64 = g.iter().zip(&delta).map(|(a, b)| a * b).sum();
        if g_dot_d >= 0.0 {
            break; // not a descent direction (sketch too coarse); stop
        }
        let f0 = *values.last().unwrap();
        let mut step = 1.0f64;
        let mut accepted = false;
        for _ in 0..40 {
            let xt: Vec<f64> = x.iter().zip(&delta).map(|(a, b)| a + step * b).collect();
            let ft = p.value(&xt);
            if ft <= f0 + opts.ls_alpha * step * g_dot_d {
                x = xt;
                values.push(ft);
                accepted = true;
                break;
            }
            step *= opts.ls_beta;
        }
        if !accepted {
            break;
        }
    }
    Trace { values, x }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::logistic::generate;

    fn small_problem(seed: u64) -> LogisticProblem {
        generate(256, 8, 0.99, seed)
    }

    #[test]
    fn exact_newton_decreases_monotonically() {
        let p = small_problem(1);
        let t = newton_solve(&p, SketchKind::Exact, NewtonOptions::default());
        for w in t.values.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "non-monotone: {:?}", t.values);
        }
        assert!(t.values.len() > 3);
    }

    #[test]
    fn exact_newton_reaches_stationarity() {
        let p = small_problem(2);
        let t = newton_solve(
            &p,
            SketchKind::Exact,
            NewtonOptions {
                max_iters: 50,
                ..Default::default()
            },
        );
        let g = p.grad(&t.x);
        let gnorm: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(gnorm < 1e-5, "|grad| = {gnorm}");
    }

    #[test]
    fn sketched_newton_converges_close_to_exact() {
        let p = small_problem(3);
        let exact = newton_solve(
            &p,
            SketchKind::Exact,
            NewtonOptions {
                max_iters: 60,
                ..Default::default()
            },
        );
        let f_star = *exact.values.last().unwrap();
        for kind in [
            SketchKind::Gaussian,
            SketchKind::Struct(Family::Hd3),
            SketchKind::Struct(Family::Toeplitz),
        ] {
            let t = newton_solve(
                &p,
                kind,
                NewtonOptions {
                    sketch_rows: 64, // 8d
                    max_iters: 40,
                    ..Default::default()
                },
            );
            let gap = t.values.last().unwrap() - f_star;
            assert!(
                gap < 1e-3 * (1.0 + f_star.abs()),
                "{kind:?}: final gap {gap}"
            );
            // sketched runs still decrease monotonically (line search)
            for w in t.values.windows(2) {
                assert!(w[1] <= w[0] + 1e-9);
            }
        }
    }

    #[test]
    fn sketch_isotropy() {
        // E[(Sx)ᵀ(Sx)] ≈ ||x||² for every sketch kind.
        let n = 128;
        let mut rng = Rng::new(4);
        let x = rng.unit_vec(n);
        let b = Mat::from_vec(n, 1, x.clone());
        for kind in [
            SketchKind::Gaussian,
            SketchKind::Struct(Family::Hd3),
            SketchKind::Struct(Family::Circulant),
        ] {
            let mut total = 0.0f64;
            let trials = 60;
            for s in 0..trials {
                let sb = sketch_apply(kind, &b, 32, &mut Rng::new(100 + s));
                total += sb.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
            }
            let avg = total / trials as f64;
            assert!(
                (avg - 1.0).abs() < 0.2,
                "{kind:?}: E||Sx||² = {avg}, want ≈ 1"
            );
        }
    }

    #[test]
    fn exact_sketch_is_identity() {
        let p = small_problem(5);
        let b = p.hessian_sqrt(&vec![0.0; p.d()]);
        let sb = sketch_apply(SketchKind::Exact, &b, 10, &mut Rng::new(1));
        assert_eq!(sb.data, b.data);
    }

    #[test]
    fn labels() {
        assert_eq!(SketchKind::Exact.label(), "exact Newton");
        assert!(SketchKind::Struct(Family::Hd3).label().contains("HD3"));
    }
}
