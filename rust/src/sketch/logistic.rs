//! Unconstrained logistic regression — the Newton-sketch experiment's
//! objective (paper Appendix 7.3).
//!
//! Given observations `(a_i, y_i)`, `y_i ∈ {-1, +1}`:
//! `f(x) = Σ_i log(1 + exp(-y_i a_iᵀ x))`,
//! `∇f(x) = Σ_i (σ(y_i a_iᵀ x) - 1) y_i a_i`,
//! `∇²f(x) = Aᵀ diag(s_i (1 - s_i)) A`, `s_i = σ(a_iᵀ x)`.
//! The Hessian square root is `B = diag(s(1-s))^{1/2} A ∈ R^{n×d}`.

use crate::linalg::Mat;

#[inline]
fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// log(1 + exp(t)) computed stably.
#[inline]
fn log1pexp(t: f64) -> f64 {
    if t > 30.0 {
        t
    } else {
        t.exp().ln_1p()
    }
}

/// A logistic-regression instance: design matrix `A ∈ R^{n×d}` (row per
/// observation) and labels `y ∈ {-1, +1}^n`.
pub struct LogisticProblem {
    pub a: Mat,
    pub y: Vec<f32>,
    /// Small ridge term keeping Hessians PD (0 reproduces the paper; the
    /// default 1e-8 merely guards the Cholesky).
    pub ridge: f64,
}

impl LogisticProblem {
    pub fn new(a: Mat, y: Vec<f32>) -> LogisticProblem {
        assert_eq!(a.rows, y.len());
        assert!(y.iter().all(|v| *v == 1.0 || *v == -1.0));
        LogisticProblem {
            a,
            y,
            ridge: 1e-8,
        }
    }

    pub fn n(&self) -> usize {
        self.a.rows
    }

    pub fn d(&self) -> usize {
        self.a.cols
    }

    /// Margins `a_iᵀ x`.
    fn margins(&self, x: &[f64]) -> Vec<f64> {
        let d = self.d();
        (0..self.n())
            .map(|i| {
                let row = self.a.row(i);
                (0..d).map(|j| row[j] as f64 * x[j]).sum()
            })
            .collect()
    }

    /// Objective value.
    pub fn value(&self, x: &[f64]) -> f64 {
        let m = self.margins(x);
        let data: f64 = m
            .iter()
            .zip(&self.y)
            .map(|(mi, yi)| log1pexp(-(*yi as f64) * mi))
            .sum();
        data + 0.5 * self.ridge * x.iter().map(|v| v * v).sum::<f64>()
    }

    /// Gradient.
    pub fn grad(&self, x: &[f64]) -> Vec<f64> {
        let m = self.margins(x);
        let d = self.d();
        let mut g = vec![0.0f64; d];
        for i in 0..self.n() {
            let yi = self.y[i] as f64;
            let coeff = sigmoid(yi * m[i]) - 1.0; // in (-1, 0)
            let row = self.a.row(i);
            for j in 0..d {
                g[j] += coeff * yi * row[j] as f64;
            }
        }
        for (gj, xj) in g.iter_mut().zip(x) {
            *gj += self.ridge * xj;
        }
        g
    }

    /// Hessian weights `w_i = s_i (1 - s_i)`, `s_i = σ(a_iᵀ x)`.
    pub fn hessian_weights(&self, x: &[f64]) -> Vec<f64> {
        self.margins(x)
            .iter()
            .map(|mi| {
                let s = sigmoid(*mi);
                s * (1.0 - s)
            })
            .collect()
    }

    /// Hessian square root `B = diag(w)^{1/2} A ∈ R^{n×d}` (f32, row-major —
    /// this is the matrix the sketch hits).
    pub fn hessian_sqrt(&self, x: &[f64]) -> Mat {
        let w = self.hessian_weights(x);
        let (n, d) = (self.n(), self.d());
        let mut b = Mat::zeros(n, d);
        for i in 0..n {
            let s = w[i].sqrt() as f32;
            let row = self.a.row(i);
            for j in 0..d {
                b.data[i * d + j] = s * row[j];
            }
        }
        b
    }

    /// Exact Hessian `BᵀB + ridge·I` as an f64 buffer (d×d, row-major).
    pub fn hessian(&self, x: &[f64]) -> Vec<f64> {
        let b = self.hessian_sqrt(x);
        gram_t(&b, self.ridge)
    }
}

/// `MᵀM + ridge·I` in f64 for a row-major f32 matrix (d×d output).
pub fn gram_t(m: &Mat, ridge: f64) -> Vec<f64> {
    let (n, d) = (m.rows, m.cols);
    let mut h = vec![0.0f64; d * d];
    for i in 0..n {
        let row = m.row(i);
        for j in 0..d {
            let rj = row[j] as f64;
            if rj == 0.0 {
                continue;
            }
            for k in j..d {
                h[j * d + k] += rj * row[k] as f64;
            }
        }
    }
    // mirror + ridge
    for j in 0..d {
        for k in j..d {
            let v = h[j * d + k];
            h[k * d + j] = v;
        }
        h[j * d + j] += ridge;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::logistic::generate;
    use crate::util::prop::for_all;
    use crate::util::rng::Rng;

    fn finite_diff_grad(p: &LogisticProblem, x: &[f64]) -> Vec<f64> {
        let eps = 1e-6;
        (0..x.len())
            .map(|j| {
                let mut xp = x.to_vec();
                let mut xm = x.to_vec();
                xp[j] += eps;
                xm[j] -= eps;
                (p.value(&xp) - p.value(&xm)) / (2.0 * eps)
            })
            .collect()
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = generate(50, 6, 0.99, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..6).map(|_| rng.gaussian() * 0.3).collect();
        let g = p.grad(&x);
        let fd = finite_diff_grad(&p, &x);
        for j in 0..6 {
            assert!(
                (g[j] - fd[j]).abs() < 1e-4 * (1.0 + fd[j].abs()),
                "j={j}: {} vs {}",
                g[j],
                fd[j]
            );
        }
    }

    #[test]
    fn hessian_matches_finite_difference_of_grad() {
        let p = generate(40, 4, 0.9, 3);
        let x = vec![0.1, -0.2, 0.05, 0.3];
        let h = p.hessian(&x);
        let eps = 1e-5;
        for j in 0..4 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += eps;
            xm[j] -= eps;
            let gp = p.grad(&xp);
            let gm = p.grad(&xm);
            for k in 0..4 {
                let fd = (gp[k] - gm[k]) / (2.0 * eps);
                assert!(
                    (h[k * 4 + j] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                    "H[{k}][{j}] = {} vs fd {}",
                    h[k * 4 + j],
                    fd
                );
            }
        }
    }

    #[test]
    fn value_at_zero_is_n_log2() {
        let p = generate(30, 5, 0.99, 4);
        let v = p.value(&vec![0.0; 5]);
        assert!((v - 30.0 * (2.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn value_convex_along_segments() {
        for_all(12, |g| {
            let p = generate(25, 4, 0.9, g.u64());
            let x: Vec<f64> = (0..4).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let y: Vec<f64> = (0..4).map(|_| g.f32_in(-1.0, 1.0) as f64).collect();
            let mid: Vec<f64> = x.iter().zip(&y).map(|(a, b)| 0.5 * (a + b)).collect();
            assert!(p.value(&mid) <= 0.5 * p.value(&x) + 0.5 * p.value(&y) + 1e-9);
        });
    }

    #[test]
    fn hessian_sqrt_squares_to_hessian() {
        let p = generate(20, 3, 0.9, 5);
        let x = vec![0.2, -0.1, 0.4];
        let b = p.hessian_sqrt(&x);
        let h = p.hessian(&x);
        let bb = gram_t(&b, p.ridge);
        for (u, v) in h.iter().zip(&bb) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn stable_sigmoid_extremes() {
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(log1pexp(1000.0).is_finite());
    }
}
