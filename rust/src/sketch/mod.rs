//! Newton sketch for convex optimization (paper §6.3, Figure 3).
//!
//! The Newton sketch [Pilanci & Wainwright] replaces the exact Hessian
//! `∇²f = BᵀB` (with `B = W^{1/2} A ∈ R^{n×d}` the Hessian square root) by
//! `(S B)ᵀ (S B)` for an isotropic `m×n` sketch `S`. With a TripleSpin `S`
//! the per-iteration cost drops from `O(n d²)` to `O(d n log n + m d²)`.
//!
//! [`logistic`] defines the objective of the experiment; [`newton`] the
//! exact / sketched solvers and sketch constructions.

pub mod logistic;
pub mod newton;

pub use logistic::LogisticProblem;
pub use newton::{newton_solve, NewtonOptions, SketchKind, Trace};
