//! Exhaustive interleaving models of the two lock-free hot spots, run by
//! the `loom` CI lane: `RUSTFLAGS="--cfg loom" cargo test --lib loom`.
//!
//! These drive the *production* code — [`crate::coordinator::breaker`] and
//! [`crate::runtime::pool::claim_chunks`] import their atomics through the
//! [`crate::util::sync`] façade, which re-exports `loom::sync::atomic`
//! under `--cfg loom` — so loom explores every thread interleaving *and*
//! every value a `Relaxed` load may legally observe, not a model of the
//! algorithm but the algorithm itself.
//!
//! What is deliberately *not* asserted matters as much as what is: the
//! breaker's protocol tolerates stale phase reads (admission is advisory;
//! see the `ORDERING:` rationale at each site), so the models pin the
//! properties the coordinator actually relies on — exactly one open edge
//! per degradation (the `breaker_opens` metric), monotonic streak
//! accounting, and a clean slate after restart — rather than any stronger
//! linearization the Relaxed orderings never promised.

#[cfg(test)]
mod models {
    use crate::coordinator::breaker::{LaneState, Phase};
    use crate::runtime::pool::claim_chunks;
    use crate::util::sync::atomic::AtomicUsize;
    use loom::sync::Arc;
    use loom::thread;
    use std::time::Duration;

    /// Long enough that a degraded breaker never half-opens mid-model
    /// (models must not depend on wall-clock time passing).
    const LONG: Duration = Duration::from_secs(3600);

    #[test]
    fn breaker_racing_failures_open_exactly_once() {
        // threshold 1: BOTH failures independently qualify to open the
        // breaker, so this pins the strongest claim — the phase swap's RMW
        // atomicity hands the open edge to exactly one of them, under
        // every interleaving and every Relaxed value assignment.
        loom::model(|| {
            let s = Arc::new(LaneState::new(1, LONG));
            let a = {
                let s = Arc::clone(&s);
                thread::spawn(move || s.record_failure())
            };
            let b = {
                let s = Arc::clone(&s);
                thread::spawn(move || s.record_failure())
            };
            let edges = [a.join().unwrap(), b.join().unwrap()];
            assert_eq!(
                edges.iter().filter(|e| **e).count(),
                1,
                "exactly one racing failure may claim the open edge: {edges:?}"
            );
            assert_eq!(s.phase(), Phase::Degraded);
            assert_eq!(s.consecutive_failures(), 2, "RMW streak: no lost increment");
            assert!(!s.admit(), "degraded breaker sheds until cooldown");
        });
    }

    #[test]
    fn breaker_threshold_counts_racing_failures_without_loss() {
        // threshold 2, two racing failures: the fetch_add streak hands out
        // distinct values 1 and 2, so the breaker must end up open no
        // matter which thread observed the threshold crossing.
        loom::model(|| {
            let s = Arc::new(LaneState::new(2, LONG));
            let a = {
                let s = Arc::clone(&s);
                thread::spawn(move || s.record_failure())
            };
            let edge_b = s.record_failure();
            let edge_a = a.join().unwrap();
            assert_eq!(
                u32::from(edge_a) + u32::from(edge_b),
                1,
                "exactly one thread sees the streak cross the threshold"
            );
            assert_eq!(s.phase(), Phase::Degraded);
            assert_eq!(s.consecutive_failures(), 2);
        });
    }

    #[test]
    fn breaker_success_failure_race_stays_coherent() {
        // A success and a failure racing (can happen across a restart
        // boundary: the old lane thread's last outcome vs the new one's
        // first). Either order is acceptable; what may never happen is an
        // incoherent composite — an open phase that still sheds, or a
        // streak the counter lost entirely.
        loom::model(|| {
            let s = Arc::new(LaneState::new(1, LONG));
            let f = {
                let s = Arc::clone(&s);
                thread::spawn(move || s.record_failure())
            };
            s.record_success();
            f.join().unwrap();
            let streak = s.consecutive_failures();
            assert!(streak <= 1, "store(0) and fetch_add can only interleave to 0 or 1");
            match s.phase() {
                // failure ordered last (or its swap landed after the
                // success's close): breaker open, shedding
                Phase::Degraded => assert!(!s.admit()),
                // success ordered last: breaker closed, admitting
                Phase::Open => assert!(s.admit()),
                Phase::Dead => unreachable!("nothing sets Dead in this model"),
            }
        });
    }

    #[test]
    fn breaker_restart_wipes_state_under_concurrent_admission() {
        // Supervisor kills and restarts the lane while a submitter polls
        // admit(): mid-flight admission may land either way (advisory by
        // design), but after the restart is sequenced the slate is clean.
        loom::model(|| {
            let s = Arc::new(LaneState::new(1, LONG));
            assert!(s.record_failure());
            let submitter = {
                let s = Arc::clone(&s);
                thread::spawn(move || {
                    // racing reads: must not crash or deadlock; the value
                    // is free to be either side of the transition
                    let _ = s.admit();
                    let _ = s.phase();
                })
            };
            s.set_dead();
            s.restart();
            submitter.join().unwrap();
            assert_eq!(s.phase(), Phase::Open, "restart leaves a clean lane");
            assert_eq!(s.consecutive_failures(), 0);
            assert!(s.admit());
        });
    }

    #[test]
    fn claim_chunks_ranges_are_disjoint_and_covering() {
        // Two workers drain a 5-row batch in chunks of 2 (ragged tail
        // included): every interleaving must partition 0..5 exactly —
        // fetch_add's RMW atomicity is the only thing making that true,
        // which is precisely what the ORDERING: rationale at the site
        // claims Relaxed is sufficient for.
        loom::model(|| {
            const ROWS: usize = 5;
            const CHUNK: usize = 2;
            let next = Arc::new(AtomicUsize::new(0));
            let worker = |next: Arc<AtomicUsize>| {
                thread::spawn(move || {
                    let mut claimed = Vec::new();
                    claim_chunks(&next, ROWS, CHUNK, |lo, hi| claimed.push((lo, hi)));
                    claimed
                })
            };
            let a = worker(Arc::clone(&next));
            // second claimant runs concurrently from the main thread so
            // loom only schedules two entities; claim_chunks is symmetric
            let mut ranges = Vec::new();
            claim_chunks(&next, ROWS, CHUNK, |lo, hi| ranges.push((lo, hi)));
            ranges.extend(a.join().unwrap());
            let mut cover = [0u8; ROWS];
            for (lo, hi) in ranges {
                assert!(lo < hi && hi <= ROWS, "claimed range {lo}..{hi} out of bounds");
                for c in &mut cover[lo..hi] {
                    *c += 1;
                }
            }
            assert!(cover.iter().all(|c| *c == 1), "rows not partitioned: {cover:?}");
        });
    }
}
