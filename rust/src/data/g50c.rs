//! G50C: 550 points in R^50 drawn from two multivariate Gaussians.
//!
//! The original dataset is itself synthetic — two Gaussians whose means are
//! placed so the Bayes error is ~5%. We reproduce that construction: means
//! `±μ·e` along a random unit direction, identity covariance.

use crate::util::rng::Rng;

pub const DIM: usize = 50;
pub const COUNT: usize = 550;

/// Generate the G50C-like dataset: `count` points, labels ±1, two Gaussian
/// classes separated along a random direction.
pub fn dataset_with_labels(count: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let dir = rng.unit_vec(DIM);
    let sep = 2.5f32; // class-mean separation giving ≈5% overlap
    let mut pts = Vec::with_capacity(count);
    let mut labels = Vec::with_capacity(count);
    for i in 0..count {
        let y: f32 = if i % 2 == 0 { 1.0 } else { -1.0 };
        let p: Vec<f32> = (0..DIM)
            .map(|j| rng.gaussian_f32() + y * sep * dir[j])
            .collect();
        pts.push(p);
        labels.push(y);
    }
    (pts, labels)
}

/// The standard 550-point instance.
pub fn dataset(seed: u64) -> Vec<Vec<f32>> {
    dataset_with_labels(COUNT, seed).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dot;

    #[test]
    fn shape() {
        let pts = dataset(1);
        assert_eq!(pts.len(), COUNT);
        assert!(pts.iter().all(|p| p.len() == DIM));
    }

    #[test]
    fn two_classes_are_separated() {
        let (pts, labels) = dataset_with_labels(400, 2);
        // project onto the difference of class means: classes should separate
        let mut mean_pos = vec![0.0f32; DIM];
        let mut mean_neg = vec![0.0f32; DIM];
        let (mut np, mut nn) = (0, 0);
        for (p, y) in pts.iter().zip(&labels) {
            if *y > 0.0 {
                for (m, v) in mean_pos.iter_mut().zip(p) {
                    *m += v;
                }
                np += 1;
            } else {
                for (m, v) in mean_neg.iter_mut().zip(p) {
                    *m += v;
                }
                nn += 1;
            }
        }
        for m in mean_pos.iter_mut() {
            *m /= np as f32;
        }
        for m in mean_neg.iter_mut() {
            *m /= nn as f32;
        }
        let w: Vec<f32> = mean_pos.iter().zip(&mean_neg).map(|(a, b)| a - b).collect();
        let mut errors = 0;
        for (p, y) in pts.iter().zip(&labels) {
            let centered: Vec<f32> = p
                .iter()
                .zip(mean_pos.iter().zip(&mean_neg))
                .map(|(v, (a, b))| v - 0.5 * (a + b))
                .collect();
            let pred = if dot(&w, &centered) > 0.0 { 1.0 } else { -1.0 };
            if pred != *y {
                errors += 1;
            }
        }
        let err_rate = errors as f64 / pts.len() as f64;
        assert!(err_rate < 0.12, "linear error rate {err_rate} (want ~5%)");
        assert!(err_rate > 0.0005 || errors == 0); // sanity
    }

    #[test]
    fn deterministic() {
        assert_eq!(dataset(9)[0], dataset(9)[0]);
        assert_ne!(dataset(9)[0], dataset(10)[0]);
    }
}
