//! Newton-sketch experiment data (paper §6.3): design matrix `A ∈ R^{n×d}`
//! with rows from a centered Gaussian with AR(1) covariance
//! `Σ_ij = ρ^|i-j|` (ρ = 0.99 in the paper), labels `y ∈ {-1, 1}` random.

use crate::linalg::Mat;
use crate::sketch::logistic::LogisticProblem;
use crate::util::rng::Rng;

/// Draw one AR(1) row: `a_1 = g_1`, `a_j = ρ a_{j-1} + √(1-ρ²) g_j`, which
/// has exactly the covariance `Σ_ij = ρ^|i-j|`.
pub fn ar1_row(d: usize, rho: f64, rng: &mut Rng) -> Vec<f32> {
    let mut row = Vec::with_capacity(d);
    let innov = (1.0 - rho * rho).sqrt();
    let mut prev = rng.gaussian();
    row.push(prev as f32);
    for _ in 1..d {
        prev = rho * prev + innov * rng.gaussian();
        row.push(prev as f32);
    }
    row
}

/// Generate the full logistic-regression instance.
pub fn generate(n: usize, d: usize, rho: f64, seed: u64) -> LogisticProblem {
    let mut rng = Rng::new(seed);
    let mut a = Mat::zeros(n, d);
    for i in 0..n {
        let row = ar1_row(d, rho, &mut rng);
        a.data[i * d..(i + 1) * d].copy_from_slice(&row);
    }
    let y: Vec<f32> = (0..n).map(|_| rng.rademacher()).collect();
    LogisticProblem::new(a, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ar1_covariance_structure() {
        // empirical Σ_ij ≈ ρ^|i-j| over many rows
        let d = 8;
        let rho = 0.9f64;
        let mut rng = Rng::new(1);
        let trials = 30_000;
        let mut cov = vec![0.0f64; d * d];
        for _ in 0..trials {
            let r = ar1_row(d, rho, &mut rng);
            for i in 0..d {
                for j in 0..d {
                    cov[i * d + j] += r[i] as f64 * r[j] as f64;
                }
            }
        }
        for v in cov.iter_mut() {
            *v /= trials as f64;
        }
        for i in 0..d {
            for j in 0..d {
                let expect = rho.powi((i as i32 - j as i32).abs());
                assert!(
                    (cov[i * d + j] - expect).abs() < 0.05,
                    "cov[{i}][{j}] = {} want {expect}",
                    cov[i * d + j]
                );
            }
        }
    }

    #[test]
    fn problem_shape_and_labels() {
        let p = generate(100, 10, 0.99, 2);
        assert_eq!(p.n(), 100);
        assert_eq!(p.d(), 10);
        assert!(p.y.iter().all(|v| *v == 1.0 || *v == -1.0));
    }

    #[test]
    fn deterministic() {
        let p1 = generate(20, 5, 0.99, 3);
        let p2 = generate(20, 5, 0.99, 3);
        assert_eq!(p1.a.data, p2.a.data);
        assert_eq!(p1.y, p2.y);
    }
}
