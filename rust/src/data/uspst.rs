//! USPST-like synthetic digits: 16×16 grayscale stroke images.
//!
//! The paper's Figure 2 uses USPST (test split of USPS): 2007 points,
//! n=258 descriptors of 16×16 scans. The experiment measures Gram-matrix
//! reconstruction, which depends only on point-cloud geometry — so we
//! synthesize a smooth, correlated, image-like cloud: each sample renders
//! 2–4 Gaussian-blob strokes along a random polyline onto a 16×16 canvas.
//! We use n=256 directly (the Hadamard pipeline zero-pads to powers of two
//! anyway; USPST's 258 would pad to 512).

use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const DIM: usize = IMG * IMG; // 256
pub const COUNT: usize = 2007;

/// Render one synthetic digit-like stroke image, normalized to unit L2 norm.
pub fn sample(rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; DIM];
    // a polyline of 2..=4 segments with blobs stamped along it
    let segments = 2 + rng.below(3) as usize;
    let mut x = 2.0 + rng.uniform() * 12.0;
    let mut y = 2.0 + rng.uniform() * 12.0;
    let sigma = 0.8 + rng.uniform() * 0.8; // stroke width
    for _ in 0..segments {
        let nx = 2.0 + rng.uniform() * 12.0;
        let ny = 2.0 + rng.uniform() * 12.0;
        let steps = 8;
        for s in 0..=steps {
            let t = s as f64 / steps as f64;
            let cx = x + t * (nx - x);
            let cy = y + t * (ny - y);
            stamp_blob(&mut img, cx, cy, sigma);
        }
        x = nx;
        y = ny;
    }
    // normalize like descriptor vectors
    let norm: f64 = img.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for v in img.iter_mut() {
            *v *= inv;
        }
    }
    img
}

fn stamp_blob(img: &mut [f32], cx: f64, cy: f64, sigma: f64) {
    let r = (3.0 * sigma).ceil() as i64;
    let (cxi, cyi) = (cx.round() as i64, cy.round() as i64);
    for dy in -r..=r {
        for dx in -r..=r {
            let (px, py) = (cxi + dx, cyi + dy);
            if px < 0 || py < 0 || px >= IMG as i64 || py >= IMG as i64 {
                continue;
            }
            let ddx = px as f64 - cx;
            let ddy = py as f64 - cy;
            let v = (-(ddx * ddx + ddy * ddy) / (2.0 * sigma * sigma)).exp();
            let idx = (py as usize) * IMG + px as usize;
            img[idx] = (img[idx] + v as f32).min(4.0);
        }
    }
}

/// The full USPST-like dataset (2007 points, n = 256), deterministic in the
/// seed.
pub fn dataset(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..COUNT).map(|_| sample(&mut rng)).collect()
}

/// Smaller slice for quick tests / examples.
pub fn dataset_n(count: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..count).map(|_| sample(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::exact::median_bandwidth;
    use crate::linalg::vecops::norm2;

    #[test]
    fn shapes_and_normalization() {
        let pts = dataset_n(50, 1);
        assert_eq!(pts.len(), 50);
        for p in &pts {
            assert_eq!(p.len(), DIM);
            assert!((norm2(p) - 1.0).abs() < 1e-4);
            assert!(p.iter().all(|v| *v >= 0.0), "images are non-negative");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(dataset_n(10, 7), dataset_n(10, 7));
        assert_ne!(dataset_n(10, 7), dataset_n(10, 8));
    }

    #[test]
    fn images_are_smooth_and_sparse_like_digits() {
        // stroke images: most pixels near zero, a connected minority bright
        let pts = dataset_n(30, 2);
        for p in &pts {
            let bright = p.iter().filter(|v| **v > 0.05).count();
            assert!(
                bright > 5 && bright < DIM * 3 / 4,
                "bright pixel count {bright} not stroke-like"
            );
        }
    }

    #[test]
    fn pairwise_geometry_nondegenerate() {
        // points are neither collapsed nor orthogonal — a meaningful kernel
        // experiment needs spread in similarity
        let pts = dataset_n(60, 3);
        let med = median_bandwidth(&pts, 60);
        assert!(med > 0.3 && med < 2.0, "median distance {med}");
    }
}
