//! Synthetic datasets standing in for the paper's workloads.
//!
//! Substitutions (rationale in DESIGN.md §4):
//! * [`uspst`] — USPST (2007 handwritten-digit scans, 16×16) → synthetic
//!   stroke images with the same point count and image geometry.
//! * [`g50c`] — G50C (550 points from two Gaussians in R^50) → generated
//!   exactly as described; the original *is* synthetic Gaussian.
//! * [`logistic`] — the Newton-sketch design matrix `A` with AR(1) row
//!   covariance `Σ_ij = 0.99^|i-j|` and random ±1 labels, per §6.3.

pub mod g50c;
pub mod logistic;
pub mod uspst;
