//! Fleet tier: a shard router that treats whole backend shards as
//! untrusted and individually failable.
//!
//! The [`ShardRouter`] is a [`LineService`] — it plugs into the same
//! [`crate::coordinator::server::serve`] loop as a single-node
//! coordinator, speaking the same newline-delimited JSON protocol, but
//! instead of owning compute lanes it owns N **shard groups** (each a
//! list of replica [`Endpoint`]s running a [`shard::ShardService`]).
//!
//! Routing policy, by op:
//!
//! - **Compute ops** (`transform`, `binary_embed`, ...): the raw request
//!   line is forwarded verbatim to one shard — the rendezvous-hash owner
//!   of the request key — and its reply relayed verbatim. On a transport
//!   failure, a retryable refusal, or a `timeout`, the router **fails
//!   over** along the replica list and then the rendezvous fallback
//!   order; terminal refusals (`bad_dim`, `throttled`, ...) are the
//!   shard's answer and are relayed, not retried. Only when every
//!   replica of every group is down does the client see a typed
//!   `shard_down` refusal with a `retry_after_ms` hint.
//! - **`lsh_query`**: scatter-gather. Every group gets a sub-query (with
//!   per-group replica failover and a hedged duplicate after that
//!   group's p95 delay — see [`hedge::HedgePolicy`]); answers merge with
//!   [`topology::merge_topk`] into the exact global top-k. A group that
//!   cannot answer inside the scatter budget degrades the result instead
//!   of blocking it: the reply is a [`partial`](crate::coordinator::codec::CODE_PARTIAL)
//!   success naming the missing shards in `degraded` — never a silent
//!   truncation, never a hang.
//! - **Introspection** (`metrics`, `health`, `metrics_text`): answered by
//!   the router itself with fleet-level counters and per-endpoint
//!   breaker phases.
//!
//! Health probes (see [`health::Prober`]) run in the background and are
//! the recovery path: an open per-endpoint breaker closes again when
//! probes succeed, without spending client requests on the experiment.

pub mod health;
pub mod hedge;
pub mod shard;
pub mod topology;

pub use health::{CallOutcome, Endpoint, Prober};
pub use hedge::HedgePolicy;
pub use shard::{demo_points, ShardIndex, ShardIndexConfig, ShardService};
pub use topology::{merge_topk, parse_topology, ShardSpec};

use crate::coordinator::breaker::Phase;
use crate::coordinator::client::is_retryable;
use crate::coordinator::codec::{self, ParsedLine, CODE_BAD_REQUEST, CODE_SHARD_DOWN, CODE_TIMEOUT, SHARD_DOWN_RETRY_MS};
use crate::coordinator::prom::{Family, Sample};
use crate::coordinator::server::LineService;
use crate::coordinator::{SubmitError, DRAINING_RETRY_MS};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Router tuning knobs (every duration has a CLI flag on `route`).
#[derive(Clone, Copy, Debug)]
pub struct RouterOptions {
    /// Per sub-request attempt: dial + write + read one reply line.
    pub attempt_timeout: Duration,
    /// Whole scatter-gather budget; groups still silent at the deadline
    /// degrade the result instead of extending it.
    pub scatter_budget: Duration,
    /// Background health-probe cadence.
    pub probe_interval: Duration,
    pub probe_timeout: Duration,
    /// Consecutive transport failures before an endpoint's breaker opens.
    pub breaker_threshold: u32,
    pub breaker_cooldown: Duration,
    /// Clamp band + warm-up value for the per-group hedge delay.
    pub hedge_min: Duration,
    pub hedge_max: Duration,
    pub hedge_initial: Duration,
}

impl Default for RouterOptions {
    fn default() -> RouterOptions {
        RouterOptions {
            attempt_timeout: Duration::from_secs(2),
            scatter_budget: Duration::from_secs(3),
            probe_interval: Duration::from_millis(100),
            probe_timeout: Duration::from_millis(250),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            hedge_min: Duration::from_millis(1),
            hedge_max: Duration::from_millis(100),
            hedge_initial: Duration::from_millis(10),
        }
    }
}

/// Fleet-level counters (exported via `metrics` and `metrics_text`).
#[derive(Default)]
pub struct RouterMetrics {
    /// Request lines handled (any op).
    pub queries: AtomicU64,
    /// Single-shard replies relayed verbatim.
    pub relayed: AtomicU64,
    /// Scatter-gather `lsh_query` fan-outs started.
    pub scatter_queries: AtomicU64,
    /// Scatter results with every group present.
    pub full: AtomicU64,
    /// Scatter results missing at least one group (marked `partial`).
    pub partial: AtomicU64,
    /// Typed `shard_down` refusals issued (single-shard and scatter).
    pub shard_down: AtomicU64,
    /// Failover hops (replica-to-replica or group-to-group).
    pub failovers: AtomicU64,
    /// Hedged duplicate sub-queries launched.
    pub hedges: AtomicU64,
    /// Hedges whose answer arrived first.
    pub hedge_wins: AtomicU64,
}

impl RouterMetrics {
    fn get(c: &AtomicU64) -> f64 {
        // ORDERING: Relaxed — monotonic observability counters; readers
        // tolerate slightly stale values.
        c.load(Ordering::Relaxed) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queries", Json::Num(Self::get(&self.queries))),
            ("relayed", Json::Num(Self::get(&self.relayed))),
            ("scatter_queries", Json::Num(Self::get(&self.scatter_queries))),
            ("full", Json::Num(Self::get(&self.full))),
            ("partial", Json::Num(Self::get(&self.partial))),
            ("shard_down", Json::Num(Self::get(&self.shard_down))),
            ("failovers", Json::Num(Self::get(&self.failovers))),
            ("hedges", Json::Num(Self::get(&self.hedges))),
            ("hedge_wins", Json::Num(Self::get(&self.hedge_wins))),
        ])
    }
}

/// One shard group at runtime: named replicas plus that group's adaptive
/// hedge policy.
struct Group {
    name: String,
    endpoints: Vec<Arc<Endpoint>>,
    hedge: Arc<HedgePolicy>,
}

/// What one group's scatter worker resolved to.
enum GroupAnswer {
    /// Decoded top-k pairs from a successful sub-query.
    Pairs(Vec<(u32, u64)>),
    /// A terminal (non-failover-eligible) refusal — the fleet's answer.
    Terminal(Json),
    /// Every replica unreachable / refused retryably / timed out.
    Down,
}

/// The fleet front-end: owns the shard endpoints, routes compute ops to
/// their rendezvous owner, scatter-gathers `lsh_query`.
pub struct ShardRouter {
    groups: Vec<Group>,
    opts: RouterOptions,
    pub metrics: Arc<RouterMetrics>,
    draining: AtomicBool,
    _prober: Prober,
}

impl ShardRouter {
    pub fn new(specs: Vec<ShardSpec>, opts: RouterOptions) -> ShardRouter {
        let groups: Vec<Group> = specs
            .into_iter()
            .map(|s| Group {
                name: s.name,
                endpoints: s
                    .endpoints
                    .iter()
                    .map(|a| {
                        Arc::new(Endpoint::new(a, opts.breaker_threshold, opts.breaker_cooldown))
                    })
                    .collect(),
                hedge: Arc::new(HedgePolicy::new(
                    opts.hedge_min,
                    opts.hedge_max,
                    opts.hedge_initial,
                )),
            })
            .collect();
        let all: Vec<Arc<Endpoint>> =
            groups.iter().flat_map(|g| g.endpoints.iter().cloned()).collect();
        let prober = Prober::start(all, opts.probe_interval, opts.probe_timeout);
        ShardRouter {
            groups,
            opts,
            metrics: Arc::new(RouterMetrics::default()),
            draining: AtomicBool::new(false),
            _prober: prober,
        }
    }

    fn draining_refusal(&self, id: Json) -> Json {
        let e = SubmitError::Draining { retry_after_ms: DRAINING_RETRY_MS };
        codec::err_response_with_hint(id, &e.to_string(), e.code(), e.retry_after_ms())
    }

    /// Forward `line` verbatim to the rendezvous owner of this request,
    /// failing over through replicas and then fallback groups.
    fn route_single(&self, line: &str, req: &codec::Request) -> Json {
        let key = topology::request_key(req.op.name(), &req.vector);
        let names: Vec<String> = self.groups.iter().map(|g| g.name.clone()).collect();
        for gi in topology::rendezvous_order(&names, key) {
            for ep in &self.groups[gi].endpoints {
                if !ep.admit() {
                    continue;
                }
                match ep.call(line, self.opts.attempt_timeout) {
                    CallOutcome::Reply(doc) => {
                        let ok = doc.get("ok") == Some(&Json::Bool(true));
                        let code = doc.get("code").and_then(Json::as_str).unwrap_or("");
                        if !ok && (is_retryable(code) || code == CODE_TIMEOUT) {
                            self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        self.metrics.relayed.fetch_add(1, Ordering::Relaxed);
                        return doc;
                    }
                    CallOutcome::Unreachable(_) => {
                        self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                }
            }
        }
        self.metrics.shard_down.fetch_add(1, Ordering::Relaxed);
        codec::err_response_with_hint(
            req.id.clone(),
            "no shard reachable for this request",
            CODE_SHARD_DOWN,
            Some(SHARD_DOWN_RETRY_MS),
        )
    }

    /// Scatter an `lsh_query` to every group, merge what comes back
    /// inside the budget, mark whatever is missing.
    fn scatter_lsh(&self, id: Json, doc: &Json) -> Json {
        let Some(vec_json) = doc.get("vector").and_then(|v| v.as_arr()) else {
            return codec::err_response(id, "missing 'vector' array", CODE_BAD_REQUEST);
        };
        if vec_json.iter().any(|v| v.as_f64().is_none()) {
            return codec::err_response(id, "'vector' must contain numbers", CODE_BAD_REQUEST);
        }
        let k = match doc.get("k") {
            None => return codec::err_response(id, "missing 'k'", CODE_BAD_REQUEST),
            Some(v) => match v.as_usize() {
                Some(k) if k >= 1 => k,
                _ => {
                    return codec::err_response(
                        id,
                        "'k' must be a positive integer",
                        CODE_BAD_REQUEST,
                    )
                }
            },
        };
        self.metrics.scatter_queries.fetch_add(1, Ordering::Relaxed);
        // re-render the parsed vector (exact: Json holds the f64s the
        // client sent) under a fixed sub-request id
        let sub_line = Arc::new(
            Json::obj(vec![
                ("id", Json::Num(0.0)),
                ("op", Json::Str("lsh_query".to_string())),
                ("vector", Json::Arr(vec_json.to_vec())),
                ("k", Json::Num(k as f64)),
            ])
            .to_string(),
        );

        let (tx, rx) = mpsc::channel();
        for (gi, g) in self.groups.iter().enumerate() {
            let endpoints = g.endpoints.clone();
            let hedge = Arc::clone(&g.hedge);
            let metrics = Arc::clone(&self.metrics);
            let line = Arc::clone(&sub_line);
            let attempt = self.opts.attempt_timeout;
            let tx = tx.clone();
            std::thread::spawn(move || {
                let ans = query_group(&endpoints, &line, attempt, &hedge, &metrics);
                let _ = tx.send((gi, ans));
            });
        }
        drop(tx);

        let deadline = Instant::now() + self.opts.scatter_budget;
        let mut answers: Vec<GroupAnswer> =
            (0..self.groups.len()).map(|_| GroupAnswer::Down).collect();
        let mut received = 0;
        while received < self.groups.len() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break; // still-silent groups stay Down => degraded
            }
            match rx.recv_timeout(left) {
                Ok((gi, ans)) => {
                    answers[gi] = ans;
                    received += 1;
                }
                Err(_) => break,
            }
        }

        // a terminal refusal from any shard is the fleet's answer (e.g.
        // bad_dim: every shard would refuse identically)
        for ans in &answers {
            if let GroupAnswer::Terminal(doc) = ans {
                let msg = doc
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("shard refused the query");
                let code = doc.get("code").and_then(Json::as_str).unwrap_or(CODE_BAD_REQUEST);
                let hint = doc.get("retry_after_ms").and_then(Json::as_f64).map(|f| f as u64);
                return codec::err_response_with_hint(id, msg, code, hint);
            }
        }

        let mut parts = Vec::new();
        let mut degraded = Vec::new();
        for (gi, ans) in answers.into_iter().enumerate() {
            match ans {
                GroupAnswer::Pairs(p) => parts.push(p),
                GroupAnswer::Down => degraded.push(self.groups[gi].name.clone()),
                GroupAnswer::Terminal(_) => unreachable!("terminals returned above"),
            }
        }
        if parts.is_empty() {
            self.metrics.shard_down.fetch_add(1, Ordering::Relaxed);
            return codec::err_response_with_hint(
                id,
                "no shard answered the query",
                CODE_SHARD_DOWN,
                Some(SHARD_DOWN_RETRY_MS),
            );
        }
        let merged = topology::merge_topk(&parts, k);
        if degraded.is_empty() {
            self.metrics.full.fetch_add(1, Ordering::Relaxed);
            codec::lsh_ok_response(id, &merged)
        } else {
            self.metrics.partial.fetch_add(1, Ordering::Relaxed);
            codec::partial_response(id, codec::lsh_result(&merged), degraded)
        }
    }

    /// Fleet counters plus per-endpoint wire counters and breaker phase.
    pub fn metrics_json(&self) -> Json {
        let mut map = BTreeMap::new();
        map.insert("router".to_string(), self.metrics.to_json());
        for g in &self.groups {
            let eps: Vec<Json> = g
                .endpoints
                .iter()
                .map(|ep| {
                    Json::obj(vec![
                        ("addr", Json::Str(ep.addr.clone())),
                        ("sent", Json::Num(RouterMetrics::get(&ep.metrics.sent))),
                        ("ok", Json::Num(RouterMetrics::get(&ep.metrics.ok))),
                        ("failed", Json::Num(RouterMetrics::get(&ep.metrics.failed))),
                        ("probes", Json::Num(RouterMetrics::get(&ep.metrics.probes))),
                        (
                            "probe_failures",
                            Json::Num(RouterMetrics::get(&ep.metrics.probe_failures)),
                        ),
                        ("state", Json::Str(ep.state.phase().name().to_string())),
                    ])
                })
                .collect();
            map.insert(g.name.clone(), Json::Arr(eps));
        }
        Json::Obj(map)
    }

    /// Drain flag plus per-replica breaker phases.
    pub fn health_json(&self) -> Json {
        let mut map = BTreeMap::new();
        // ORDERING: Relaxed — one-way drain latch, freshness not needed.
        map.insert("draining".to_string(), Json::Bool(self.draining.load(Ordering::Relaxed)));
        for g in &self.groups {
            let eps: Vec<Json> = g
                .endpoints
                .iter()
                .map(|ep| {
                    Json::obj(vec![
                        ("addr", Json::Str(ep.addr.clone())),
                        ("state", Json::Str(ep.state.phase().name().to_string())),
                    ])
                })
                .collect();
            map.insert(g.name.clone(), Json::Arr(eps));
        }
        Json::Obj(map)
    }

    /// Prometheus families: `ts_router_*` fleet counters, `ts_shard_*`
    /// per-endpoint counters, and a `ts_shard_up` breaker gauge.
    pub fn families(&self) -> Vec<Family> {
        let m = &self.metrics;
        let router: [(&str, &AtomicU64); 9] = [
            ("queries", &m.queries),
            ("relayed", &m.relayed),
            ("scatter_queries", &m.scatter_queries),
            ("full", &m.full),
            ("partial", &m.partial),
            ("shard_down", &m.shard_down),
            ("failovers", &m.failovers),
            ("hedges", &m.hedges),
            ("hedge_wins", &m.hedge_wins),
        ];
        let mut out: Vec<Family> = router
            .into_iter()
            .map(|(key, c)| Family {
                name: format!("ts_router_{key}"),
                kind: "counter".to_string(),
                samples: vec![Sample { labels: Vec::new(), value: RouterMetrics::get(c) }],
            })
            .collect();
        let per_shard: [(&str, fn(&health::EndpointMetrics) -> &AtomicU64); 5] = [
            ("sent", |m| &m.sent),
            ("ok", |m| &m.ok),
            ("failed", |m| &m.failed),
            ("probes", |m| &m.probes),
            ("probe_failures", |m| &m.probe_failures),
        ];
        for (key, field) in per_shard {
            let samples = self
                .groups
                .iter()
                .flat_map(|g| {
                    g.endpoints.iter().map(|ep| Sample {
                        labels: vec![
                            ("shard".to_string(), g.name.clone()),
                            ("addr".to_string(), ep.addr.clone()),
                        ],
                        value: RouterMetrics::get(field(&ep.metrics)),
                    })
                })
                .collect();
            out.push(Family {
                name: format!("ts_shard_{key}"),
                kind: "counter".to_string(),
                samples,
            });
        }
        let up = self
            .groups
            .iter()
            .flat_map(|g| {
                g.endpoints.iter().map(|ep| Sample {
                    labels: vec![
                        ("shard".to_string(), g.name.clone()),
                        ("addr".to_string(), ep.addr.clone()),
                    ],
                    value: if ep.state.phase() == Phase::Open { 1.0 } else { 0.0 },
                })
            })
            .collect();
        out.push(Family { name: "ts_shard_up".to_string(), kind: "gauge".to_string(), samples: up });
        out
    }
}

impl LineService for ShardRouter {
    fn handle_line(&self, line: &str, _peer: &str) -> Json {
        self.metrics.queries.fetch_add(1, Ordering::Relaxed);
        match codec::parse_line(line) {
            ParsedLine::Malformed(reply) => reply,
            ParsedLine::Compute(req) => {
                // ORDERING: Relaxed — one-way drain latch; a request that
                // races the flag is refused by the shard's own drain.
                if self.draining.load(Ordering::Relaxed) {
                    return self.draining_refusal(req.id);
                }
                self.route_single(line, &req)
            }
            ParsedLine::Other { id, op, doc } => match op.as_deref() {
                Some("lsh_query") => {
                    // ORDERING: Relaxed — one-way drain latch (as above).
                    if self.draining.load(Ordering::Relaxed) {
                        return self.draining_refusal(id);
                    }
                    self.scatter_lsh(id, &doc)
                }
                Some("metrics") => codec::ok_response_json(id, self.metrics_json()),
                Some("health") => codec::ok_response_json(id, self.health_json()),
                Some("metrics_text") => codec::ok_response_json(
                    id,
                    Json::Str(crate::coordinator::prom::render(&self.families())),
                ),
                _ => codec::err_response(id, "missing or unknown 'op'", CODE_BAD_REQUEST),
            },
        }
    }

    fn begin_drain(&self) {
        // ORDERING: Relaxed — one-way latch; handlers observe it on
        // their next line, which is all drain needs.
        self.draining.store(true, Ordering::Relaxed);
    }

    fn drain(&self, _deadline: Duration) -> bool {
        // sub-requests are fire-and-forget threads with their own
        // timeouts; nothing to join at the router
        true
    }
}

/// Spawn the next admitted endpoint's attempt (detached thread); `false`
/// when no untried admitted endpoint remains.
fn launch_next(
    endpoints: &[Arc<Endpoint>],
    cursor: &mut usize,
    line: &Arc<String>,
    attempt_timeout: Duration,
    is_hedge: bool,
    tx: &mpsc::Sender<(bool, Instant, CallOutcome)>,
) -> bool {
    while *cursor < endpoints.len() {
        let ep = Arc::clone(&endpoints[*cursor]);
        *cursor += 1;
        if !ep.admit() {
            continue;
        }
        let tx = tx.clone();
        let line = Arc::clone(line);
        std::thread::spawn(move || {
            let started = Instant::now();
            let out = ep.call(&line, attempt_timeout);
            // receiver gone = the gather already resolved; drop silently
            let _ = tx.send((is_hedge, started, out));
        });
        return true;
    }
    false
}

/// Resolve one group's sub-query: primary attempt, hedged duplicate after
/// the group's adaptive delay, replica failover on retryable failures,
/// first terminal answer wins.
fn query_group(
    endpoints: &[Arc<Endpoint>],
    line: &Arc<String>,
    attempt_timeout: Duration,
    hedge: &Arc<HedgePolicy>,
    metrics: &Arc<RouterMetrics>,
) -> GroupAnswer {
    let hedge_delay = hedge.delay();
    let deadline = Instant::now() + attempt_timeout + hedge_delay + attempt_timeout;
    let (tx, rx) = mpsc::channel();
    let mut cursor = 0usize;
    if !launch_next(endpoints, &mut cursor, line, attempt_timeout, false, &tx) {
        return GroupAnswer::Down; // breaker-open across the whole group
    }
    let mut pending = 1usize;
    let mut hedged = false;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return GroupAnswer::Down;
        }
        let wait = if hedged { left } else { left.min(hedge_delay) };
        match rx.recv_timeout(wait) {
            Ok((is_hedge, started, CallOutcome::Reply(doc))) => {
                if doc.get("ok") == Some(&Json::Bool(true)) {
                    if let Some(pairs) = doc.get("result").and_then(codec::lsh_pairs) {
                        hedge.observe(started.elapsed());
                        if is_hedge {
                            metrics.hedge_wins.fetch_add(1, Ordering::Relaxed);
                        }
                        return GroupAnswer::Pairs(pairs);
                    }
                    // an ok reply we cannot decode is a failed attempt
                } else {
                    let code = doc.get("code").and_then(Json::as_str).unwrap_or("");
                    if !(is_retryable(code) || code == CODE_TIMEOUT) {
                        return GroupAnswer::Terminal(doc);
                    }
                }
                pending -= 1;
                metrics.failovers.fetch_add(1, Ordering::Relaxed);
                if launch_next(endpoints, &mut cursor, line, attempt_timeout, false, &tx) {
                    pending += 1;
                } else if pending == 0 {
                    return GroupAnswer::Down;
                }
            }
            Ok((_, _, CallOutcome::Unreachable(_))) => {
                pending -= 1;
                metrics.failovers.fetch_add(1, Ordering::Relaxed);
                if launch_next(endpoints, &mut cursor, line, attempt_timeout, false, &tx) {
                    pending += 1;
                } else if pending == 0 {
                    return GroupAnswer::Down;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if hedged {
                    return GroupAnswer::Down; // the full deadline elapsed
                }
                hedged = true;
                if launch_next(endpoints, &mut cursor, line, attempt_timeout, true, &tx) {
                    pending += 1;
                    metrics.hedges.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return GroupAnswer::Down,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{self, ServerOptions, TcpServer};
    use crate::coordinator::{Config, Coordinator, NativeBackend};
    use crate::runtime::Op;

    const N: usize = 64;
    const FLEET_SEED: u64 = 71;
    const POINTS: usize = 240;

    fn spawn_shard(shard: usize, shards: usize) -> TcpServer {
        let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
        let config = Config {
            lanes: vec![(Op::Transform, N), (Op::BinaryEmbed, N)],
            max_batch: 1,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            sigma: 1.0,
            seed: 17,
            ..Config::default()
        };
        let coordinator = Arc::new(Coordinator::start(config, backend));
        let points = demo_points(N, POINTS, FLEET_SEED);
        let index = ShardIndex::build(
            &points,
            &ShardIndexConfig {
                n: N,
                tables: 6,
                prefix_bits: 10,
                seed: FLEET_SEED,
                shard,
                shards,
            },
        );
        let service = Arc::new(ShardService::new(coordinator, index));
        server::serve(service, "127.0.0.1:0", ServerOptions::default()).unwrap()
    }

    fn fast_opts() -> RouterOptions {
        RouterOptions {
            attempt_timeout: Duration::from_millis(500),
            scatter_budget: Duration::from_millis(1500),
            probe_interval: Duration::from_millis(25),
            probe_timeout: Duration::from_millis(100),
            breaker_cooldown: Duration::from_millis(50),
            ..RouterOptions::default()
        }
    }

    fn specs_for(servers: &[&TcpServer]) -> Vec<ShardSpec> {
        servers
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSpec {
                name: format!("s{i}"),
                endpoints: vec![s.addr().to_string()],
            })
            .collect()
    }

    fn lsh_line(q: &[f32], k: usize) -> String {
        let vals: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
        format!("{{\"id\": 7, \"op\": \"lsh_query\", \"vector\": [{}], \"k\": {k}}}", vals.join(","))
    }

    fn query_vec(seed: u64) -> Vec<f32> {
        crate::util::rng::Rng::new(seed).unit_vec(N)
    }

    #[test]
    fn scatter_gather_reproduces_the_global_topk() {
        let s0 = spawn_shard(0, 2);
        let s1 = spawn_shard(1, 2);
        let router = ShardRouter::new(specs_for(&[&s0, &s1]), fast_opts());
        let points = demo_points(N, POINTS, FLEET_SEED);
        let global = ShardIndex::build(
            &points,
            &ShardIndexConfig {
                n: N,
                tables: 6,
                prefix_bits: 10,
                seed: FLEET_SEED,
                shard: 0,
                shards: 1,
            },
        );
        for seed in 0..5u64 {
            let q = query_vec(seed);
            let reply = router.handle_line(&lsh_line(&q, 8), "test");
            assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
            assert_eq!(reply.get("code"), None, "full result is unmarked: {reply}");
            assert_eq!(reply.get("id"), Some(&Json::Num(7.0)), "client id echoed");
            let pairs = codec::lsh_pairs(reply.get("result").unwrap()).unwrap();
            assert_eq!(pairs, global.query(&q, 8), "fleet == one big index");
        }
        assert_eq!(router.metrics.full.load(Ordering::Relaxed), 5);
        assert_eq!(router.metrics.partial.load(Ordering::Relaxed), 0);
        s0.shutdown();
        s1.shutdown();
    }

    #[test]
    fn a_dead_shard_degrades_to_a_marked_partial_result() {
        let s0 = spawn_shard(0, 2);
        let s1 = spawn_shard(1, 2);
        let addr1 = s1.addr().to_string();
        let specs = vec![
            ShardSpec { name: "s0".to_string(), endpoints: vec![s0.addr().to_string()] },
            ShardSpec { name: "s1".to_string(), endpoints: vec![addr1] },
        ];
        let router = ShardRouter::new(specs, fast_opts());
        s1.shutdown(); // kill the whole second shard
        let q = query_vec(3);
        let reply = router.handle_line(&lsh_line(&q, 8), "test");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "partial is a success: {reply}");
        assert_eq!(
            reply.get("code").and_then(Json::as_str),
            Some(codec::CODE_PARTIAL),
            "degradation is marked, never silent: {reply}"
        );
        let degraded = reply.get("degraded").unwrap().as_arr().unwrap();
        assert_eq!(degraded, &[Json::Str("s1".to_string())][..], "names the missing shard");
        // the surviving shard's answer is still the exact local top-k
        let points = demo_points(N, POINTS, FLEET_SEED);
        let local = ShardIndex::build(
            &points,
            &ShardIndexConfig {
                n: N,
                tables: 6,
                prefix_bits: 10,
                seed: FLEET_SEED,
                shard: 0,
                shards: 2,
            },
        );
        let pairs = codec::lsh_pairs(reply.get("result").unwrap()).unwrap();
        assert_eq!(pairs, local.query(&q, 8));
        assert_eq!(router.metrics.partial.load(Ordering::Relaxed), 1);
        s0.shutdown();
    }

    #[test]
    fn compute_requests_fail_over_to_the_replica_invisibly() {
        let primary = spawn_shard(0, 1);
        let replica = spawn_shard(0, 1);
        let specs = vec![ShardSpec {
            name: "s0".to_string(),
            endpoints: vec![primary.addr().to_string(), replica.addr().to_string()],
        }];
        let router = ShardRouter::new(specs, fast_opts());
        primary.shutdown();
        let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32 / 8.0 - 4.0)).collect();
        let line =
            format!("{{\"id\": 3, \"op\": \"transform\", \"vector\": [{}]}}", vals.join(","));
        let reply = router.handle_line(&line, "test");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "replica served it: {reply}");
        assert_eq!(reply.get("id"), Some(&Json::Num(3.0)));
        assert!(router.metrics.failovers.load(Ordering::Relaxed) >= 1);
        replica.shutdown();
    }

    #[test]
    fn an_empty_fleet_refuses_with_a_typed_shard_down() {
        // one group whose only endpoint never listens
        let specs = vec![ShardSpec {
            name: "s0".to_string(),
            endpoints: vec!["127.0.0.1:9".to_string()],
        }];
        let mut opts = fast_opts();
        opts.attempt_timeout = Duration::from_millis(150);
        opts.scatter_budget = Duration::from_millis(800);
        let router = ShardRouter::new(specs, opts);
        let q = query_vec(1);
        let reply = router.handle_line(&lsh_line(&q, 4), "test");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
        assert_eq!(reply.get("code").and_then(Json::as_str), Some(CODE_SHARD_DOWN));
        assert_eq!(
            reply.get("retry_after_ms"),
            Some(&Json::Num(SHARD_DOWN_RETRY_MS as f64)),
            "shard_down refusals carry the retry hint: {reply}"
        );
        let vals: Vec<String> = (0..N).map(|_| "0.5".to_string()).collect();
        let line =
            format!("{{\"id\": 9, \"op\": \"transform\", \"vector\": [{}]}}", vals.join(","));
        let reply = router.handle_line(&line, "test");
        assert_eq!(reply.get("code").and_then(Json::as_str), Some(CODE_SHARD_DOWN));
        assert_eq!(reply.get("id"), Some(&Json::Num(9.0)), "client id survives refusal");
        assert!(router.metrics.shard_down.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn terminal_refusals_relay_instead_of_masquerading_as_shard_down() {
        let s0 = spawn_shard(0, 1);
        let router = ShardRouter::new(specs_for(&[&s0]), fast_opts());
        // wrong dimensionality: the shard refuses bad_dim (terminal)
        let reply =
            router.handle_line("{\"id\": 5, \"op\": \"lsh_query\", \"vector\": [1.0], \"k\": 2}", "t");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(reply.get("code").and_then(Json::as_str), Some("bad_dim"), "{reply}");
        assert_eq!(reply.get("id"), Some(&Json::Num(5.0)), "client id restored");
        s0.shutdown();
    }

    #[test]
    fn router_introspection_reports_fleet_counters_and_breaker_phases() {
        let s0 = spawn_shard(0, 1);
        let router = ShardRouter::new(specs_for(&[&s0]), fast_opts());
        let q = query_vec(2);
        router.handle_line(&lsh_line(&q, 4), "test");
        let m = router.handle_line("{\"id\": 1, \"op\": \"metrics\"}", "t");
        let result = m.get("result").unwrap();
        let r = result.get("router").unwrap();
        assert_eq!(r.get("scatter_queries"), Some(&Json::Num(1.0)));
        let eps = result.get("s0").unwrap().as_arr().unwrap();
        assert_eq!(eps[0].get("state").and_then(Json::as_str), Some("open"));
        let h = router.handle_line("{\"id\": 2, \"op\": \"health\"}", "t");
        assert_eq!(h.get("result").unwrap().get("draining"), Some(&Json::Bool(false)));
        let t = router.handle_line("{\"id\": 3, \"op\": \"metrics_text\"}", "t");
        let text = t.get("result").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE ts_router_scatter_queries counter"), "{text}");
        assert!(text.contains("ts_router_full 1"), "{text}");
        assert!(text.contains("# TYPE ts_shard_up gauge"), "{text}");
        assert!(
            text.contains(&format!("ts_shard_up{{shard=\"s0\",addr=\"{}\"}} 1", s0.addr())),
            "{text}"
        );
        let families = crate::coordinator::prom::parse(text).expect("exposition parses");
        assert!(families.iter().any(|f| f.name == "ts_shard_sent"));
        s0.shutdown();
    }

    #[test]
    fn a_draining_router_refuses_with_the_retry_hint() {
        let s0 = spawn_shard(0, 1);
        let router = ShardRouter::new(specs_for(&[&s0]), fast_opts());
        router.begin_drain();
        let q = query_vec(4);
        let reply = router.handle_line(&lsh_line(&q, 4), "test");
        assert_eq!(reply.get("code").and_then(Json::as_str), Some("draining"), "{reply}");
        assert_eq!(reply.get("retry_after_ms"), Some(&Json::Num(DRAINING_RETRY_MS as f64)));
        assert!(router.drain(Duration::from_millis(10)));
        s0.shutdown();
    }
}
