//! Fleet topology: shard groups, replica lists, deterministic routing.
//!
//! A fleet is an ordered list of **shard groups**; each group holds one or
//! more replica endpoints serving the same data. The wire spec is
//! `"primary|replica,primary|replica,..."` — commas separate groups,
//! pipes separate replicas within a group — and groups are named `s0`,
//! `s1`, ... in spec order (the names appear in `degraded` markers,
//! metrics labels, and health output, so they are part of the observable
//! contract).
//!
//! Routing is deterministic and state-free: compute ops pick their owner
//! group by **rendezvous (highest-random-weight) hashing** of the request
//! key against each group name, which keeps assignment stable when groups
//! are added or removed (only keys owned by the changed group move).
//! Scatter-gather answers combine with [`merge_topk`], whose `(distance,
//! id)` ordering matches the per-shard LSH ordering exactly — so a merged
//! fleet answer is byte-identical to what one big index would return.

/// One shard group: a name plus its replica endpoints (first = primary).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardSpec {
    pub name: String,
    pub endpoints: Vec<String>,
}

/// Parse a `"host:p1|host:p1b,host:p2,host:p3"` fleet spec. Empty groups
/// or empty endpoints are rejected loudly (a silently-shrunken fleet
/// would serve partial answers with no shard ever marked down).
pub fn parse_topology(spec: &str) -> Result<Vec<ShardSpec>, String> {
    let mut groups = Vec::new();
    for (i, group) in spec.split(',').enumerate() {
        let group = group.trim();
        if group.is_empty() {
            return Err(format!("topology: group {i} is empty"));
        }
        let endpoints: Vec<String> = group
            .split('|')
            .map(str::trim)
            .map(str::to_string)
            .collect();
        if endpoints.iter().any(String::is_empty) {
            return Err(format!("topology: group {i} has an empty endpoint"));
        }
        groups.push(ShardSpec {
            name: format!("s{i}"),
            endpoints,
        });
    }
    if groups.is_empty() {
        return Err("topology: no shard groups".to_string());
    }
    Ok(groups)
}

/// FNV-1a over bytes: tiny, deterministic, good enough spread for
/// rendezvous weights and bucket-range placement (not cryptographic).
pub fn hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Routing key for a compute request: op name + exact input bits, so the
/// same request always lands on the same owner group (cache affinity)
/// while nearby-but-different vectors spread uniformly.
pub fn request_key(op: &str, vector: &[f32]) -> u64 {
    let mut bytes = Vec::with_capacity(op.len() + vector.len() * 4);
    bytes.extend_from_slice(op.as_bytes());
    for x in vector {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    hash64(&bytes)
}

/// Rendezvous order: group indices sorted by descending weight
/// `hash(name ⊕ key)`. Index 0 is the owner; the rest are the stable
/// fallback order when the owner's replicas are all down.
pub fn rendezvous_order(names: &[String], key: u64) -> Vec<usize> {
    let mut weighted: Vec<(u64, usize)> = names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let mut bytes = name.as_bytes().to_vec();
            bytes.extend_from_slice(&key.to_le_bytes());
            (hash64(&bytes), i)
        })
        .collect();
    weighted.sort_by(|a, b| b.cmp(a));
    weighted.into_iter().map(|(_, i)| i).collect()
}

/// Merge per-shard top-k lists into the fleet top-k: ascending by
/// `(distance, id)` — the same total order every shard sorts by — with
/// duplicate ids dropped (a hedged sub-query can answer twice).
pub fn merge_topk(parts: &[Vec<(u32, u64)>], k: usize) -> Vec<(u32, u64)> {
    let mut all: Vec<(u32, u64)> = parts.iter().flatten().copied().collect();
    all.sort_by_key(|&(id, d)| (d, id));
    let mut seen = std::collections::BTreeSet::new();
    all.retain(|&(id, _)| seen.insert(id));
    all.truncate(k);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_parses_groups_and_replicas() {
        let t = parse_topology("a:1|b:1, c:2 ,d:3").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].name, "s0");
        assert_eq!(t[0].endpoints, vec!["a:1".to_string(), "b:1".to_string()]);
        assert_eq!(t[1].endpoints, vec!["c:2".to_string()]);
        assert_eq!(t[2].name, "s2");
        assert!(parse_topology("").is_err());
        assert!(parse_topology("a:1,,b:2").is_err());
        assert!(parse_topology("a:1|").is_err());
    }

    #[test]
    fn rendezvous_is_deterministic_and_balanced() {
        let names: Vec<String> = (0..4).map(|i| format!("s{i}")).collect();
        let mut counts = [0usize; 4];
        for key in 0..4000u64 {
            let order = rendezvous_order(&names, hash64(&key.to_le_bytes()));
            assert_eq!(order.len(), 4);
            // a permutation, and stable across calls
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3]);
            assert_eq!(order, rendezvous_order(&names, hash64(&key.to_le_bytes())));
            counts[order[0]] += 1;
        }
        for &c in &counts {
            assert!(c > 600 && c < 1400, "owner load skew: {counts:?}");
        }
    }

    #[test]
    fn rendezvous_moves_only_the_removed_groups_keys() {
        // minimal-disruption property: dropping one group must not move
        // keys between the surviving groups
        let four: Vec<String> = (0..4).map(|i| format!("s{i}")).collect();
        let three: Vec<String> = vec!["s0".into(), "s1".into(), "s2".into()];
        for key in 0..2000u64 {
            let k = hash64(&key.to_le_bytes());
            let owner4 = rendezvous_order(&four, k)[0];
            let owner3 = rendezvous_order(&three, k)[0];
            if owner4 != 3 {
                assert_eq!(owner4, owner3, "key {key} moved between survivors");
            }
        }
    }

    #[test]
    fn request_key_depends_on_op_and_exact_bits() {
        let v = [0.25f32, -1.5, 3.0];
        assert_eq!(request_key("transform", &v), request_key("transform", &v));
        assert_ne!(request_key("transform", &v), request_key("binary_embed", &v));
        let mut w = v;
        w[1] = -1.5000001;
        assert_ne!(request_key("transform", &v), request_key("transform", &w));
    }

    #[test]
    fn merge_topk_orders_dedups_and_truncates() {
        let parts = vec![
            vec![(5u32, 2u64), (1, 4)],
            vec![(9, 1), (5, 2), (7, 4)], // 5 duplicated by a hedge win
            vec![(2, 3)],
        ];
        let merged = merge_topk(&parts, 4);
        assert_eq!(merged, vec![(9, 1), (5, 2), (2, 3), (1, 4)]);
        // id breaks distance ties deterministically
        let tied = vec![vec![(8u32, 7u64)], vec![(3, 7)]];
        assert_eq!(merge_topk(&tied, 2), vec![(3, 7), (8, 7)]);
        assert_eq!(merge_topk(&[], 3), vec![]);
    }
}
