//! Shard endpoints: pooled connections, health probes, per-endpoint
//! circuit breakers.
//!
//! An [`Endpoint`] is one replica address plus everything the router
//! needs to distrust it: a connection pool (take on call, return only
//! after a clean round trip — an abandoned or failed connection is
//! dropped, never returned dirty, so a hedge loser can't desync the
//! stream for the next caller), a [`LaneState`] circuit breaker reused
//! verbatim from the coordinator's lane supervision (same
//! open/degraded/half-open semantics, now guarding a TCP peer instead of
//! a thread), and wire counters.
//!
//! The [`Prober`] is the recovery path: a background thread sends a
//! `health` request to every endpoint each interval, **bypassing**
//! `admit()` — probe successes are exactly how an open breaker learns the
//! shard is back and closes again, without spending a client request on
//! the experiment.

use crate::coordinator::breaker::LaneState;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-endpoint wire counters (exported via the router's `metrics` op
/// and the `metrics_text` exposition).
#[derive(Default)]
pub struct EndpointMetrics {
    /// Requests written (calls + probes).
    pub sent: AtomicU64,
    /// Clean round trips (a parseable reply line came back).
    pub ok: AtomicU64,
    /// Transport failures (dial/write/read/parse).
    pub failed: AtomicU64,
    /// Health probes issued.
    pub probes: AtomicU64,
    /// Probes that failed (transport error or non-ok reply).
    pub probe_failures: AtomicU64,
}

/// What one sub-request attempt produced at the transport level.
pub enum CallOutcome {
    /// A parseable reply line (may still be a coded refusal).
    Reply(Json),
    /// No reply: dial/write/read/parse failure. The connection is gone.
    Unreachable(String),
}

type Conn = (BufReader<TcpStream>, TcpStream);

/// One replica address with pooled connections and a circuit breaker.
pub struct Endpoint {
    pub addr: String,
    /// Reused lane-breaker: records call/probe outcomes, gates `admit()`.
    pub state: LaneState,
    pub metrics: EndpointMetrics,
    pool: Mutex<Vec<Conn>>,
}

impl Endpoint {
    pub fn new(addr: &str, breaker_threshold: u32, breaker_cooldown: Duration) -> Endpoint {
        Endpoint {
            addr: addr.to_string(),
            state: LaneState::new(breaker_threshold, breaker_cooldown),
            metrics: EndpointMetrics::default(),
            pool: Mutex::new(Vec::new()),
        }
    }

    /// Breaker gate for client-request traffic (probes bypass this).
    pub fn admit(&self) -> bool {
        self.state.admit()
    }

    /// One request/response round trip. Takes a pooled connection or
    /// dials; the connection returns to the pool only after a clean
    /// round trip. Success/failure feeds the breaker.
    pub fn call(&self, line: &str, timeout: Duration) -> CallOutcome {
        self.metrics.sent.fetch_add(1, Ordering::Relaxed);
        let conn = self.pool.lock().unwrap_or_else(|p| p.into_inner()).pop();
        let mut conn = match conn {
            Some(c) => c,
            None => match self.dial(timeout) {
                Ok(c) => c,
                Err(e) => return self.fail(e),
            },
        };
        let _ = conn.1.set_read_timeout(Some(timeout));
        if let Err(e) = conn
            .1
            .write_all(format!("{line}\n").as_bytes())
            .and_then(|()| conn.1.flush())
        {
            return self.fail(e.to_string());
        }
        let mut reply = String::new();
        match conn.0.read_line(&mut reply) {
            Ok(0) => return self.fail("shard closed the connection".to_string()),
            Ok(_) => {}
            Err(e) => return self.fail(e.to_string()),
        }
        match Json::parse(reply.trim()) {
            Ok(doc) => {
                self.pool
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .push(conn);
                self.state.record_success();
                self.metrics.ok.fetch_add(1, Ordering::Relaxed);
                CallOutcome::Reply(doc)
            }
            Err(e) => self.fail(format!("unparseable shard reply: {e:?}")),
        }
    }

    /// One health probe (bypasses `admit()` — this is the recovery path).
    /// `true` when the shard answered `ok`.
    pub fn probe(&self, timeout: Duration) -> bool {
        self.metrics.probes.fetch_add(1, Ordering::Relaxed);
        let up = matches!(
            self.call(r#"{"id":0,"op":"health"}"#, timeout),
            CallOutcome::Reply(doc) if doc.get("ok") == Some(&Json::Bool(true))
        );
        if !up {
            self.metrics.probe_failures.fetch_add(1, Ordering::Relaxed);
        }
        up
    }

    fn fail(&self, e: String) -> CallOutcome {
        // the breaker edge (closed -> open) is interesting but already
        // counted as failed + state transition; drop the bool
        let _ = self.state.record_failure();
        self.metrics.failed.fetch_add(1, Ordering::Relaxed);
        CallOutcome::Unreachable(e)
    }

    fn dial(&self, timeout: Duration) -> Result<Conn, String> {
        let addr = self
            .addr
            .to_socket_addrs()
            .map_err(|e| e.to_string())?
            .next()
            .ok_or_else(|| format!("no address for {}", self.addr))?;
        let stream = TcpStream::connect_timeout(&addr, timeout).map_err(|e| e.to_string())?;
        stream
            .set_write_timeout(Some(Duration::from_secs(5)))
            .map_err(|e| e.to_string())?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok((reader, stream))
    }
}

/// Background health-probe loop over a fleet's endpoints; stops and joins
/// on drop.
pub struct Prober {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Prober {
    pub fn start(endpoints: Vec<Arc<Endpoint>>, interval: Duration, timeout: Duration) -> Prober {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("shard-probe".into())
            .spawn(move || {
                // ORDERING: Relaxed — one-way stop latch polled per round;
                // shutdown correctness comes from the join.
                while !stop2.load(Ordering::Relaxed) {
                    for ep in &endpoints {
                        ep.probe(timeout);
                    }
                    std::thread::sleep(interval);
                }
            })
            .ok();
        Prober { stop, join }
    }
}

impl Drop for Prober {
    fn drop(&mut self) {
        // ORDERING: Relaxed — one-way latch; the join below synchronizes.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::breaker::Phase;

    #[test]
    fn unreachable_endpoint_trips_its_breaker_and_counts_failures() {
        // port 9 (discard) on localhost: nothing listens in the test env
        let ep = Endpoint::new("127.0.0.1:9", 2, Duration::from_millis(50));
        assert!(ep.admit(), "breaker starts closed");
        for _ in 0..2 {
            match ep.call(r#"{"id":0,"op":"health"}"#, Duration::from_millis(200)) {
                CallOutcome::Unreachable(_) => {}
                CallOutcome::Reply(r) => panic!("nothing listens on :9, got {r}"),
            }
        }
        assert_eq!(ep.state.phase(), Phase::Degraded, "threshold 2 tripped");
        assert!(!ep.admit(), "open breaker sheds before the cooldown");
        assert_eq!(ep.metrics.failed.load(Ordering::Relaxed), 2);
        assert_eq!(ep.metrics.sent.load(Ordering::Relaxed), 2);
        // probes keep flowing despite the open breaker (recovery path)
        assert!(!ep.probe(Duration::from_millis(200)));
        assert_eq!(ep.metrics.probes.load(Ordering::Relaxed), 1);
        assert_eq!(ep.metrics.probe_failures.load(Ordering::Relaxed), 1);
    }
}
