//! Shard-side fleet tenant: a bucket-prefix-range slice of an LSH point
//! set, served next to the coordinator's compute lanes.
//!
//! ## Placement
//!
//! Every shard derives the same deterministic **placement code** per
//! point — a `prefix_bits`-bit structured binary embedding
//! ([`crate::binary::BinaryEmbedding`] over an HD3 chain, seeded from the
//! fleet seed with a fixed salt so it is independent of the index
//! tables) — and keeps exactly the points whose code falls in its
//! contiguous range of the code space: shard `i` of `m` owns codes `c`
//! with `⌊c·m / 2^prefix_bits⌋ = i`. No coordination, no point list
//! exchange: feed every shard the same point stream and the fleet
//! partitions itself.
//!
//! ## Exactness under scatter-gather
//!
//! All shards build their [`crate::lsh::HammingLsh`] tables from the same
//! fleet seed, so a point's bucket key in its shard's index equals its
//! key in a hypothetical global index; local indices are assigned in
//! global-id order, so the per-shard `(distance, local_id)` result order
//! equals the global `(distance, global_id)` order. Union the per-shard
//! buckets and you get exactly the global candidate set — which is why
//! the router's merged top-k is *identical* to one big index's answer
//! (asserted in the chaos suite), and a missing shard degrades recall
//! only by its own points.
//!
//! [`ShardService`] is the [`LineService`] a shard process runs: it
//! answers `lsh_query` from the local index slice and delegates every
//! other op (compute, introspection) to the coordinator's line handler.

use crate::binary::BinaryEmbedding;
use crate::coordinator::codec::{self, ParsedLine};
use crate::coordinator::server::{self, LineService};
use crate::coordinator::{Coordinator, SubmitError, DRAINING_RETRY_MS};
use crate::linalg::Workspace;
use crate::lsh::HammingLsh;
use crate::transform::{make, Family};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Salt xor'd into the fleet seed for the placement embedding, so
/// placement is independent of the index tables built from the same seed.
const PLACEMENT_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Everything needed to build one shard's slice of the fleet index.
#[derive(Clone, Copy, Debug)]
pub struct ShardIndexConfig {
    /// Point / query dimensionality (power of two).
    pub n: usize,
    /// LSH tables per shard.
    pub tables: usize,
    /// Bucket-prefix width in bits (also the placement-code width).
    pub prefix_bits: usize,
    /// Fleet seed: index tables AND placement derive from it, so every
    /// shard agrees on both without coordination.
    pub seed: u64,
    /// This shard's position in `0..shards`.
    pub shard: usize,
    /// Fleet width. `1` = a global (unsharded) index.
    pub shards: usize,
}

/// Which shard owns a placement code: contiguous range partition of the
/// `prefix_bits`-bit code space.
pub fn placement_owner(code: u64, prefix_bits: usize, shards: usize) -> usize {
    ((code as u128 * shards as u128) >> prefix_bits) as usize
}

/// Deterministic per-point placement codes (identical on every shard).
fn placement_codes(points: &[Vec<f32>], cfg: &ShardIndexConfig) -> Vec<u64> {
    let mut rng = Rng::new(cfg.seed ^ PLACEMENT_SALT);
    let embed = BinaryEmbedding::new(make(
        Family::Hd3,
        cfg.prefix_bits,
        cfg.n,
        cfg.n,
        &mut rng,
    ));
    let mut ws = Workspace::new();
    let mut word = vec![0u64; embed.words_per_code()];
    let mask = if cfg.prefix_bits == 64 {
        u64::MAX
    } else {
        (1u64 << cfg.prefix_bits) - 1
    };
    points
        .iter()
        .map(|p| {
            embed.embed_into(p, &mut word, &mut ws);
            word[0] & mask
        })
        .collect()
}

/// One shard's slice of the fleet LSH index: the local tables plus the
/// local-to-global id map.
pub struct ShardIndex {
    index: HammingLsh,
    /// Local row -> global point id (ascending, by construction).
    ids: Vec<u32>,
    n: usize,
}

impl ShardIndex {
    /// Keep this shard's range of `points` (by placement code) and index
    /// it. Every shard calls this with the SAME full point stream.
    pub fn build(points: &[Vec<f32>], cfg: &ShardIndexConfig) -> ShardIndex {
        assert!(cfg.shards >= 1, "fleet width must be at least 1");
        assert!(cfg.shard < cfg.shards, "shard index out of range");
        let codes = placement_codes(points, cfg);
        let mut mine = Vec::new();
        let mut ids = Vec::new();
        for (i, p) in points.iter().enumerate() {
            if placement_owner(codes[i], cfg.prefix_bits, cfg.shards) == cfg.shard {
                ids.push(i as u32);
                mine.push(p.clone());
            }
        }
        let index = HammingLsh::build(
            &mine,
            Family::Hd3,
            cfg.n,
            cfg.tables,
            cfg.prefix_bits,
            cfg.seed,
        );
        ShardIndex {
            index,
            ids,
            n: cfg.n,
        }
    }

    /// Local top-k for `q`, reported as `(global_id, hamming_distance)`
    /// in the fleet-wide `(distance, id)` order.
    pub fn query(&self, q: &[f32], k: usize) -> Vec<(u32, u64)> {
        self.index
            .query(q, k)
            .into_iter()
            .map(|(local, d)| (self.ids[local], d))
            .collect()
    }

    /// Points this shard owns.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Query/point dimensionality.
    pub fn dim(&self) -> usize {
        self.n
    }
}

/// Deterministic demo point set (unit vectors) shared by the `serve
/// --shard` CLI and the chaos suite: every shard of a fleet generates the
/// identical stream from the fleet seed and keeps its own slice.
pub fn demo_points(n: usize, count: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..count).map(|_| rng.unit_vec(n)).collect()
}

/// The [`LineService`] a shard process runs: `lsh_query` against the
/// local index slice, everything else delegated to the coordinator.
pub struct ShardService {
    coordinator: Arc<Coordinator>,
    index: ShardIndex,
}

impl ShardService {
    pub fn new(coordinator: Arc<Coordinator>, index: ShardIndex) -> ShardService {
        ShardService { coordinator, index }
    }

    pub fn index(&self) -> &ShardIndex {
        &self.index
    }

    fn lsh_query(&self, id: Json, doc: &Json) -> Json {
        if self.coordinator.is_draining() {
            let e = SubmitError::Draining {
                retry_after_ms: DRAINING_RETRY_MS,
            };
            return codec::err_response_with_hint(id, &e.to_string(), e.code(), e.retry_after_ms());
        }
        let Some(vec_json) = doc.get("vector").and_then(|v| v.as_arr()) else {
            return codec::err_response(id, "missing 'vector' array", codec::CODE_BAD_REQUEST);
        };
        let mut q = Vec::with_capacity(vec_json.len());
        for v in vec_json {
            match v.as_f64() {
                Some(f) => q.push(f as f32),
                None => {
                    return codec::err_response(
                        id,
                        "'vector' must contain numbers",
                        codec::CODE_BAD_REQUEST,
                    )
                }
            }
        }
        if q.len() != self.index.dim() {
            let e = SubmitError::BadDim;
            return codec::err_response(id, &e.to_string(), e.code());
        }
        let k = match doc.get("k") {
            None => {
                return codec::err_response(id, "missing 'k'", codec::CODE_BAD_REQUEST);
            }
            Some(v) => match v.as_usize() {
                Some(k) if k >= 1 => k,
                _ => {
                    return codec::err_response(
                        id,
                        "'k' must be a positive integer",
                        codec::CODE_BAD_REQUEST,
                    )
                }
            },
        };
        codec::lsh_ok_response(id, &self.index.query(&q, k))
    }
}

impl LineService for ShardService {
    fn handle_line(&self, line: &str, peer: &str) -> Json {
        if let ParsedLine::Other { id, op, doc } = codec::parse_line(line) {
            if op.as_deref() == Some("lsh_query") {
                return self.lsh_query(id, &doc);
            }
        }
        server::process_line_from(line, &self.coordinator, peer)
    }

    fn begin_drain(&self) {
        self.coordinator.begin_drain();
    }

    fn drain(&self, deadline: Duration) -> bool {
        self.coordinator.drain(deadline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::normalize;

    fn cfg(shard: usize, shards: usize) -> ShardIndexConfig {
        ShardIndexConfig {
            n: 64,
            tables: 6,
            prefix_bits: 10,
            seed: 71,
            shard,
            shards,
        }
    }

    #[test]
    fn shards_partition_the_point_set_exactly() {
        let points = demo_points(64, 300, 5);
        let shards: Vec<ShardIndex> = (0..3).map(|s| ShardIndex::build(&points, &cfg(s, 3))).collect();
        let total: usize = shards.iter().map(ShardIndex::len).sum();
        assert_eq!(total, points.len(), "every point owned exactly once");
        let mut all_ids: Vec<u32> = shards.iter().flat_map(|s| s.ids.clone()).collect();
        all_ids.sort_unstable();
        let want: Vec<u32> = (0..points.len() as u32).collect();
        assert_eq!(all_ids, want, "no id duplicated or dropped");
        for s in &shards {
            assert!(s.len() > 20, "range partition badly skewed: {}", s.len());
            assert!(s.ids.windows(2).all(|w| w[0] < w[1]), "ids ascend");
        }
    }

    #[test]
    fn sharded_union_matches_the_global_index() {
        // the exactness property the scatter-gather merge relies on:
        // merging per-shard top-k answers reproduces the global top-k
        let points = demo_points(64, 300, 5);
        let global = ShardIndex::build(&points, &cfg(0, 1));
        assert_eq!(global.len(), points.len());
        let shards: Vec<ShardIndex> = (0..3).map(|s| ShardIndex::build(&points, &cfg(s, 3))).collect();
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let mut q = rng.gaussian_vec(64);
            normalize(&mut q);
            let k = 8;
            let want = global.query(&q, k);
            let parts: Vec<Vec<(u32, u64)>> = shards.iter().map(|s| s.query(&q, k)).collect();
            let got = crate::router::topology::merge_topk(&parts, k);
            assert_eq!(got, want, "fleet merge must equal the global answer");
        }
    }

    #[test]
    fn placement_owner_is_a_contiguous_range_partition() {
        let pb = 10usize;
        let shards = 3usize;
        let mut last = 0usize;
        for code in 0..(1u64 << pb) {
            let o = placement_owner(code, pb, shards);
            assert!(o < shards);
            assert!(o >= last, "owner must be monotone in the code");
            last = o;
        }
        assert_eq!(placement_owner(0, pb, shards), 0);
        assert_eq!(placement_owner((1 << pb) - 1, pb, shards), shards - 1);
    }
}
