//! Hedged-request policy: when to duplicate a straggling sub-query.
//!
//! Classic tail-at-scale hedging: wait a latency-percentile delay, then
//! fire one duplicate at a replica and take whichever terminal answer
//! lands first. The delay adapts per shard group — it tracks that
//! group's own p95 (clamped to a configured band), so a uniformly slow
//! group does not trigger a hedge storm and a uniformly fast one hedges
//! promptly. Until enough samples exist the policy uses a fixed initial
//! delay rather than extrapolating from noise.

use crate::coordinator::metrics::Histogram;
use std::time::Duration;

/// Samples needed before the p95 estimate replaces the initial delay.
const MIN_SAMPLES: u64 = 16;

/// Per-group hedge policy (shared by that group's scatter workers).
pub struct HedgePolicy {
    latency: Histogram,
    min: Duration,
    max: Duration,
    initial: Duration,
}

impl HedgePolicy {
    pub fn new(min: Duration, max: Duration, initial: Duration) -> HedgePolicy {
        HedgePolicy {
            latency: Histogram::new(),
            min,
            max,
            initial: initial.clamp(min, max),
        }
    }

    /// Record one successful sub-query latency.
    pub fn observe(&self, latency: Duration) {
        self.latency.record_us(latency.as_micros() as u64);
    }

    /// How long to wait on the primary before hedging.
    pub fn delay(&self) -> Duration {
        if self.latency.count() < MIN_SAMPLES {
            return self.initial;
        }
        Duration::from_micros(self.latency.percentile_us(0.95)).clamp(self.min, self.max)
    }

    /// Observations recorded so far (observability).
    pub fn samples(&self) -> u64 {
        self.latency.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HedgePolicy {
        HedgePolicy::new(
            Duration::from_millis(1),
            Duration::from_millis(100),
            Duration::from_millis(10),
        )
    }

    #[test]
    fn initial_delay_until_enough_samples() {
        let p = policy();
        assert_eq!(p.delay(), Duration::from_millis(10));
        for _ in 0..MIN_SAMPLES - 1 {
            p.observe(Duration::from_micros(500));
        }
        assert_eq!(p.delay(), Duration::from_millis(10), "still warming up");
        p.observe(Duration::from_micros(500));
        assert!(p.delay() < Duration::from_millis(10), "p95 took over");
        assert_eq!(p.samples(), MIN_SAMPLES);
    }

    #[test]
    fn delay_tracks_p95_within_the_band() {
        let p = policy();
        for _ in 0..100 {
            p.observe(Duration::from_millis(4));
        }
        let d = p.delay();
        // histogram buckets are power-of-two upper edges: ~4ms lands in
        // the (4096..8192]us bucket
        assert!(d >= Duration::from_millis(4) && d <= Duration::from_millis(8), "{d:?}");
        // a slow group clamps at the max instead of never hedging
        let slow = policy();
        for _ in 0..100 {
            slow.observe(Duration::from_millis(900));
        }
        assert_eq!(slow.delay(), Duration::from_millis(100));
        // a fast group clamps at the min instead of hedging instantly
        let fast = HedgePolicy::new(
            Duration::from_millis(2),
            Duration::from_millis(100),
            Duration::from_millis(10),
        );
        for _ in 0..100 {
            fast.observe(Duration::from_micros(3));
        }
        assert_eq!(fast.delay(), Duration::from_millis(2));
    }
}
