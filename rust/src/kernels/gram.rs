//! Gram-matrix reconstruction: the accuracy metric of Figures 2 and 4.
//!
//! `err = ||K - K̃||_F / ||K||_F` where `K` is the exact Gram matrix and
//! `K̃[i][j] = Φ(p_i)ᵀΦ(p_j)` the feature-map approximation.

use super::features::FeatureMap;
use crate::linalg::Mat;
use crate::runtime::WorkerPool;

/// Feature matrix `Φ ∈ R^{N x D}`: one row per point, computed as a single
/// zero-padded batch through the persistent worker pool (batch kernels +
/// multi-core sharding) — bit-identical to the per-point path.
pub fn feature_matrix(map: &FeatureMap, points: &[Vec<f32>]) -> Mat {
    let d = map.dim_features();
    let n = map.dim_in();
    let mut xs = vec![0.0f32; points.len() * n];
    for (p, row) in points.iter().zip(xs.chunks_exact_mut(n)) {
        assert!(p.len() <= n, "point dim {} exceeds map dim {n}", p.len());
        row[..p.len()].copy_from_slice(p);
    }
    let mut out = Mat::zeros(points.len(), d);
    map.features_batch_into(&xs, &mut out.data, WorkerPool::global());
    out
}

/// Approximate Gram matrix `K̃ = Φ Φᵀ`.
pub fn approx_gram(map: &FeatureMap, points: &[Vec<f32>]) -> Mat {
    let phi = feature_matrix(map, points);
    let phit = phi.transpose();
    phi.matmul(&phit)
}

/// `||K̃ - K||_F / ||K||_F`.
pub fn reconstruction_error(map: &FeatureMap, points: &[Vec<f32>], exact: &Mat) -> f64 {
    approx_gram(map, points).rel_frob_err(exact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::exact;
    use crate::kernels::features::FeatureKind;
    use crate::transform::{make, Family};
    use crate::util::rng::Rng;

    fn sphere_points(count: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..count).map(|_| rng.unit_vec(dim)).collect()
    }

    #[test]
    fn error_decreases_with_more_features() {
        let n = 32;
        let pts = sphere_points(30, n, 1);
        let k_exact = exact::gram(&pts, |a, b| exact::gaussian(a, b, 1.0));
        let mut errs = Vec::new();
        for feats in [8usize, 64, 512] {
            // average over a few seeds to damp MC noise
            let mut e = 0.0;
            for s in 0..3 {
                let tr = make(Family::Dense, feats, n, n, &mut Rng::new(10 + s));
                let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
                e += reconstruction_error(&fm, &pts, &k_exact);
            }
            errs.push(e / 3.0);
        }
        assert!(
            errs[0] > errs[1] && errs[1] > errs[2],
            "errors should decrease: {errs:?}"
        );
        assert!(errs[2] < 0.1, "512 features should reconstruct well: {errs:?}");
    }

    #[test]
    fn structured_matches_unstructured_accuracy() {
        // The paper's headline: TripleSpin ≈ Gaussian accuracy.
        let n = 32;
        let pts = sphere_points(25, n, 2);
        let k_exact = exact::gram(&pts, |a, b| exact::gaussian(a, b, 1.0));
        let feats = 128;
        let avg_err = |fam: Family| -> f64 {
            let mut e = 0.0;
            for s in 0..4 {
                let tr = make(fam, feats, n, n, &mut Rng::new(60 + s));
                let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
                e += reconstruction_error(&fm, &pts, &k_exact);
            }
            e / 4.0
        };
        let dense = avg_err(Family::Dense);
        let hd3 = avg_err(Family::Hd3);
        assert!(
            hd3 < dense * 1.6,
            "hd3 err {hd3} should be comparable to dense err {dense}"
        );
    }

    #[test]
    fn feature_matrix_shape() {
        let n = 16;
        let pts = sphere_points(5, n, 3);
        let tr = make(Family::Hd3, 32, n, n, &mut Rng::new(4));
        let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
        let phi = feature_matrix(&fm, &pts);
        assert_eq!(phi.rows, 5);
        assert_eq!(phi.cols, 64);
    }
}
