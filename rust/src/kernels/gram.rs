//! Gram-matrix reconstruction: the accuracy metric of Figures 2 and 4.
//!
//! `err = ||K - K̃||_F / ||K||_F` where `K` is the exact Gram matrix and
//! `K̃[i][j] = Φ(p_i)ᵀΦ(p_j)` the feature-map approximation.

use super::features::FeatureMap;
use crate::linalg::Mat;
use crate::runtime::WorkerPool;

/// Feature matrix `Φ ∈ R^{N x D}`: one row per point, computed as a single
/// zero-padded batch through the persistent worker pool (batch kernels +
/// multi-core sharding) — bit-identical to the per-point path.
pub fn feature_matrix(map: &FeatureMap, points: &[Vec<f32>]) -> Mat {
    let d = map.dim_features();
    let n = map.dim_in();
    let xs = crate::linalg::dense::flatten_padded(points, n);
    let mut out = Mat::zeros(points.len(), d);
    map.features_batch_into(&xs, &mut out.data, WorkerPool::global());
    out
}

/// Approximate Gram matrix `K̃ = Φ Φᵀ`.
pub fn approx_gram(map: &FeatureMap, points: &[Vec<f32>]) -> Mat {
    let phi = feature_matrix(map, points);
    let phit = phi.transpose();
    phi.matmul(&phit)
}

/// `||K̃ - K||_F / ||K||_F`.
pub fn reconstruction_error(map: &FeatureMap, points: &[Vec<f32>], exact: &Mat) -> f64 {
    approx_gram(map, points).rel_frob_err(exact)
}

/// Packed code matrix: one 1-bit sign code per point (the binarized
/// feature path), computed as a single pooled batch — the bit-matrix
/// analogue of [`feature_matrix`] at 1/32 the bytes.
pub fn binary_code_matrix(map: &FeatureMap, points: &[Vec<f32>]) -> crate::binary::BitMatrix {
    let n = map.dim_in();
    let xs = crate::linalg::dense::flatten_padded(points, n);
    let mut out = crate::binary::BitMatrix::zeros(points.len(), map.dim_projection());
    map.binary_codes_batch_into(&xs, &mut out, WorkerPool::global());
    out
}

/// 1-bit approximate Gram matrix: `K̃1[i][j] = 1 - 2·d_H(c_i, c_j)/k` over
/// the packed codes — pure XOR/popcount, no float features. For the
/// angular kernel this matches [`approx_gram`] of the sign feature map up
/// to f32 dot round-off (pinned in `kernels::features` tests).
pub fn binary_gram(map: &FeatureMap, points: &[Vec<f32>]) -> Mat {
    let codes = binary_code_matrix(map, points);
    let n = codes.rows();
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let est = map.approx_kernel_1bit(codes.row(i), codes.row(j));
            out.data[i * n + j] = est as f32;
            out.data[j * n + i] = est as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::exact;
    use crate::kernels::features::FeatureKind;
    use crate::transform::{make, Family};
    use crate::util::rng::Rng;

    fn sphere_points(count: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..count).map(|_| rng.unit_vec(dim)).collect()
    }

    #[test]
    fn error_decreases_with_more_features() {
        let n = 32;
        let pts = sphere_points(30, n, 1);
        let k_exact = exact::gram(&pts, |a, b| exact::gaussian(a, b, 1.0));
        let mut errs = Vec::new();
        for feats in [8usize, 64, 512] {
            // average over a few seeds to damp MC noise
            let mut e = 0.0;
            for s in 0..3 {
                let tr = make(Family::Dense, feats, n, n, &mut Rng::new(10 + s));
                let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
                e += reconstruction_error(&fm, &pts, &k_exact);
            }
            errs.push(e / 3.0);
        }
        assert!(
            errs[0] > errs[1] && errs[1] > errs[2],
            "errors should decrease: {errs:?}"
        );
        assert!(errs[2] < 0.1, "512 features should reconstruct well: {errs:?}");
    }

    #[test]
    fn structured_matches_unstructured_accuracy() {
        // The paper's headline: TripleSpin ≈ Gaussian accuracy.
        let n = 32;
        let pts = sphere_points(25, n, 2);
        let k_exact = exact::gram(&pts, |a, b| exact::gaussian(a, b, 1.0));
        let feats = 128;
        let avg_err = |fam: Family| -> f64 {
            let mut e = 0.0;
            for s in 0..4 {
                let tr = make(fam, feats, n, n, &mut Rng::new(60 + s));
                let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
                e += reconstruction_error(&fm, &pts, &k_exact);
            }
            e / 4.0
        };
        let dense = avg_err(Family::Dense);
        let hd3 = avg_err(Family::Hd3);
        assert!(
            hd3 < dense * 1.6,
            "hd3 err {hd3} should be comparable to dense err {dense}"
        );
    }

    #[test]
    fn binary_gram_pinned_against_dense_angular_gram() {
        // matrix-level pin: for the angular kernel, the XOR/popcount Gram
        // equals the dense sign-feature Gram up to f32 round-off, and both
        // approximate the exact angular Gram.
        let n = 32;
        let pts = sphere_points(20, n, 8);
        let tr = make(Family::Hd3, 128, n, n, &mut Rng::new(80));
        let fm = FeatureMap::new(tr, FeatureKind::Angular, 1.0);
        let dense = approx_gram(&fm, &pts);
        let one_bit = binary_gram(&fm, &pts);
        assert_eq!(one_bit.rows, dense.rows);
        for i in 0..dense.rows {
            for j in 0..dense.cols {
                let (a, b) = (dense.data[i * dense.cols + j], one_bit.data[i * dense.cols + j]);
                assert!((a - b).abs() < 1e-4, "[{i}][{j}]: dense {a} vs 1-bit {b}");
            }
        }
        let k_exact = exact::gram(&pts, exact::angular);
        let err = one_bit.rel_frob_err(&k_exact);
        assert!(err < 0.35, "1-bit angular gram err {err}");
        // footprint: 128-bit codes vs 128 f32 features per point (bytes)
        let codes = binary_code_matrix(&fm, &pts);
        assert_eq!(codes.storage_bytes() * 32, pts.len() * 128 * 4);
    }

    #[test]
    fn feature_matrix_shape() {
        let n = 16;
        let pts = sphere_points(5, n, 3);
        let tr = make(Family::Hd3, 32, n, n, &mut Rng::new(4));
        let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
        let phi = feature_matrix(&fm, &pts);
        assert_eq!(phi.rows, 5);
        assert_eq!(phi.cols, 64);
    }
}
