//! Kernel approximation with random feature maps (paper §4).
//!
//! Pointwise Nonlinear Gaussian (PNG) kernels
//! `κ(x, y) = E[f(gᵀx) f(gᵀy)]` are estimated by Monte-Carlo:
//! `κ̂(x, y) = (1/k) f(Gx)ᵀ f(Gy)` with `G` either an unstructured Gaussian
//! matrix or any TripleSpin member. [`exact`] holds closed forms for the
//! kernels the experiments sweep (Gaussian, angular, arc-cosine), [`features`]
//! the feature-map machinery, [`png`] the general PNG / sum-of-PNG layer
//! (Theorem 4.1's spectral-mixture construction), and [`gram`] the
//! Gram-matrix reconstruction metric of Figures 2 and 4.

pub mod exact;
pub mod features;
pub mod gram;
pub mod png;

pub use features::{FeatureKind, FeatureMap};
