//! General Pointwise Nonlinear Gaussian (PNG) kernels and sums of PNGs
//! (paper §4, Theorem 4.1).
//!
//! A PNG is `κ_{f,μ,Σ}(x,y) = E[f(gᵀx) f(gᵀy)]`, `g ~ N(μ, Σ)` with
//! diagonal Σ. Sums of PNGs are dense in stationary kernels (Theorem 4.1 —
//! the spectral-mixture family): the Gaussian kernel itself is the 2-term
//! sum `E[cos(gᵀx)cos(gᵀy)] + E[sin(gᵀx)sin(gᵀy)]`.
//!
//! [`PngComponent`] estimates one PNG term with any [`Transform`]; a
//! [`PngSum`] mixes components with weights `α_k`, giving the library's
//! "virtually all kernels" surface.

use crate::linalg::vecops::dot;
use crate::linalg::Workspace;
use crate::transform::Transform;

/// Pointwise nonlinearity choices for a PNG component.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Nonlin {
    Cos,
    Sin,
    Relu,
    Sign,
    Identity,
    /// Sigmoidal-network nonlinearity `tanh`.
    Tanh,
}

impl Nonlin {
    #[inline]
    pub fn eval(&self, t: f32) -> f32 {
        match self {
            Nonlin::Cos => t.cos(),
            Nonlin::Sin => t.sin(),
            Nonlin::Relu => t.max(0.0),
            Nonlin::Sign => {
                if t >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            Nonlin::Identity => t,
            Nonlin::Tanh => t.tanh(),
        }
    }
}

/// One PNG term `E[f((σ ⊙ g + μ)ᵀ x) f((σ ⊙ g + μ)ᵀ y)]` estimated with the
/// rows of `transform` standing in for the Gaussian draws `g`.
pub struct PngComponent {
    transform: Box<dyn Transform>,
    pub f: Nonlin,
    /// Mean shift μ (projected as `μᵀx` added per feature; `None` = 0).
    pub mu: Option<Vec<f32>>,
    /// Per-dimension scale σ (applied to the *input*, which is equivalent to
    /// scaling the Gaussian rows for diagonal Σ; `None` = 1).
    pub sigma: Option<Vec<f32>>,
}

impl PngComponent {
    pub fn new(transform: Box<dyn Transform>, f: Nonlin) -> PngComponent {
        PngComponent {
            transform,
            f,
            mu: None,
            sigma: None,
        }
    }

    pub fn with_mu(mut self, mu: Vec<f32>) -> PngComponent {
        assert_eq!(mu.len(), self.transform.dim_in());
        self.mu = Some(mu);
        self
    }

    pub fn with_sigma(mut self, sigma: Vec<f32>) -> PngComponent {
        assert!(sigma.len() <= self.transform.dim_in());
        self.sigma = Some(sigma);
        self
    }

    pub fn dim_features(&self) -> usize {
        self.transform.dim_out()
    }

    /// Feature vector `(1/√k) f(Gx + μᵀx·1)` into `out`
    /// (`out.len() == dim_features()`), all scratch drawn from `ws` — dot of
    /// two of these is the Monte-Carlo PNG estimate.
    pub fn features_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let n = self.transform.dim_in();
        assert!(x.len() <= n, "input dim {} exceeds transform dim {n}", x.len());
        let k = self.transform.dim_out();
        debug_assert_eq!(out.len(), k);
        // σ ⊙ x, zero-padded to n (diagonal Σ absorbed into the input)
        let mut xs = ws.take_f32(n); // zeroed by take_f32
        xs[..x.len()].copy_from_slice(x);
        if let Some(sig) = &self.sigma {
            for (v, s) in xs.iter_mut().zip(sig) {
                *v *= *s;
            }
        }
        let mut proj = ws.take_f32_uninit(k); // OVERWRITE: fully overwritten by apply_into
        self.transform.apply_into(&xs, &mut proj, ws);
        // μᵀx over the zero-padded input == μ[..len]ᵀ x
        let mu_dot = self
            .mu
            .as_ref()
            .map(|m| dot(&m[..x.len()], x) as f32)
            .unwrap_or(0.0);
        let scale = (1.0 / k as f64).sqrt() as f32;
        for (o, v) in out.iter_mut().zip(&proj) {
            *o = self.f.eval(v + mu_dot) * scale;
        }
        ws.put_f32(proj);
        ws.put_f32(xs);
    }

    /// Allocating wrapper over [`PngComponent::features_into`].
    pub fn features(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim_features()];
        let mut ws = Workspace::new();
        self.features_into(x, &mut out, &mut ws);
        out
    }

    /// Monte-Carlo estimate of the PNG kernel.
    pub fn estimate(&self, x: &[f32], y: &[f32]) -> f64 {
        dot(&self.features(x), &self.features(y))
    }
}

/// Weighted sum of PNG components: `κ(x,y) = Σ_k α_k κ_k(x,y)`.
///
/// Theorem 4.1: with cos/sin pairs and per-component `(μ_k, σ_k)` this family
/// is dense in stationary kernels (spectral mixtures).
pub struct PngSum {
    pub components: Vec<(f64, PngComponent)>,
}

impl PngSum {
    pub fn new(components: Vec<(f64, PngComponent)>) -> PngSum {
        PngSum { components }
    }

    /// The Gaussian kernel `exp(-||x-y||²/(2σ²))` as the canonical 2-term
    /// PNG sum: `E[cos(gᵀx/σ)cos(gᵀy/σ)] + E[sin(gᵀx/σ)sin(gᵀy/σ)]`.
    pub fn gaussian_kernel(
        make_transform: &mut dyn FnMut() -> Box<dyn Transform>,
        sigma: f64,
        dim: usize,
    ) -> PngSum {
        let inv = (1.0 / sigma) as f32;
        let sig = vec![inv; dim];
        let cos = PngComponent::new(make_transform(), Nonlin::Cos).with_sigma(sig.clone());
        let sin = PngComponent::new(make_transform(), Nonlin::Sin).with_sigma(sig);
        PngSum::new(vec![(1.0, cos), (1.0, sin)])
    }

    pub fn estimate(&self, x: &[f32], y: &[f32]) -> f64 {
        self.components
            .iter()
            .map(|(a, c)| a * c.estimate(x, y))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::exact;
    use crate::transform::{make, Family};
    use crate::util::rng::Rng;

    #[test]
    fn gaussian_kernel_as_png_sum() {
        // 2-term cos/sin PNG sum ≈ Gaussian kernel, with a TripleSpin
        // transform inside. NOTE: cos/sin must share the SAME projection for
        // the identity to hold per-sample; with independent draws it still
        // holds in expectation — we average seeds.
        let n = 32;
        let sigma = 1.5;
        let mut rng = Rng::new(1);
        let x = rng.unit_vec(n);
        let mut y = rng.unit_vec(n);
        for (a, b) in y.iter_mut().zip(&x) {
            *a = 0.7 * *a + 0.3 * *b;
        }
        crate::linalg::vecops::normalize(&mut y);
        let expect = exact::gaussian(&x, &y, sigma);
        let mut est = 0.0;
        let trials = 12;
        for s in 0..trials {
            let mut seed = 100 + s;
            let mut mk = || -> Box<dyn Transform> {
                seed += 1;
                make(Family::Hd3, 256, n, n, &mut Rng::new(seed))
            };
            let sum = PngSum::gaussian_kernel(&mut mk, sigma, n);
            est += sum.estimate(&x, &y);
        }
        est /= trials as f64;
        assert!(
            (est - expect).abs() < 0.06,
            "PNG-sum estimate {est} vs exact {expect}"
        );
    }

    #[test]
    fn sign_png_estimates_angular() {
        let n = 64;
        let mut rng = Rng::new(2);
        let x = rng.unit_vec(n);
        let y = rng.unit_vec(n);
        let expect = exact::angular(&x, &y);
        let mut est = 0.0;
        let trials = 10;
        for s in 0..trials {
            let tr = make(Family::Hdg, 512, n, n, &mut Rng::new(300 + s));
            let c = PngComponent::new(tr, Nonlin::Sign);
            est += c.estimate(&x, &y);
        }
        est /= trials as f64;
        assert!((est - expect).abs() < 0.08, "{est} vs {expect}");
    }

    #[test]
    fn relu_png_estimates_arccosine() {
        // E[relu(gᵀx) relu(gᵀy)] = κ_arc(x,y) / 2
        let n = 32;
        let mut rng = Rng::new(3);
        let x = rng.unit_vec(n);
        let y = rng.unit_vec(n);
        let expect = exact::arc_cosine1(&x, &y) / 2.0;
        let mut est = 0.0;
        let trials = 10;
        for s in 0..trials {
            let tr = make(Family::Dense, 512, n, n, &mut Rng::new(400 + s));
            let c = PngComponent::new(tr, Nonlin::Relu);
            est += c.estimate(&x, &y);
        }
        est /= trials as f64;
        assert!((est - expect).abs() < 0.05, "{est} vs {expect}");
    }

    #[test]
    fn identity_png_is_dot_product() {
        // f = id: E[(gᵀx)(gᵀy)] = xᵀy — the linear kernel.
        let n = 16;
        let mut rng = Rng::new(4);
        let x = rng.unit_vec(n);
        let y = rng.unit_vec(n);
        let expect = dot(&x, &y);
        let mut est = 0.0;
        let trials = 20;
        for s in 0..trials {
            let tr = make(Family::Circulant, 256, n, n, &mut Rng::new(500 + s));
            let c = PngComponent::new(tr, Nonlin::Identity);
            est += c.estimate(&x, &y);
        }
        est /= trials as f64;
        assert!((est - expect).abs() < 0.08, "{est} vs {expect}");
    }

    #[test]
    fn nonlin_eval_table() {
        assert_eq!(Nonlin::Relu.eval(-2.0), 0.0);
        assert_eq!(Nonlin::Relu.eval(2.0), 2.0);
        assert_eq!(Nonlin::Sign.eval(-0.1), -1.0);
        assert_eq!(Nonlin::Sign.eval(0.0), 1.0);
        assert_eq!(Nonlin::Identity.eval(3.5), 3.5);
        assert!((Nonlin::Cos.eval(0.0) - 1.0).abs() < 1e-7);
        assert!(Nonlin::Sin.eval(0.0).abs() < 1e-7);
        assert!((Nonlin::Tanh.eval(100.0) - 1.0).abs() < 1e-6);
    }
}
