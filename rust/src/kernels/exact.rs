//! Closed-form kernels used as ground truth in the experiments.

use crate::linalg::vecops::{angle, euclidean};
use crate::linalg::Mat;
use std::f64::consts::PI;

/// Gaussian (RBF) kernel `exp(-||x-y||² / (2σ²))`.
pub fn gaussian(x: &[f32], y: &[f32], sigma: f64) -> f64 {
    let d = euclidean(x, y);
    (-d * d / (2.0 * sigma * sigma)).exp()
}

/// Angular kernel `1 - 2θ/π` (the sign/"binary embedding" kernel of [9]:
/// `E[sign(gᵀx) sign(gᵀy)] = 1 - 2θ/π`).
pub fn angular(x: &[f32], y: &[f32]) -> f64 {
    1.0 - 2.0 * angle(x, y) / PI
}

/// First-order arc-cosine kernel (Cho & Saul):
/// `κ(x,y) = (1/π) ||x|| ||y|| (sin θ + (π-θ) cos θ)`; its PNG form uses
/// `f = ReLU` with a `√2` normalization: `E[relu(gᵀx) relu(gᵀy)] = κ/2`.
pub fn arc_cosine1(x: &[f32], y: &[f32]) -> f64 {
    use crate::linalg::vecops::norm2;
    let theta = angle(x, y);
    norm2(x) * norm2(y) / PI * (theta.sin() + (PI - theta) * theta.cos())
}

/// Exact Gram matrix `K[i][j] = κ(p_i, p_j)` for a pointwise kernel.
pub fn gram<F: Fn(&[f32], &[f32]) -> f64>(points: &[Vec<f32>], k: F) -> Mat {
    let n = points.len();
    let mut m = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = k(&points[i], &points[j]) as f32;
            *m.at_mut(i, j) = v;
            *m.at_mut(j, i) = v;
        }
    }
    m
}

/// Median-heuristic bandwidth: the median pairwise Euclidean distance over
/// at most `cap` points (the standard way USPST's σ=9.4338 was derived).
pub fn median_bandwidth(points: &[Vec<f32>], cap: usize) -> f64 {
    let n = points.len().min(cap);
    let mut dists = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in i + 1..n {
            dists.push(euclidean(&points[i], &points[j]));
        }
    }
    if dists.is_empty() {
        return 1.0;
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    dists[dists.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn gaussian_limits() {
        let x = [1.0f32, 2.0];
        assert!((gaussian(&x, &x, 1.0) - 1.0).abs() < 1e-12);
        // far apart -> ~0
        assert!(gaussian(&[0.0, 0.0], &[100.0, 0.0], 1.0) < 1e-12);
    }

    #[test]
    fn gaussian_symmetry_and_bounds() {
        for_all(24, |g| {
            let n = g.usize_in(1, 16);
            let x = g.gaussian_vec(n);
            let y = g.gaussian_vec(n);
            let s = g.f32_in(0.5, 10.0) as f64;
            let k = gaussian(&x, &y, s);
            assert!((0.0..=1.0).contains(&k));
            assert!((k - gaussian(&y, &x, s)).abs() < 1e-12);
        });
    }

    #[test]
    fn angular_known_values() {
        assert!((angular(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(angular(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6); // orthogonal -> 0
        assert!((angular(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6); // opposite -> -1
    }

    #[test]
    fn arc_cosine_parallel() {
        // θ=0: κ = ||x|| ||y||
        let x = [2.0f32, 0.0];
        assert!((arc_cosine1(&x, &x) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let pts: Vec<Vec<f32>> = vec![
            vec![1.0, 0.0],
            vec![0.5, 0.5],
            vec![-1.0, 0.2],
        ];
        let g = gram(&pts, |a, b| gaussian(a, b, 2.0));
        for i in 0..3 {
            assert!((g.at(i, i) - 1.0).abs() < 1e-6);
            for j in 0..3 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn median_bandwidth_sane() {
        let pts: Vec<Vec<f32>> = vec![vec![0.0], vec![1.0], vec![2.0]];
        // pairwise distances 1, 1, 2 -> median 1
        assert!((median_bandwidth(&pts, 10) - 1.0).abs() < 1e-9);
        assert_eq!(median_bandwidth(&pts[..1], 10), 1.0); // degenerate
    }
}
